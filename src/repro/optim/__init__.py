from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .compress import (dequantize_int8, ef_compress, ef_init, quantize_int8)
from .schedule import warmup_cosine

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "warmup_cosine", "quantize_int8", "dequantize_int8", "ef_init",
           "ef_compress"]
