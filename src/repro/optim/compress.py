"""Gradient compression for cross-pod (DCN) synchronization.

int8 quantization with error feedback (EF-SGD style): the quantization
residual is carried into the next step, so compression adds no bias to
the long-run gradient signal. Intended for the slow pod axis — ICI
all-reduces stay full precision; the planner models the 4× byte saving
via ``Workload.grad_compression``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-12


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor absmax int8 quantization → (q int8, scale f32)."""
    scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0 + _EPS
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_init(params) -> Any:
    """Error-feedback residual state (f32, zero-init, param-shaped)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress(grads, ef_state) -> Tuple[Any, Any, Dict[str, jnp.ndarray]]:
    """Compress grads with error feedback.

    Returns (decompressed grads — what a receiver reconstructs after the
    int8 all-reduce — , new residual state, metrics)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_e = treedef.unflatten([o[1] for o in out])
    err = jnp.sqrt(sum(jnp.sum(jnp.square(e)) for _, e in out))
    return new_g, new_e, {"ef_residual_norm": err}
