"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b", family="dense", n_layers=24, d_model=2560,
    n_heads=32, n_kv_heads=8, head_dim=80, d_ff=6912, vocab_size=32000,
    gated_mlp=True, act="silu", window=4096,
)

REDUCED = ArchConfig(
    name="h2o-danube-reduced", family="dense", n_layers=4, d_model=128,
    n_heads=8, n_kv_heads=2, head_dim=16, d_ff=384, vocab_size=512,
    gated_mlp=True, act="silu", window=32, dtype="float32",
)
