"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000 — RG-LRU + local attention, 2 recurrent : 1
attention. [arXiv:2402.19427]

Pattern (rec, rec, attn) ⇒ 12 scan units + 2 unrolled recurrent layers;
local-attention window 2048; lru_width = d_model.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, head_dim=256, d_ff=12288, vocab_size=256000,
    gated_mlp=True, act="gelu", window=2048,
    block_pattern=("rec", "rec", "local_attn"), lru_width=4096,
    tie_embeddings=True,
)

REDUCED = ArchConfig(
    name="recurrentgemma-reduced", family="hybrid", n_layers=5, d_model=128,
    n_heads=4, n_kv_heads=1, head_dim=32, d_ff=384, vocab_size=512,
    gated_mlp=True, act="gelu", window=32,
    block_pattern=("rec", "rec", "local_attn"), lru_width=128,
    tie_embeddings=True, dtype="float32",
)
