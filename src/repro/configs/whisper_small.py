"""whisper-small [audio]: 12L d_model=768 12H d_ff=3072 vocab=51865 —
encoder-decoder; conv frontend STUB (input_specs() provides 1500
precomputed frame embeddings). [arXiv:2212.04356]

Vocab padded 51865 → 52096. 12 heads are not divisible by the 16-way
model axis ⇒ attention TP via flat-projection sharding (DESIGN.md).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="encdec", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=51865,
    gated_mlp=False, act="gelu",
    encdec=True, n_enc_layers=12, enc_seq=1500, tie_embeddings=True,
)

REDUCED = ArchConfig(
    name="whisper-reduced", family="encdec", n_layers=3, d_model=96,
    n_heads=4, n_kv_heads=4, head_dim=24, d_ff=256, vocab_size=512,
    gated_mlp=False, act="gelu",
    encdec=True, n_enc_layers=3, enc_seq=32, tie_embeddings=True,
    dtype="float32",
)
