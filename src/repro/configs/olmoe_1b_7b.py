"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16) per-expert d_ff=1024
vocab=50304, 64 experts top-8. [arXiv:2409.02060; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1024, vocab_size=50304,
    gated_mlp=True, act="silu", qk_norm=True,
    n_experts=64, experts_per_token=8, moe_d_ff=1024,
)

REDUCED = ArchConfig(
    name="olmoe-reduced", family="moe", n_layers=3, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
    gated_mlp=True, act="silu", qk_norm=True,
    n_experts=8, experts_per_token=2, moe_d_ff=128, dtype="float32",
)
