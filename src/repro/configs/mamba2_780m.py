"""mamba2-780m [ssm]: 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060]

d_inner = 2·d_model = 3072, headdim 64 ⇒ 48 SSD heads, 1 group.
Vocab padded 50280 → 50432 for 16-way sharding (loss-masked).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm", n_layers=48, d_model=1536,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=50280,
    ssm=True, ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_ngroups=1,
    tie_embeddings=True,
)

REDUCED = ArchConfig(
    name="mamba2-reduced", family="ssm", n_layers=4, d_model=128,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=512,
    ssm=True, ssm_state=16, ssm_headdim=32, ssm_expand=2, ssm_ngroups=1,
    ssm_chunk=32, tie_embeddings=True, dtype="float32",
)
