"""deepseek-v2-236b [moe]: 60L d_model=5120 128H, MLA kv_lora=512,
160 routed experts top-6 + 2 shared, per-expert d_ff=1536.
[arXiv:2405.04434; hf]

First layer uses a dense FFN (d_ff=12288); q_lora_rank=1536,
qk_nope=128, qk_rope=64, v_head=128 per the public config.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
    n_heads=128, n_kv_heads=128, d_ff=12288, vocab_size=102400,
    gated_mlp=True, act="silu",
    n_experts=160, experts_per_token=6, n_shared_experts=2,
    moe_d_ff=1536, n_dense_layers=1,
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
)

REDUCED = ArchConfig(
    name="deepseek-v2-reduced", family="moe", n_layers=3, d_model=128,
    n_heads=8, n_kv_heads=8, d_ff=256, vocab_size=512,
    gated_mlp=True, act="silu",
    n_experts=8, experts_per_token=2, n_shared_experts=1,
    moe_d_ff=64, n_dense_layers=1,
    mla=True, q_lora_rank=64, kv_lora_rank=32,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, dtype="float32",
)
