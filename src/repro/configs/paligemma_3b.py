"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216 — SigLIP + gemma-2b backbone. [arXiv:2407.07726; hf]

SigLIP frontend is a STUB: input_specs() provides 256 precomputed patch
embeddings; image prefix attends bidirectionally (prefix-LM).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm", n_layers=18, d_model=2048,
    n_heads=8, n_kv_heads=1, head_dim=256, d_ff=16384, vocab_size=257216,
    gated_mlp=True, act="gelu", tie_embeddings=True,
    vision_stub=True, n_patches=256, prefix_len=256,
)

REDUCED = ArchConfig(
    name="paligemma-reduced", family="vlm", n_layers=3, d_model=128,
    n_heads=4, n_kv_heads=1, head_dim=32, d_ff=384, vocab_size=512,
    gated_mlp=True, act="gelu", tie_embeddings=True,
    vision_stub=True, n_patches=16, prefix_len=16, dtype="float32",
)
