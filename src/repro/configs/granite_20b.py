"""granite-20b [dense]: 52L d_model=6144 48H (GQA kv=1 → MQA)
d_ff=24576 vocab=49152 — code model. [arXiv:2405.04324; hf]

d_ff = 4·d_model ⇒ standard (non-gated) 2-matrix MLP, matching the
20B analytic parameter count.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="dense", n_layers=52, d_model=6144,
    n_heads=48, n_kv_heads=1, head_dim=128, d_ff=24576, vocab_size=49152,
    gated_mlp=False, act="gelu",
)

REDUCED = ArchConfig(
    name="granite-20b-reduced", family="dense", n_layers=4, d_model=128,
    n_heads=8, n_kv_heads=1, head_dim=16, d_ff=512, vocab_size=512,
    gated_mlp=False, act="gelu", dtype="float32",
)
