"""Assigned architectures (exact public configs) + input-shape sets.

Every entry is selectable via ``--arch <id>`` in the launchers. The
``shapes`` table defines the 4 assigned input shapes; per-arch skips
(long_500k for pure full-attention archs) are encoded in
``applicable_shapes`` and documented in DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from ..models.config import ArchConfig

ARCH_IDS = [
    "qwen3_32b", "granite_20b", "h2o_danube_1_8b", "granite_8b",
    "mamba2_780m", "recurrentgemma_9b", "olmoe_1b_7b", "deepseek_v2_236b",
    "whisper_small", "paligemma_3b",
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs with sub-quadratic / bounded-window attention run long_500k
SUBQUADRATIC = {"mamba2_780m", "recurrentgemma_9b", "h2o_danube_1_8b"}


def get_config(arch: str) -> ArchConfig:
    arch = _ALIAS.get(arch, arch)
    mod = importlib.import_module(f".{arch}", __package__)
    return mod.CONFIG


def reduced_config(arch: str) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    arch = _ALIAS.get(arch, arch)
    mod = importlib.import_module(f".{arch}", __package__)
    return mod.REDUCED


def applicable_shapes(arch: str) -> List[ShapeSpec]:
    arch = _ALIAS.get(arch, arch)
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if arch in SUBQUADRATIC:
        out.append(SHAPES["long_500k"])
    return out


def all_cells() -> List[Tuple[str, ShapeSpec]]:
    """Every assigned (arch × shape) cell (40 incl. documented skips)."""
    cells = []
    for a in ARCH_IDS:
        for s in applicable_shapes(a):
            cells.append((a, s))
    return cells
