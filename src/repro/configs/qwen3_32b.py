"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=25600, vocab_size=151936,
    gated_mlp=True, act="silu", qk_norm=True, rope_theta=1_000_000.0,
)

REDUCED = ArchConfig(
    name="qwen3-32b-reduced", family="dense", n_layers=4, d_model=128,
    n_heads=8, n_kv_heads=2, head_dim=16, d_ff=512, vocab_size=512,
    gated_mlp=True, act="silu", qk_norm=True, dtype="float32",
)
