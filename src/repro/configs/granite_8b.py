"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152 — llama-arch code model. [arXiv:2405.04324; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b", family="dense", n_layers=36, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=49152,
    gated_mlp=True, act="silu",
)

REDUCED = ArchConfig(
    name="granite-8b-reduced", family="dense", n_layers=4, d_model=128,
    n_heads=8, n_kv_heads=2, head_dim=16, d_ff=448, vocab_size=512,
    gated_mlp=True, act="silu", dtype="float32",
)
