"""The armed multi-tenant runtime: per-tenant adapters + rebalancing.

A :class:`FleetSession` is to a fleet what
:class:`~repro.dora.ServeSession` is to one workload.  It keeps one
ServeSession per tenant (each with its runtime adapter armed over the
tenant's candidate pool) plus the *fleet-level* cumulative picture:

* Non-churn dynamics events are translated into each tenant's device
  space and routed to its adapter — a compute-speed drop on a device
  only stirs the tenant that owns it; a shared-link bandwidth shift
  reaches every tenant on the medium.
* Device ``leave``/``join`` churn — and load shifts that leave a tenant
  QoE-infeasible — trigger a **rebalance**: the
  :class:`~repro.fleet.planner.FleetPlanner` search re-runs on the
  surviving fleet under the accumulated conditions, warm-starting every
  dora tenant from its surviving candidate pool
  (:meth:`DoraPlanner.replan`) and always pricing the incumbent
  assignment so devices only move when moving wins.  Each re-assigned
  tenant's migration stall is priced by the adapter's delta-switching
  model against its previous plan re-indexed into the new allotment.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

from ..core.adapter import DynamicsEvent, RuntimeAdapter, RuntimeState, \
    cold_load_stall
from ..core.scheduler import NetworkScheduler
from ..dora import ServeSession, _remap_plan
from .planner import FleetPlan, FleetPlanner, TenantPlan, _translate


def _orig_placement(plan, tp: TenantPlan) -> tuple:
    """A tenant-local plan's placement signature in *fleet* device space
    (which model nodes sit on which physical devices)."""
    inv = {loc: orig for orig, loc in tp.mapping.items()}
    return tuple((tuple(s.node_ids), tuple(sorted(inv[d] for d in s.devices)))
                 for s in plan.stages)


@dataclasses.dataclass(frozen=True)
class TenantAction:
    """What the fleet runtime did about one event, for one tenant."""

    tenant: str
    action: str            # "reschedule" | "replan" | "rebalance"
    react_s: float
    stall_s: float
    latency_after: float
    allotment: Tuple[int, ...]


class FleetSession:
    """N tenant sessions + the rebalancer that moves devices between
    them.  ``sessions[name].current`` is tenant-local; map back to
    fleet ids via ``plan.tenants[name].allotment``."""

    def __init__(self, planner: FleetPlanner, plan: FleetPlan,
                 scenario=None):
        self.planner = planner
        self.plan = plan
        self.scenario = scenario        # FleetScenario (or None for ad-hoc)
        self.state = RuntimeState()     # fleet-space cumulative conditions
        self.active: Tuple[int, ...] = tuple(range(planner.topo.n))
        self.rebalances = 0
        self.sessions: Dict[str, ServeSession] = {}
        for name, tp in plan.tenants.items():
            self.sessions[name] = self._arm_tenant(tp)

    # -- wiring -------------------------------------------------------------------
    def _arm_tenant(self, tp: TenantPlan,
                    state: Optional[RuntimeState] = None) -> ServeSession:
        report = tp.report
        scheduler = NetworkScheduler(report.topology, report.qoe,
                                     self.planner.scheduler_config)
        adapter = RuntimeAdapter(report.candidates, report.topology,
                                 report.qoe, scheduler,
                                 self.planner.adapter_config)
        current = report.best
        local = state or RuntimeState()
        if local.compute_speed or local.bandwidth_scale:
            current = scheduler.refine(
                current, compute_speed=dict(local.compute_speed),
                bandwidth_scale=dict(local.bandwidth_scale))
        return ServeSession(report=report, adapter=adapter, current=current,
                            state=local,
                            partitioner_config=self.planner.partitioner_config,
                            scheduler_config=self.planner.scheduler_config)

    def _local_state(self, tp: TenantPlan,
                     merged: RuntimeState) -> RuntimeState:
        kw = _translate(merged, tp.mapping, tp.report.topology)
        return RuntimeState(compute_speed=kw["compute_speed"],
                            bandwidth_scale=kw["bandwidth_scale"])

    def _local_event(self, tp: TenantPlan,
                     event: DynamicsEvent) -> Optional[DynamicsEvent]:
        """``event`` in the tenant's device space, or ``None`` when it
        doesn't touch this tenant's devices or links at all."""
        speed = {tp.mapping[d]: v for d, v in event.compute_speed.items()
                 if d in tp.mapping}
        bw = {r: v for r, v in event.bandwidth_scale.items()
              if r in tp.report.topology.resources}
        if not speed and not bw:
            return None
        return DynamicsEvent(t=event.t, compute_speed=speed,
                             bandwidth_scale=bw)

    # -- properties ---------------------------------------------------------------
    @property
    def assignments(self) -> Dict[str, Tuple[int, ...]]:
        return self.plan.assignments

    @property
    def meets_qoe(self) -> bool:
        return all(s.meets_qoe for s in self.sessions.values())

    def tenant(self, name: str) -> ServeSession:
        return self.sessions[name]

    # -- dynamics -----------------------------------------------------------------
    def on_dynamics(self, event: DynamicsEvent) -> List[TenantAction]:
        """Feed one fleet-space runtime event to every affected tenant.

        Churn always rebalances; condition shifts route to the owning
        tenants' adapters, then trigger a rebalance if some tenant is
        left QoE-infeasible (and ``FleetConfig.rebalance_on_load``).
        Returns the actions taken, one per affected tenant.
        """
        if event.is_churn:
            return self._rebalance(event)
        merged = self.state.apply(event)
        actions: List[TenantAction] = []
        for name, tp in self.plan.tenants.items():
            local = self._local_event(tp, event)
            if local is None:
                continue
            sess = self.sessions[name]
            new, act, react = sess.on_dynamics(local)
            stall = (float(new.meta.get("switch_stall_s", 0.0))
                     if act == "replan" else 0.0)
            actions.append(TenantAction(tenant=name, action=act,
                                        react_s=react, stall_s=stall,
                                        latency_after=new.latency,
                                        allotment=tp.allotment))
        self.state = merged
        if (self.planner.config.rebalance_on_load
                and any(not s.meets_qoe for s in self.sessions.values())):
            actions += self._rebalance(None)
        return actions

    def _rebalance(self, event: Optional[DynamicsEvent]
                   ) -> List[TenantAction]:
        """Re-run the assignment search on the surviving fleet and move
        devices between tenants; no-op when the incumbent assignment is
        still the joint winner."""
        t0 = time.perf_counter()
        if event is not None:
            full_n = self.planner.topo.n
            bad = [d for d in (*event.leave, *event.join)
                   if not (0 <= d < full_n)]
            if bad:
                raise ValueError(f"churn references unknown devices {bad} "
                                 f"(fleet has {full_n})")
            fleet = (set(self.active) - set(event.leave)) | set(event.join)
            if len(fleet) < len(self.planner.tenants):
                raise ValueError(
                    f"churn leaves {sorted(fleet)}: not enough devices for "
                    f"{len(self.planner.tenants)} exclusive tenants")
            merged = self.state.apply(event)
        else:
            fleet = set(self.active)
            merged = self.state
        warm = {name: (list(sess.plans), self.plan.tenants[name].allotment)
                for name, sess in self.sessions.items()}
        conditions = merged if (merged.compute_speed
                                or merged.bandwidth_scale) else None
        new_plan = self.planner.plan(devices=sorted(fleet), warm=warm,
                                     conditions=conditions,
                                     include=[self.plan.assignments])
        if (event is None
                and new_plan.assignments == self.plan.assignments):
            # load-shift probe: moving devices doesn't help — stay put
            return []
        actions: List[TenantAction] = []
        old_plan = self.plan
        # a kept session is only valid if its shared-link pricing is
        # unchanged too — another tenant's move can change the medium's
        # user count and with it this tenant's fair share
        shares_of = self.planner.link_shares
        old_shares = shares_of(list(old_plan.assignments.values()))
        new_shares = shares_of(list(new_plan.assignments.values()))
        new_sessions: Dict[str, ServeSession] = {}
        for name, tp in new_plan.tenants.items():
            old_tp = old_plan.tenants.get(name)
            if (old_tp is not None and old_tp.allotment == tp.allotment
                    and self.planner._factors_key(tp.allotment, old_shares)
                    == self.planner._factors_key(tp.allotment, new_shares)):
                # same allotment, same link shares: keep the tenant's
                # adapted session (pareto pool and cumulative state are
                # already right) — but a churn event can carry condition
                # shifts too, and those must still reach the tenant
                sess = self.sessions[name]
                local = self._local_event(tp, event) \
                    if event is not None else None
                if local is not None:
                    new, act, react = sess.on_dynamics(local)
                    actions.append(TenantAction(
                        tenant=name, action=act, react_s=react,
                        stall_s=(float(new.meta.get("switch_stall_s", 0.0))
                                 if act == "replan" else 0.0),
                        latency_after=new.latency,
                        allotment=tp.allotment))
                new_sessions[name] = sess
                continue
            sess = self._arm_tenant(tp, state=self._local_state(tp, merged))
            stall = 0.0
            if old_tp is not None:
                old_current = self.sessions[name].current
                if (_orig_placement(old_current, old_tp)
                        != _orig_placement(sess.current, tp)):
                    # only a placement that actually moved pays migration
                    stall = self._migration_stall(
                        old_current, old_tp, tp, sess)
            sess.current.meta["switch_stall_s"] = stall
            sess.current.meta["fleet"] = list(tp.allotment)
            new_sessions[name] = sess
            actions.append(TenantAction(
                tenant=name, action="rebalance",
                react_s=time.perf_counter() - t0, stall_s=stall,
                latency_after=sess.current.latency,
                allotment=tp.allotment))
        self.plan = new_plan
        self.sessions = new_sessions
        self.active = tuple(sorted(fleet))
        self.state = merged
        self.rebalances += 1
        if event is not None and not actions:
            # churn that didn't move any allotment still reacted
            actions.append(TenantAction(
                tenant="*", action="rebalance",
                react_s=time.perf_counter() - t0, stall_s=0.0,
                latency_after=math.nan, allotment=self.active))
        return actions

    def _migration_stall(self, old_current, old_tp: TenantPlan,
                         new_tp: TenantPlan, sess: ServeSession) -> float:
        """Delta-switching stall of moving one tenant between
        allotments: its old plan re-indexed into the new device space
        prices the layers already resident."""
        trans = {old_tp.mapping[orig]: new_tp.mapping[orig]
                 for orig in old_tp.allotment if orig in new_tp.mapping}
        proxy = _remap_plan(old_current, trans)
        new = sess.current
        if proxy is not None:
            return sess.adapter.switch_cost(proxy, new)
        return cold_load_stall(new, new_tp.report.topology,
                               sess.adapter.config)
