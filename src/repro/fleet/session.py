"""The armed multi-tenant runtime: per-tenant adapters + rebalancing.

A :class:`FleetSession` is to a fleet what
:class:`~repro.dora.ServeSession` is to one workload.  It keeps one
ServeSession per tenant (each with its runtime adapter armed over the
tenant's candidate pool) plus the *fleet-level* cumulative picture:

* Non-churn dynamics events are translated into each tenant's device
  space and routed to its adapter — a compute-speed drop on a device
  only stirs the tenant that owns it; a shared-link bandwidth shift
  reaches every tenant on the medium.
* Device ``leave``/``join`` churn — and load shifts that leave a tenant
  QoE-infeasible — trigger a **rebalance**: the
  :class:`~repro.fleet.planner.FleetPlanner` search re-runs on the
  surviving fleet under the accumulated conditions, warm-starting every
  dora tenant from its surviving candidate pool
  (:meth:`DoraPlanner.replan`) and always pricing the incumbent
  assignment so devices only move when moving wins.  Each re-assigned
  tenant's migration stall is priced by the adapter's delta-switching
  model against its previous plan re-indexed into the new allotment.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..control.plane import FleetControlPlane, _remap_plan
from ..core.adapter import DynamicsEvent, RuntimeAdapter, RuntimeState, \
    cold_load_stall
from ..core.scheduler import NetworkScheduler
from ..dora import ServeSession
from .planner import FleetPlan, FleetPlanner, TenantPlan, _translate


def _orig_placement(plan, tp: TenantPlan) -> tuple:
    """A tenant-local plan's placement signature in *fleet* device space
    (which model nodes sit on which physical devices)."""
    inv = {loc: orig for orig, loc in tp.mapping.items()}
    return tuple((tuple(s.node_ids), tuple(sorted(inv[d] for d in s.devices)))
                 for s in plan.stages)


@dataclasses.dataclass(frozen=True)
class TenantAction:
    """What the fleet runtime did about one event, for one tenant."""

    tenant: str
    action: str            # "reschedule" | "replan" | "rebalance"
    react_s: float
    stall_s: float
    latency_after: float
    allotment: Tuple[int, ...]


class FleetSession:
    """N tenant sessions + the rebalancer that moves devices between
    them.  ``sessions[name].current`` is tenant-local; map back to
    fleet ids via ``plan.tenants[name].allotment``."""

    def __init__(self, planner: FleetPlanner, plan: FleetPlan,
                 scenario=None):
        self.planner = planner
        self.plan = plan
        self.scenario = scenario        # FleetScenario (or None for ad-hoc)
        self.state = RuntimeState()     # fleet-space cumulative conditions
        self.active: Tuple[int, ...] = tuple(range(planner.topo.n))
        self.rebalances = 0
        self.sessions: Dict[str, ServeSession] = {}
        for name, tp in plan.tenants.items():
            self.sessions[name] = self._arm_tenant(tp)
        #: the fleet's reaction layer (event routing + rebalancing);
        #: ``on_dynamics`` below is a thin adapter over it
        self.plane = FleetControlPlane(self)

    # -- wiring -------------------------------------------------------------------
    def _arm_tenant(self, tp: TenantPlan,
                    state: Optional[RuntimeState] = None) -> ServeSession:
        report = tp.report
        scheduler = NetworkScheduler(report.topology, report.qoe,
                                     self.planner.scheduler_config)
        adapter = RuntimeAdapter(report.candidates, report.topology,
                                 report.qoe, scheduler,
                                 self.planner.adapter_config)
        current = report.best
        local = state or RuntimeState()
        if local.compute_speed or local.bandwidth_scale:
            current = scheduler.refine(
                current, compute_speed=dict(local.compute_speed),
                bandwidth_scale=dict(local.bandwidth_scale))
        return ServeSession(report=report, adapter=adapter, current=current,
                            state=local,
                            partitioner_config=self.planner.partitioner_config,
                            scheduler_config=self.planner.scheduler_config)

    def _local_state(self, tp: TenantPlan,
                     merged: RuntimeState) -> RuntimeState:
        kw = _translate(merged, tp.mapping, tp.report.topology)
        # bandwidth entries are retained wholesale (resource ids are
        # fleet-global): a link outside the tenant's *current*
        # sub-topology doesn't price its plan today, but the tenant may
        # be rebalanced onto it later and must remember the shift —
        # dropping entries here made tenant state diverge from the
        # fleet's cumulative RuntimeState
        return RuntimeState(compute_speed=kw["compute_speed"],
                            bandwidth_scale=dict(merged.bandwidth_scale))

    def _local_event(self, tp: TenantPlan,
                     event: DynamicsEvent) -> Optional[DynamicsEvent]:
        """``event`` in the tenant's device space, or ``None`` when it
        doesn't touch this tenant's devices or links at all."""
        speed = {tp.mapping[d]: v for d, v in event.compute_speed.items()
                 if d in tp.mapping}
        bw = {r: v for r, v in event.bandwidth_scale.items()
              if r in tp.report.topology.resources}
        if not speed and not bw:
            return None
        return DynamicsEvent(t=event.t, compute_speed=speed,
                             bandwidth_scale=bw)

    # -- properties ---------------------------------------------------------------
    @property
    def assignments(self) -> Dict[str, Tuple[int, ...]]:
        return self.plan.assignments

    @property
    def meets_qoe(self) -> bool:
        return all(s.meets_qoe for s in self.sessions.values())

    def tenant(self, name: str) -> ServeSession:
        return self.sessions[name]

    # -- dynamics -----------------------------------------------------------------
    def on_dynamics(self, event: DynamicsEvent) -> List[TenantAction]:
        """Feed one fleet-space runtime event to every affected tenant.

        Churn always rebalances; condition shifts route to the owning
        tenants' adapters, then trigger a rebalance if some tenant is
        left QoE-infeasible (and ``FleetConfig.rebalance_on_load``).
        Returns the actions taken, one per affected tenant.  (Thin
        adapter over :meth:`FleetControlPlane.on_dynamics` — the single
        reaction implementation.)
        """
        return self.plane.on_dynamics(event)

    def _rebalance(self, event: Optional[DynamicsEvent]
                   ) -> List[TenantAction]:
        """Re-run the assignment search on the surviving fleet and move
        devices between tenants (adapter over
        :meth:`FleetControlPlane.rebalance`)."""
        return self.plane.rebalance(event)

    def _migration_stall(self, old_current, old_tp: TenantPlan,
                         new_tp: TenantPlan, sess: ServeSession) -> float:
        """Delta-switching stall of moving one tenant between
        allotments: its old plan re-indexed into the new device space
        prices the layers already resident."""
        trans = {old_tp.mapping[orig]: new_tp.mapping[orig]
                 for orig in old_tp.allotment if orig in new_tp.mapping}
        proxy = _remap_plan(old_current, trans)
        new = sess.current
        if proxy is not None:
            return sess.adapter.switch_cost(proxy, new)
        return cold_load_stall(new, new_tp.report.topology,
                               sess.adapter.config)
