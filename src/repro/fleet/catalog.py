"""Built-in multi-tenant fleet scenarios.

Each fleet reuses a Table-3 device setting and co-locates two tenant
workloads on it.  Tenant ``topology`` fields point at the *same* shared
fleet builder, so ``dora.plan(tenant)`` standalone reproduces exactly
the "independent planning on the full fleet" baseline that
``benchmarks/fig_fleet.py`` compares against: without co-planning,
every tenant independently picks the same energy-optimal device and
they grind each other's QoE down once the fluid-fair interference is
priced.
"""
from __future__ import annotations

from ..core.adapter import DynamicsEvent
from ..core.cost_model import PAPER_SERVE_WORKLOAD, PAPER_TRAIN_WORKLOAD
from ..core.device import make_setting
from ..core.qoe import QoESpec
from ..scenarios import Scenario
from . import FleetScenario, register_fleet


def _home2():
    return make_setting("smart_home_2")


def _traffic():
    return make_setting("traffic_monitor")


def _home1():
    return make_setting("smart_home_1")


# -- smart home: voice assistant + door-camera vision --------------------------
VOICE_ASSISTANT = Scenario(
    name="voice_assistant",
    description="Always-on voice assistant serving household queries.",
    topology=_home2, model="qwen3-0.6b", workload=PAPER_SERVE_WORKLOAD,
    qoe=QoESpec(t_qoe=0.3, lam=100.0), tags=("serve", "tenant"),
    request_rate=2.0)

VISION_MONITOR = Scenario(
    name="vision_monitor",
    description="Door-camera vision encoder flagging motion events.",
    topology=_home2, model="bert", workload=PAPER_SERVE_WORKLOAD,
    qoe=QoESpec(t_qoe=0.05, lam=100.0), tags=("serve", "tenant"),
    request_rate=5.0)

register_fleet(FleetScenario(
    name="smart_home_assist",
    description="Smart Home 2 fleet shared by a voice assistant and a "
                "vision monitor; both gravitate to the same phone when "
                "planned independently.",
    topology=_home2, tenants=(VOICE_ASSISTANT, VISION_MONITOR),
    tags=("fleet", "serve"),
    timeline=(
        ("evening 4K stream saturates WiFi (-40%)",
         DynamicsEvent(t=30.0, bandwidth_scale={"wifi": 0.6})),
        ("stream ends",
         DynamicsEvent(t=90.0, bandwidth_scale={"wifi": 1.0})),
    ),
))


# -- roadside unit: detector + tracker ------------------------------------------
DETECTOR = Scenario(
    name="detector",
    description="Per-frame object detector on the roadside camera feed.",
    topology=_traffic, model="qwen3-0.6b", workload=PAPER_SERVE_WORKLOAD,
    qoe=QoESpec(t_qoe=0.2, lam=100.0), tags=("serve", "tenant"),
    request_rate=3.0)

TRACKER = Scenario(
    name="tracker",
    description="Lightweight track-association model over detections.",
    topology=_traffic, model="bert", workload=PAPER_SERVE_WORKLOAD,
    qoe=QoESpec(t_qoe=0.05, lam=100.0), tags=("serve", "tenant"),
    request_rate=6.0)

register_fleet(FleetScenario(
    name="traffic_intersection",
    description="Traffic-monitor fleet running detector + tracker; "
                "camera churn and a thermal throttle force the "
                "rebalancer to shuffle devices between tenants.",
    topology=_traffic, tenants=(DETECTOR, TRACKER),
    tags=("fleet", "serve"),
    timeline=(
        ("camera 3 powers down for maintenance",
         DynamicsEvent(t=20.0, leave=(3,))),
        ("midday heat throttles camera 0 (-40%)",
         DynamicsEvent(t=35.0, compute_speed={0: 0.6})),
        ("camera 3 back online",
         DynamicsEvent(t=60.0, join=(3,))),
        ("camera 0 cools off",
         DynamicsEvent(t=80.0, compute_speed={0: 1.0})),
    ),
))


# -- smart home at night: fine-tune + assistant ----------------------------------
OVERNIGHT_TUNE = Scenario(
    name="overnight_tune",
    description="Overnight fine-tuning run pacing toward a morning "
                "deadline.",
    topology=_home1, model="qwen3-0.6b", workload=PAPER_TRAIN_WORKLOAD,
    qoe=QoESpec(t_qoe=6.0, lam=50.0, deadline=8 * 3600.0),
    tags=("train", "tenant"), request_rate=0.05)

NIGHT_ASSISTANT = Scenario(
    name="night_assistant",
    description="Low-traffic voice assistant that must stay snappy "
                "while the fleet fine-tunes.",
    topology=_home1, model="qwen3-0.6b", workload=PAPER_SERVE_WORKLOAD,
    qoe=QoESpec(t_qoe=0.08, lam=100.0), tags=("serve", "tenant"),
    request_rate=1.0)

register_fleet(FleetScenario(
    name="smart_home_overnight",
    description="Smart Home 1 fleet fine-tuning overnight while still "
                "serving the assistant: a train + serve tenant mix.",
    topology=_home1, tenants=(OVERNIGHT_TUNE, NIGHT_ASSISTANT),
    tags=("fleet", "mixed"),
    timeline=(
        ("late-night 4K stream (-50% WiFi)",
         DynamicsEvent(t=40.0, bandwidth_scale={"wifi": 0.5})),
        ("stream ends",
         DynamicsEvent(t=120.0, bandwidth_scale={"wifi": 1.0})),
    ),
))


# -- generated mixed fleet --------------------------------------------------------
# One representative of the generator's ``mixed_train_serve`` fleet
# family (repro.scenarios.generate.generate_fleet): a fine-tuning
# tenant co-deployed with an always-on serving tenant on a generated
# shared-medium fleet.  Seed 0 is verified feasible under co-planning.
from ..scenarios.generate import generate_fleet

register_fleet(generate_fleet(0, name="mixed_train_serve"))
