"""``repro.fleet`` — multi-tenant co-planning on one shared device fleet.

The single-tenant stack assumes one workload owns the whole
:class:`~repro.core.device.Topology`.  Real edge deployments run
*several* models at once — a smart home serves a voice assistant while a
vision monitor watches the door; a roadside unit runs a detector and a
tracker.  This package plans N workloads ("tenants") jointly on one
shared fleet under a simple, enforceable contract:

* **Devices are exclusive** — the fleet planner partitions the device
  set among tenants; a tenant's pipeline only ever places layers on its
  own allotment, so compute never time-shares (and the serving
  simulator asserts no device is oversubscribed).
* **Links are shared** — a shared medium (WiFi) carries every tenant's
  transfers; each tenant plans against its fluid-fair share of the
  capacity (``Topology.scale_resources``), the same fluid model the
  Phase-2 scheduler uses for unscheduled contention.

Three layers mirror the single-tenant stack:

* :class:`~repro.fleet.planner.FleetPlanner` — searches device
  assignments (cheap proxy scoring over every feasible partition, full
  per-tenant planning for the best few) for a joint objective: all
  tenants QoE-feasible first, then minimum total energy, then maximum
  latency headroom.
* :class:`~repro.fleet.session.FleetSession` — the armed runtime: it
  routes dynamics events into each tenant's adapter and *rebalances
  devices between tenants* on fleet churn or when a load shift leaves a
  tenant QoE-infeasible (warm-starting every tenant replan from its
  surviving candidate pool, §4.3-style).
* :func:`repro.sim.fleet.simulate_fleet` — concurrent per-tenant
  request streams against the composed plans with per-tenant
  p50/p95/p99, SLO attainment and per-device energy attribution.

Reachable from the facade as ``dora.plan_fleet(...)``,
``dora.serve_fleet(...)`` and ``dora.simulate(..., mode="fleet")``; the
multi-tenant deployments below (:mod:`repro.fleet.catalog`) live in
their own registry, listed via ``python -m repro.scenarios --list
--fleet``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence, \
    Tuple, Union

from ..core.adapter import DynamicsEvent
from ..core.device import Topology
from ..scenarios import Scenario, get_scenario


@dataclasses.dataclass(frozen=True)
class FleetScenario:
    """N tenant workloads co-deployed on one shared fleet.

    ``tenants`` are plain :class:`~repro.scenarios.Scenario` objects —
    their model/workload/QoE/request-rate describe the tenant; their
    ``topology`` is *ignored* in favor of the fleet's shared one (by
    convention the catalog points both at the same builder, so planning
    a tenant standalone reproduces the "independent planning on the
    full fleet" baseline).  ``timeline`` events are in fleet device
    space and hit every tenant they touch.
    """

    name: str
    description: str
    topology: Callable[[], Topology]
    tenants: Tuple[Scenario, ...]
    timeline: Tuple[Tuple[str, DynamicsEvent], ...] = ()
    tags: Tuple[str, ...] = ()

    def __post_init__(self) -> None:
        if not self.tenants:
            raise ValueError("a fleet needs at least one tenant")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"tenant names must be unique, got {names}")

    def build_topology(self) -> Topology:
        return self.topology()

    def tenant(self, name: str) -> Scenario:
        for t in self.tenants:
            if t.name == name:
                return t
        raise KeyError(f"fleet {self.name!r} has no tenant {name!r}; "
                       f"tenants: {[t.name for t in self.tenants]}")

    def summary_row(self) -> Tuple[str, str, str, str]:
        topo = self.build_topology()
        return (self.name, str(len(self.tenants)), str(topo.n),
                self.description)


# -- registry ------------------------------------------------------------------
_FLEETS: Dict[str, FleetScenario] = {}


def register_fleet(fleet: FleetScenario,
                   overwrite: bool = False) -> FleetScenario:
    if fleet.name in _FLEETS and not overwrite:
        raise ValueError(f"fleet scenario {fleet.name!r} already registered")
    _FLEETS[fleet.name] = fleet
    return fleet


def list_fleets(tag: Optional[str] = None) -> List[str]:
    return sorted(n for n, f in _FLEETS.items()
                  if tag is None or tag in f.tags)


def iter_fleets(tag: Optional[str] = None) -> Iterable[FleetScenario]:
    for name in list_fleets(tag):
        yield _FLEETS[name]


FleetRef = Union[str, FleetScenario, Sequence[Union[str, Scenario]]]


def resolve_fleet(ref: FleetRef,
                  topology: Optional[Union[Topology,
                                           Callable[[], Topology]]] = None
                  ) -> FleetScenario:
    """A :class:`FleetScenario` from a registry name, a ready object, or
    an ad-hoc list of tenant scenario refs.  ``topology`` overrides the
    shared fleet in every case (for ad-hoc lists the default is the
    first tenant's); it is never silently dropped."""
    topo_fn: Optional[Callable[[], Topology]] = None
    if topology is not None:
        topo_fn = ((lambda t=topology: t) if isinstance(topology, Topology)
                   else topology)
    if isinstance(ref, (FleetScenario, str)):
        if isinstance(ref, str):
            try:
                ref = _FLEETS[ref]
            except KeyError:
                known = ", ".join(sorted(_FLEETS))
                raise KeyError(f"unknown fleet scenario {ref!r}; "
                               f"known: {known}") from None
        if topo_fn is not None:
            ref = dataclasses.replace(ref, topology=topo_fn)
        return ref
    tenants = tuple(get_scenario(t) for t in ref)
    if not tenants:
        raise ValueError("an ad-hoc fleet needs at least one tenant")
    return FleetScenario(
        name="+".join(t.name for t in tenants),
        description="ad-hoc fleet of "
                    + ", ".join(t.name for t in tenants),
        topology=topo_fn or tenants[0].topology, tenants=tenants)


from .planner import FleetConfig, FleetPlan, FleetPlanner, TenantPlan, \
    plan_independent  # noqa: E402
from .session import FleetSession, TenantAction  # noqa: E402

# Populate the fleet registry with the built-in catalogue on import.
from . import catalog  # noqa: E402,F401  (registration side effects)

__all__ = [
    "FleetScenario", "FleetRef", "register_fleet", "list_fleets",
    "iter_fleets", "resolve_fleet",
    "FleetConfig", "FleetPlan", "FleetPlanner", "TenantPlan",
    "plan_independent", "FleetSession", "TenantAction", "catalog",
]
