"""Joint device-assignment search for multi-tenant fleets.

``FleetPlanner`` partitions one shared fleet's devices among N tenant
workloads (exclusive devices, fluid-fair shared links) and plans each
tenant with any registered :class:`~repro.strategies.PlannerStrategy`
against its allotment.  The search runs in two passes, mirroring the
single-tenant Phase-1/Phase-2 split:

1. **Proxy scoring** — every feasible assignment (each tenant gets at
   least one device, every device is assigned) is scored with a cheap
   contention-free strategy (``chain_split`` by default, ~1 ms per
   allotment, memoized per tenant x allotment).  Fleets too large to
   enumerate fall back to a demand-greedy seed plus single-device-move
   hill climbing under the same proxy.
2. **Refinement** — the best ``refine_k`` assignments are planned for
   real (per-tenant strategy, full Phase-1+2 for ``dora``), again
   memoized, and the joint winner is picked lexicographically:
   fewest QoE violations, then least total violation overshoot, then
   minimum total per-request energy, then maximum latency headroom.

Rebalancing reuses the same search: ``plan(devices=..., warm=...,
conditions=...)`` restricts the partition to the surviving fleet,
warm-starts each dora tenant from its previous candidate pool
(:meth:`DoraPlanner.replan`), and — when accumulated runtime conditions
are supplied — re-prices every scored plan under them so a throttled
device loses assignments it can no longer serve.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from ..core.adapter import AdapterConfig, RuntimeState
from ..core.cost_model import CostProvider, resolve_costs
from ..core.device import Topology
from ..core.partitioner import PartitionerConfig
from ..core.planner import DoraPlanner
from ..core.plans import ParallelismPlan
from ..core.scheduler import NetworkScheduler, SchedulerConfig
from ..dora import PlanReport, _json_num, _plan_dict
from ..scenarios import Scenario, get_scenario
from ..strategies import get_strategy

#: An assignment: tenant index per fleet-device slot (aligned with the
#: ``devices`` list the search runs over).
Assignment = Tuple[int, ...]


@dataclasses.dataclass
class FleetConfig:
    """Knobs of the assignment search."""

    proxy_strategy: str = "chain_split"  # cheap pass-1 scorer
    refine_k: int = 4                    # assignments planned for real
    max_assignments: int = 4096          # enumeration cap -> local search
    search_budget: int = 200             # proxy evals for local search
    objective: str = "energy"            # "energy" | "headroom" first
    rebalance_on_load: bool = True       # FleetSession: rebalance when a
    #                                      load shift breaks a tenant's QoE


@dataclasses.dataclass
class TenantPlan:
    """One tenant's share of a fleet plan."""

    scenario: Scenario
    allotment: Tuple[int, ...]      # fleet device ids, sorted
    mapping: Dict[int, int]         # fleet id -> tenant-local id
    report: PlanReport              # planned on the allotment topology
    exclusive: bool = True          # False for the independent baseline

    @property
    def plan(self) -> ParallelismPlan:
        return self.report.best

    @property
    def feasible(self) -> bool:
        return self.report.meets_qoe

    @property
    def latency(self) -> float:
        return self.report.latency

    @property
    def energy(self) -> float:
        return self.report.energy

    def to_dict(self) -> Dict[str, object]:
        return {
            "tenant": self.scenario.name,
            "model": self.scenario.model_name,
            "mode": self.scenario.mode,
            "allotment": list(self.allotment),
            "exclusive": self.exclusive,
            "strategy": self.report.strategy,
            "latency_s": _json_num(self.latency),
            "energy_j": _json_num(self.energy),
            "meets_qoe": self.feasible,
            "t_qoe_s": _json_num(self.scenario.qoe.t_qoe),
            "best": _plan_dict(self.plan),
        }


@dataclasses.dataclass
class FleetPlan:
    """The joint plan: every tenant's allotment + per-tenant report."""

    name: str
    topology: Topology                       # calibrated shared fleet
    tenants: "OrderedDict[str, TenantPlan]"
    exclusive: bool = True
    planning_s: float = 0.0
    searched: int = 0                        # assignments proxy-scored
    refined: int = 0                         # assignments fully planned

    @property
    def feasible(self) -> bool:
        return all(t.feasible for t in self.tenants.values())

    @property
    def total_energy(self) -> float:
        """Sum of per-request (per-iteration) plan energies."""
        return sum(t.energy for t in self.tenants.values())

    @property
    def headroom(self) -> float:
        """Worst tenant's relative latency slack vs its QoE target."""
        return min((_headroom(t.scenario.qoe.t_qoe, t.latency)
                    for t in self.tenants.values()), default=1.0)

    @property
    def assignments(self) -> Dict[str, Tuple[int, ...]]:
        return {name: t.allotment for name, t in self.tenants.items()}

    def tenant(self, name: str) -> TenantPlan:
        return self.tenants[name]

    def to_dict(self) -> Dict[str, object]:
        return {
            "fleet": self.name,
            "devices": self.topology.n,
            "exclusive": self.exclusive,
            "feasible": self.feasible,
            "total_energy_j": _json_num(self.total_energy),
            "headroom": _json_num(self.headroom),
            "planning_s": _json_num(self.planning_s),
            "assignments_searched": self.searched,
            "assignments_refined": self.refined,
            "tenants": {name: t.to_dict()
                        for name, t in self.tenants.items()},
        }

    def summary(self) -> str:
        word = "co-planned" if self.exclusive else "independent"
        lines = [f"fleet {self.name} ({word}): {len(self.tenants)} tenants "
                 f"on {self.topology.n} devices, "
                 f"{'all QoE-feasible' if self.feasible else 'QoE VIOLATED'}"
                 f", total energy {self.total_energy:.2f} J/req, "
                 f"headroom {self.headroom:+.0%}"]
        for name, t in self.tenants.items():
            lines.append(
                f"  {name:24s} devs={list(t.allotment)!s:14s} "
                f"lat={t.latency * 1e3:8.1f} ms (t_qoe "
                f"{t.scenario.qoe.t_qoe:g}s) E={t.energy:7.2f} J  "
                f"{'OK' if t.feasible else 'MISS'}")
        return "\n".join(lines)


def _headroom(t_qoe: float, latency: float) -> float:
    if not math.isfinite(t_qoe) or t_qoe <= 0.0:
        return 1.0
    return (t_qoe - latency) / t_qoe


@dataclasses.dataclass(frozen=True)
class _Score:
    """One tenant's contribution to the joint objective."""

    feasible: bool
    overshoot: float        # QoE-violation seconds (inf: planning failed)
    energy: float
    headroom: float


class FleetPlanner:
    """Co-plan N tenant workloads on one shared topology."""

    def __init__(self, topology: Topology,
                 tenants: Sequence[Union[str, Scenario]], *,
                 name: str = "fleet",
                 strategy: Union[str, Dict[str, str]] = "dora",
                 config: Optional[FleetConfig] = None,
                 partitioner_config: Optional[PartitionerConfig] = None,
                 scheduler_config: Optional[SchedulerConfig] = None,
                 adapter_config: Optional[AdapterConfig] = None,
                 costs: Optional[CostProvider] = None):
        self.tenants = [get_scenario(t) for t in tenants]
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"tenant names must be unique, got {names}")
        if not self.tenants:
            raise ValueError("fleet planning needs at least one tenant")
        self.name = name
        # calibrate the shared fleet ONCE; tenant subsets inherit the
        # calibrated rates, so tenant planners run with identity costs
        # (re-calibrating a subset would double-apply a measured provider)
        self.topo = resolve_costs(costs).calibrate(topology)
        if len(self.tenants) > self.topo.n:
            raise ValueError(
                f"{len(self.tenants)} tenants cannot each get an exclusive "
                f"device on a {self.topo.n}-device fleet")
        self.config = config or FleetConfig()
        self.strategy = strategy
        self.partitioner_config = partitioner_config
        self.scheduler_config = scheduler_config
        self.adapter_config = adapter_config
        self.graphs = {t.name: t.build_graph() for t in self.tenants}
        # memos keyed by (tenant, allotment, link-share factors, conditions)
        self._proxy_cache: Dict[tuple, Optional[_Score]] = {}
        self._plan_cache: Dict[tuple, Optional[PlanReport]] = {}

    def strategy_for(self, tenant: str) -> str:
        if isinstance(self.strategy, dict):
            return self.strategy.get(tenant, "dora")
        return self.strategy

    # -- tenant topology ----------------------------------------------------------
    def link_shares(self, allotments: Sequence[Tuple[int, ...]]
                    ) -> Dict[str, int]:
        """How many tenants transfer over each shared resource.

        A tenant occupies a shared medium iff at least two of its
        devices are members (single-device tenants never transfer).
        Dedicated pair links are exclusive by construction — both
        endpoints always belong to one tenant's allotment or the link
        dies in the subset.
        """
        users: Dict[str, int] = {}
        for r in self.topo.resources.values():
            if not r.shared:
                continue
            n = sum(1 for a in allotments
                    if len(r.members.intersection(a)) >= 2)
            if n:
                users[r.name] = n
        return users

    def tenant_topology(self, allotment: Tuple[int, ...],
                        shares: Dict[str, int]
                        ) -> Tuple[Topology, Dict[int, int]]:
        """The allotment's topology with shared links priced at their
        fluid-fair cross-tenant share."""
        sub, mapping = self.topo.subset(allotment)
        factors = {name: 1.0 / n for name, n in shares.items()
                   if n > 1 and name in sub.resources}
        if factors:
            sub = sub.scale_resources(factors)
        return sub, mapping

    def _factors_key(self, allotment: Tuple[int, ...],
                     shares: Dict[str, int]) -> tuple:
        return tuple(sorted((name, n) for name, n in shares.items()
                            if n > 1))

    # -- joint objective ---------------------------------------------------------
    def _score_of(self, qoe, plan: ParallelismPlan) -> _Score:
        return _Score(feasible=qoe.satisfied(plan),
                      overshoot=max(0.0, plan.latency - qoe.t_qoe),
                      energy=plan.energy,
                      headroom=_headroom(qoe.t_qoe, plan.latency))

    _FAILED = _Score(feasible=False, overshoot=math.inf, energy=math.inf,
                     headroom=-math.inf)

    def _joint_key(self, scores: Sequence[_Score]) -> tuple:
        violations = sum(1 for s in scores if not s.feasible)
        overshoot = sum(s.overshoot for s in scores)
        energy = sum(s.energy for s in scores)
        headroom = min((s.headroom for s in scores), default=1.0)
        if self.config.objective == "headroom":
            return (violations, overshoot, -headroom, energy)
        return (violations, overshoot, energy, -headroom)

    # -- pass 1: proxy scoring ----------------------------------------------------
    def _proxy(self, tenant: Scenario, allotment: Tuple[int, ...],
               shares: Dict[str, int],
               conditions: Optional[RuntimeState]) -> _Score:
        key = (tenant.name, allotment, self._factors_key(allotment, shares),
               _conditions_key(conditions))
        if key in self._proxy_cache:
            return self._proxy_cache[key] or self._FAILED
        score: Optional[_Score] = None
        try:
            # subset() raises when the allotment disconnects a routed
            # topology (star leaves without their hub, mesh fragments);
            # such allotments are infeasible, not fatal
            sub, mapping = self.tenant_topology(allotment, shares)
            result = get_strategy(self.config.proxy_strategy).plan(
                self.graphs[tenant.name], sub, tenant.qoe, tenant.workload)
            plan = result.best
            if conditions is not None:
                plan = NetworkScheduler(sub, tenant.qoe,
                                        self.scheduler_config).evaluate_fair(
                    plan, **_translate(conditions, mapping, sub))
            score = self._score_of(tenant.qoe, plan)
        except Exception:  # noqa: BLE001 — infeasible allotment, score it so
            score = None
        self._proxy_cache[key] = score
        return score or self._FAILED

    # -- pass 2: full planning -----------------------------------------------------
    def _plan_tenant(self, tenant: Scenario, allotment: Tuple[int, ...],
                     shares: Dict[str, int],
                     warm: Optional[Tuple[Sequence[ParallelismPlan],
                                          Tuple[int, ...]]] = None,
                     memo: Optional[Dict[tuple, Optional[PlanReport]]] = None
                     ) -> Optional[PlanReport]:
        key = (tenant.name, allotment,
               self._factors_key(allotment, shares))
        # warm results depend on the candidate pool of the *current*
        # rebalance, so they dedupe only within this plan() call
        # (``memo``) and never touch the cross-call memo — a stale
        # pool's plan must never be replayed for a later rebalance
        cache = self._plan_cache if warm is None else memo
        if cache is not None and key in cache:
            return cache[key]
        strat_name = self.strategy_for(tenant.name)
        report: Optional[PlanReport] = None
        try:
            # subset() raises on disconnecting allotments — infeasible
            sub, mapping = self.tenant_topology(allotment, shares)
            if strat_name == "dora":
                planner = DoraPlanner(
                    self.graphs[tenant.name], sub, tenant.qoe,
                    partitioner_config=self.partitioner_config,
                    scheduler_config=self.scheduler_config,
                    adapter_config=self.adapter_config)
                if warm is not None:
                    pool, prev_allot = warm
                    trans = {pos: mapping[orig]
                             for pos, orig in enumerate(prev_allot)
                             if orig in mapping}
                    result = planner.replan(tenant.workload, list(pool),
                                            mapping=trans)
                else:
                    result = planner.plan(tenant.workload)
            else:
                result = get_strategy(strat_name).plan(
                    self.graphs[tenant.name], sub, tenant.qoe,
                    tenant.workload)
            report = PlanReport(scenario=tenant, topology=sub,
                                graph=self.graphs[tenant.name],
                                workload=tenant.workload, qoe=tenant.qoe,
                                result=result, strategy=strat_name)
        except Exception:  # noqa: BLE001 — allotment can't host the tenant
            report = None
        if cache is not None:
            cache[key] = report
        return report

    # -- assignment enumeration -----------------------------------------------------
    def _exhaustive(self, n: int, k: int) -> Iterable[Assignment]:
        for combo in itertools.product(range(k), repeat=n):
            if len(set(combo)) == k:
                yield combo

    def _demand(self, tenant: Scenario) -> float:
        flops = self.graphs[tenant.name].total_flops_fwd
        rate = tenant.request_rate or 1.0
        return max(flops, 1.0) * rate

    def _local_search(self, devices: List[int], k: int,
                      score_fn) -> List[Assignment]:
        """Demand-greedy seed + single-device-move hill climbing under
        the proxy score, for fleets too large to enumerate."""
        order = sorted(range(len(devices)),
                       key=lambda i:
                       -self.topo.devices[devices[i]].effective_flops())
        demand = [self._demand(t) for t in self.tenants]
        got = [0.0] * k
        seed = [0] * len(devices)
        for slot in order:
            flops = self.topo.devices[devices[slot]].effective_flops()
            tenant = max(range(k),
                         key=lambda t: demand[t] / (got[t] + flops))
            seed[slot] = tenant
            got[tenant] += flops
        for t in range(k):             # everyone gets at least one device
            if t not in seed:
                seed[order[t % len(order)]] = t
        current = tuple(seed)
        if len(set(current)) != k:     # tiny fleets: round-robin fallback
            current = tuple(i % k for i in range(len(devices)))
        scores: Dict[Assignment, tuple] = {current: score_fn(current)}
        best_key = scores[current]
        improved = True
        while improved and len(scores) < self.config.search_budget:
            improved = False
            for slot in range(len(devices)):
                for t in range(k):
                    cand = list(current)
                    if cand[slot] == t:
                        continue
                    old = cand[slot]
                    cand[slot] = t
                    cand = tuple(cand)
                    if old not in cand or cand in scores:
                        continue       # would empty a tenant / already seen
                    scores[cand] = key = score_fn(cand)
                    if key < best_key:
                        current, best_key, improved = cand, key, True
                    if len(scores) >= self.config.search_budget:
                        break
                if len(scores) >= self.config.search_budget:
                    break
        return sorted(scores, key=scores.__getitem__)

    # -- the search -----------------------------------------------------------------
    def plan(self, devices: Optional[Sequence[int]] = None,
             warm: Optional[Dict[str, Tuple[Sequence[ParallelismPlan],
                                            Tuple[int, ...]]]] = None,
             conditions: Optional[RuntimeState] = None,
             include: Optional[Sequence[Dict[str, Tuple[int, ...]]]] = None
             ) -> FleetPlan:
        """Search device assignments and co-plan every tenant.

        ``devices`` restricts the partition to a surviving sub-fleet
        (fleet ids; default: the whole fleet).  ``warm`` maps tenant
        names to ``(candidate pool, previous allotment)`` pairs for
        §4.3-style warm-started replans.  ``conditions`` re-prices all
        scored plans under accumulated runtime state, so rebalancing
        sees degraded devices as degraded.  ``include`` forces specific
        assignments (e.g. the incumbent) into the fully-planned set.
        """
        t0 = time.perf_counter()
        devs = sorted(set(devices)) if devices is not None \
            else list(range(self.topo.n))
        bad = [d for d in devs if not (0 <= d < self.topo.n)]
        if bad:
            raise ValueError(f"unknown fleet devices {bad} "
                             f"(fleet has {self.topo.n})")
        k = len(self.tenants)
        if k > len(devs):
            raise ValueError(f"{k} tenants need at least {k} devices; "
                             f"only {devs} survive")

        def allotments_of(a: Assignment) -> List[Tuple[int, ...]]:
            return [tuple(d for d, t in zip(devs, a) if t == i)
                    for i in range(k)]

        searched = 0

        def proxy_key(a: Assignment) -> tuple:
            nonlocal searched
            searched += 1
            allots = allotments_of(a)
            shares = self.link_shares(allots)
            return self._joint_key([
                self._proxy(t, allot, shares, conditions)
                for t, allot in zip(self.tenants, allots)])

        if k ** len(devs) <= self.config.max_assignments:
            ranked = sorted(self._exhaustive(len(devs), k), key=proxy_key)
        else:
            ranked = self._local_search(devs, k, proxy_key)
        head = ranked[:max(self.config.refine_k, 1)]
        for forced in (include or ()):
            a = _as_assignment(forced, devs,
                               [t.name for t in self.tenants])
            if a is not None and a not in head:
                head.append(a)

        best_key, best_entry = None, None
        refined = 0
        call_memo: Dict[tuple, Optional[PlanReport]] = {}
        for a in head:
            allots = allotments_of(a)
            shares = self.link_shares(allots)
            entry: "OrderedDict[str, TenantPlan]" = OrderedDict()
            scores: List[_Score] = []
            for tenant, allot in zip(self.tenants, allots):
                report = self._plan_tenant(
                    tenant, allot, shares,
                    warm=(warm or {}).get(tenant.name), memo=call_memo)
                if report is None:
                    scores.append(self._FAILED)
                    continue
                plan = report.best
                if conditions is not None:
                    sub = report.topology
                    mapping = {orig: pos
                               for pos, orig in enumerate(allot)}
                    plan = NetworkScheduler(
                        sub, tenant.qoe, self.scheduler_config).refine(
                        plan, **_translate(conditions, mapping, sub))
                scores.append(self._score_of(tenant.qoe, plan))
                entry[tenant.name] = TenantPlan(
                    scenario=tenant, allotment=allot,
                    mapping={orig: pos for pos, orig in enumerate(allot)},
                    report=report)
            refined += 1
            if len(entry) < k:      # a tenant failed to plan: skip unless
                if best_entry is not None:      # nothing better exists
                    continue
            key = self._joint_key(scores)
            if best_key is None or key < best_key:
                best_key, best_entry = key, entry
        if not best_entry or len(best_entry) < k:
            missing = [t.name for t in self.tenants
                       if t.name not in (best_entry or {})]
            raise RuntimeError(
                f"no assignment of {devs} hosts every tenant "
                f"(QoE-feasibly plannable allotment missing for "
                f"{missing})")
        return FleetPlan(name=self.name, topology=self.topo,
                         tenants=best_entry,
                         planning_s=time.perf_counter() - t0,
                         searched=searched, refined=refined)


def _conditions_key(conditions: Optional[RuntimeState]) -> tuple:
    if conditions is None:
        return ()
    return (tuple(sorted(conditions.compute_speed.items())),
            tuple(sorted(conditions.bandwidth_scale.items())))


def _translate(conditions: RuntimeState, mapping: Dict[int, int],
               sub: Topology) -> Dict[str, Dict]:
    """Fleet-space runtime state -> tenant-local refine() keywords."""
    return {
        "compute_speed": {mapping[d]: v
                          for d, v in conditions.compute_speed.items()
                          if d in mapping},
        "bandwidth_scale": {r: v
                            for r, v in conditions.bandwidth_scale.items()
                            if r in sub.resources},
    }


def _as_assignment(assignment: Dict[str, Tuple[int, ...]],
                   devs: List[int], names: List[str]
                   ) -> Optional[Assignment]:
    """{tenant: allotment} -> tenant-index-per-device tuple, or ``None``
    when it doesn't cover exactly the searched devices."""
    owner: Dict[int, int] = {}
    for i, name in enumerate(names):
        for d in assignment.get(name, ()):
            if d in owner:
                return None
            owner[d] = i
    if sorted(owner) != devs:
        return None
    return tuple(owner[d] for d in devs)


# -- the "no co-planning" baseline ------------------------------------------------
def plan_independent(topology: Topology,
                     tenants: Sequence[Union[str, Scenario]], *,
                     name: str = "fleet",
                     strategy: Union[str, Dict[str, str]] = "dora",
                     partitioner_config: Optional[PartitionerConfig] = None,
                     scheduler_config: Optional[SchedulerConfig] = None,
                     costs: Optional[CostProvider] = None) -> FleetPlan:
    """What happens *without* the fleet layer: every tenant plans alone
    on the full fleet, then they all run at once.

    Each tenant's plan is then re-priced under fluid-fair interference:
    a device placed in ``k`` tenants' plans serves each at ``1/k`` of
    its cycles, and a shared link carrying ``u`` tenants' transfers
    gives each ``1/u`` of its bandwidth — the same fluid model the
    Phase-2 scheduler uses for unscheduled contention (Fig. 2).  The
    result is a :class:`FleetPlan` with ``exclusive=False`` and
    overlapping allotments, directly comparable with
    :meth:`FleetPlanner.plan` — the fig_fleet benchmark's baseline.
    """
    scs = [get_scenario(t) for t in tenants]
    topo = resolve_costs(costs).calibrate(topology)
    t0 = time.perf_counter()
    reports: "OrderedDict[str, PlanReport]" = OrderedDict()
    for sc in scs:
        strat = strategy.get(sc.name, "dora") if isinstance(strategy, dict) \
            else strategy
        graph = sc.build_graph()
        if strat == "dora":
            planner = DoraPlanner(graph, topo, sc.qoe,
                                  partitioner_config=partitioner_config,
                                  scheduler_config=scheduler_config)
            result = planner.plan(sc.workload)
        else:
            result = get_strategy(strat).plan(graph, topo, sc.qoe,
                                              sc.workload)
        reports[sc.name] = PlanReport(scenario=sc, topology=topo,
                                      graph=graph, workload=sc.workload,
                                      qoe=sc.qoe, result=result,
                                      strategy=strat)
    # fluid-fair interference: count tenants per device / shared medium
    dev_users: Dict[int, int] = {}
    for rep in reports.values():
        for d in set(rep.best.devices):
            dev_users[d] = dev_users.get(d, 0) + 1
    link_users: Dict[str, int] = {}
    for r in topo.resources.values():
        if not r.shared:
            continue
        n = sum(1 for rep in reports.values()
                if len(r.members.intersection(rep.best.devices)) >= 2)
        if n:
            link_users[r.name] = n
    tenants_out: "OrderedDict[str, TenantPlan]" = OrderedDict()
    for sc in scs:
        rep = reports[sc.name]
        speed = {d: 1.0 / dev_users[d] for d in set(rep.best.devices)
                 if dev_users[d] > 1}
        bw = {rn: 1.0 / u for rn, u in link_users.items() if u > 1}
        if speed or bw:
            contended = NetworkScheduler(topo, sc.qoe,
                                         scheduler_config).refine(
                rep.best, compute_speed=speed, bandwidth_scale=bw)
            result = dataclasses.replace(rep.result, best=contended,
                                         candidates=[contended],
                                         pareto=[contended])
            rep = dataclasses.replace(rep, result=result)
        tenants_out[sc.name] = TenantPlan(
            scenario=sc, allotment=tuple(sorted(set(rep.best.devices))),
            mapping={d: d for d in range(topo.n)}, report=rep,
            exclusive=False)
    return FleetPlan(name=name, topology=topo, tenants=tenants_out,
                     exclusive=False, planning_s=time.perf_counter() - t0,
                     searched=0, refined=len(tenants_out))
