"""Batched serving driver with Dora-planned placement and a QoE monitor.

Runs prefill + decode over synthetic request batches, reporting
per-token latency against the QoE target; with ``--dynamics`` it injects
a mid-run slowdown and shows the runtime adapter's network-only
rescheduling decision (paper Fig. 16 behavior at example scale).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import dora
from ..configs import get_config, reduced_config
from ..core import DynamicsEvent, QoESpec, Workload
from ..models.registry import planning_graph
from .mesh import make_host_mesh, use_mesh
from .steps import make_prefill_step, make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--t-qoe-ms", type=float, default=200.0)
    ap.add_argument("--dynamics", action="store_true")
    ap.add_argument("--setting", default="smart_home_2")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)

    # --- Dora plans the edge deployment for this model --------------------
    # scenario fleet + this invocation's model/batch/QoE via overrides
    session = dora.serve(
        args.setting, graph=planning_graph(cfg, args.prompt_len),
        qoe=QoESpec(t_qoe=args.t_qoe_ms / 1e3, lam=100.0),
        workload=Workload(global_batch=args.batch, microbatch_size=1,
                          training=False))
    result = session.report.result
    adapter = session.adapter
    print("Dora plan:", result.best.summary())
    print(f"planning took {result.total_s*1e3:.0f}ms "
          f"(phase1 {result.phase1_s*1e3:.0f}ms, phase2 {result.phase2_s*1e3:.0f}ms)")

    # --- local JAX execution of the serving loop ---------------------------
    mesh = make_host_mesh()
    model, prefill_step = make_prefill_step(cfg)
    _, serve_step = make_serve_step(cfg)
    max_len = args.prompt_len + args.gen_len
    with use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(args.batch, max_len)
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                          (args.batch, args.prompt_len)), jnp.int32)
        extras = {}
        if cfg.encdec:
            extras["encoder_frames"] = jnp.zeros(
                (args.batch, cfg.enc_seq, cfg.d_model), jnp.float32)
        if cfg.vision_stub:
            extras["extra_embeddings"] = jnp.zeros(
                (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
        jit_prefill = jax.jit(prefill_step, donate_argnums=(2,))
        jit_decode = jax.jit(serve_step, donate_argnums=(2,))
        t0 = time.time()
        tok, cache = jit_prefill(params, tokens, cache, extras)
        jax.block_until_ready(tok)
        print(f"prefill({args.prompt_len} tokens): {(time.time()-t0)*1e3:.1f}ms")
        lat = []
        offset = cfg.n_patches if cfg.vision_stub else 0
        for i in range(args.gen_len):
            pos = jnp.full((args.batch,), args.prompt_len + offset + i, jnp.int32)
            t1 = time.time()
            tok, cache = jit_decode(params, tok, cache, pos)
            jax.block_until_ready(tok)
            lat.append((time.time() - t1) * 1e3)
            if args.dynamics and i == args.gen_len // 2:
                ev = DynamicsEvent(t=time.time() - t0,
                                   compute_speed={0: 0.6},
                                   bandwidth_scale={"wifi": 0.7})
                plan, action, dt = adapter.on_dynamics(result.best, ev)
                print(f"  [dynamics] adapter action={action} in {dt*1e3:.0f}ms; "
                      f"plan latency {result.best.latency*1e3:.0f} -> "
                      f"{plan.latency*1e3:.0f}ms")
        lat = np.array(lat[1:])
        print(f"decode: p50={np.percentile(lat,50):.1f}ms "
              f"p99={np.percentile(lat,99):.1f}ms "
              f"QoE target={args.t_qoe_ms:.0f}ms "
              f"({'MET' if np.percentile(lat,99) < args.t_qoe_ms else 'MISSED'} locally)")


if __name__ == "__main__":
    main()
