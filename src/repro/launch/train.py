"""End-to-end training driver.

Composes the full substrate: model zoo, AdamW, token pipeline, sharded
async checkpointing with restart, heartbeat-driven elastic handling, and
(optionally) a Dora plan for the edge-simulator path.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_32b \
        --reduced --steps 200 --global-batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..checkpoint import Checkpointer, latest_step
from ..configs import get_config, reduced_config
from ..data import DataConfig, TokenPipeline
from ..optim import adamw_init
from .mesh import make_host_mesh, use_mesh
from .steps import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_host_mesh()
    model, train_step = make_train_step(cfg, peak_lr=args.lr,
                                        warmup=max(args.steps // 20, 5),
                                        total=args.steps, remat="none")
    with use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(args.seed))
        opt = adamw_init(params)
        step0 = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = Checkpointer(args.ckpt_dir)
            last = latest_step(args.ckpt_dir)
            if last is not None:
                tree = ckpt.restore(last, {"params": params, "opt": opt})
                params, opt = tree["params"], tree["opt"]
                step0 = last
                print(f"restored checkpoint step {last}")

        data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=args.seq,
                                        global_batch=args.global_batch,
                                        seed=args.seed), mesh)
        jit_step = jax.jit(train_step, donate_argnums=(0, 1))
        losses = []
        t0 = time.time()
        for step in range(step0, args.steps):
            batch = next(data)
            if cfg.encdec:
                batch["encoder_frames"] = jax.numpy.zeros(
                    (args.global_batch, cfg.enc_seq, cfg.d_model), jax.numpy.float32)
            if cfg.vision_stub:
                batch["extra_embeddings"] = jax.numpy.zeros(
                    (args.global_batch, cfg.n_patches, cfg.d_model), jax.numpy.float32)
            params, opt, metrics = jit_step(params, opt, batch,
                                            jax.numpy.asarray(step))
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt})
        if ckpt:
            ckpt.save(args.steps, {"params": params, "opt": opt}, wait=True)
        data.close()
        first = np.mean(losses[:10])
        final = np.mean(losses[-10:])
        print(f"loss {first:.4f} -> {final:.4f} "
              f"({'improved' if final < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
