import os
# This MUST run before any other import (jax locks the device count on
# first initialization).  Append to XLA_FLAGS rather than overwrite so a
# user-set flag string survives; an explicit device-count choice wins.
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512").strip()

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Dict, Optional, Tuple

import jax

from ..configs import ARCH_IDS, ShapeSpec, applicable_shapes, get_config
from ..models.config import ArchConfig
from .mesh import make_production_mesh, use_mesh
from .steps import (batch_structs, make_prefill_step, make_serve_step,
                    make_train_step, param_structs, serve_structs, step_struct)

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link
N_LINKS = 4                  # usable links per chip

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_COLL_LINE = re.compile(
    r"=\s+(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
    r"\[([0-9,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return float(n * _DTYPE_BYTES[dtype])


def _group_size(line: str, default: int = 16) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device ICI traffic (bytes) per collective opcode, estimated
    from *result* shapes with ring-algorithm multipliers:

      all-gather        (g-1)/g × result        (result = gathered)
      reduce-scatter    (g-1)   × result        (input  = g × result)
      all-reduce        2(g-1)/g × result
      all-to-all        (g-1)/g × result
      collective-permute 1 × result
    """
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_LINE.search(line)
        if not m:
            continue
        dtype, dims, op = m.group(1), m.group(2), m.group(3).lower()
        nbytes = _shape_bytes(dtype, dims)
        g = _group_size(line)
        mult = {"all-gather": (g - 1) / g,
                "reduce-scatter": float(g - 1),
                "all-reduce": 2.0 * (g - 1) / g,
                "all-to-all": (g - 1) / g,
                "collective-permute": 1.0}[op]
        out[op] = out.get(op, 0.0) + nbytes * mult
    return out


def roofline(per_dev_flops: float, per_dev_bytes: float,
             coll: Dict[str, float]) -> Dict[str, float]:
    coll_total = sum(coll.values())
    t_compute = per_dev_flops / PEAK_FLOPS
    t_memory = per_dev_bytes / HBM_BW
    t_coll = coll_total / (N_LINKS * LINK_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bound = max(terms, key=terms.get)
    return {"t_compute": t_compute, "t_memory": t_memory,
            "t_collective": t_coll, "bound": bound,
            "collective_bytes": coll_total}


# ------------------------------------------------------------------------------
# depth extrapolation: XLA cost_analysis counts a scan body ONCE, so we
# lower shallow variants with k and k+1 scan units and reconstruct
# full-depth cost as cost(k) + unit × (F − k).
# ------------------------------------------------------------------------------
def _unit_len(cfg: ArchConfig) -> int:
    if cfg.block_pattern:
        return len(cfg.block_pattern)
    return 1


def _n_units(cfg: ArchConfig) -> int:
    if cfg.block_pattern:
        return cfg.n_layers // len(cfg.block_pattern)
    if cfg.n_experts and cfg.n_dense_layers:
        return cfg.n_layers - cfg.n_dense_layers
    return cfg.n_layers


def _shallow_cfg(cfg: ArchConfig, k: int) -> ArchConfig:
    u = _unit_len(cfg)
    if cfg.block_pattern:
        tail = cfg.n_layers - _n_units(cfg) * u
        n = k * u + tail
    elif cfg.n_experts and cfg.n_dense_layers:
        n = cfg.n_dense_layers + k
    else:
        n = k
    kw = {"n_layers": n, "scan_unroll": True}
    if cfg.encdec:
        kw["n_enc_layers"] = k
    return dataclasses.replace(cfg, **kw)


def _lower_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, remat: str):
    # donation mirrors production: params/opt update in place (train),
    # caches update in place (serving)
    if shape.mode == "train":
        _, train_step = make_train_step(cfg, remat=remat)
        params, opt = param_structs(cfg, mesh)
        batch = batch_structs(cfg, shape, mesh)
        return jax.jit(train_step, donate_argnums=(0, 1)).lower(
            params, opt, batch, step_struct(mesh))
    if shape.mode == "prefill":
        _, prefill_step = make_prefill_step(cfg)
        params, _ = param_structs(cfg, mesh)
        sv = serve_structs(cfg, shape, mesh)
        return jax.jit(prefill_step, donate_argnums=(2,)).lower(
            params, sv["tokens"], sv["cache"], sv["extras"])
    _, serve_step = make_serve_step(cfg)
    params, _ = param_structs(cfg, mesh)
    sv = serve_structs(cfg, shape, mesh)
    return jax.jit(serve_step, donate_argnums=(2,)).lower(
        params, sv["token"], sv["cache"], sv["pos"])


def _cost_terms(compiled) -> Tuple[float, float, Dict[str, float]]:
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)), coll


def run_cell(arch: str, shape: ShapeSpec, multi_pod: bool,
             remat: str = "full", extra: Optional[dict] = None) -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: dict = {"arch": arch, "shape": shape.name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "mode": shape.mode, "devices": int(mesh.devices.size)}
    t0 = time.time()
    with use_mesh(mesh):
        # 1) full-depth lower + compile — THE dry-run proof + memory truth
        lowered = _lower_cell(cfg, shape, mesh, remat)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_gb": ma.argument_size_in_bytes / 1e9,
            "output_gb": ma.output_size_in_bytes / 1e9,
            "temp_gb": ma.temp_size_in_bytes / 1e9,
            "alias_gb": ma.alias_size_in_bytes / 1e9,
            "peak_gb": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                        + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 1e9,
        }
        f_full, b_full, c_full = _cost_terms(compiled)

        # 2) depth extrapolation for scan-body costs (shallow variants run
        # UNROLLED so every layer is counted exactly; per-unit cost is the
        # k=3 minus k=2 difference, immune to loop-structure quirks)
        F = _n_units(cfg)
        k1, k2 = (2, 3) if F >= 3 else (F, F)
        if k2 > k1:
            c1 = _lower_cell(_shallow_cfg(cfg, k1), shape, mesh, remat).compile()
            c2 = _lower_cell(_shallow_cfg(cfg, k2), shape, mesh, remat).compile()
            f1, b1, co1 = _cost_terms(c1)
            f2, b2, co2 = _cost_terms(c2)
            uf, ub = max(f2 - f1, 0.0), max(b2 - b1, 0.0)
            flops = f1 + uf * (F - k1)
            hbytes = b1 + ub * (F - k1)
            coll = {}
            for op in set(co1) | set(co2):
                u = max(co2.get(op, 0.0) - co1.get(op, 0.0), 0.0)
                coll[op] = co1.get(op, 0.0) + u * (F - k1)
            rec["extrapolated"] = True
            rec["scan_body_flops_once"] = f_full
        else:
            flops, hbytes, coll = f_full, b_full, c_full
            rec["extrapolated"] = False
    rec["per_device_flops"] = flops
    rec["per_device_bytes"] = hbytes
    rec["collectives"] = {k: round(v, 1) for k, v in coll.items()}
    rec["roofline"] = roofline(flops, hbytes, coll)
    if extra:
        rec.update(extra)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--remat", default="full")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = 0
    for arch in archs:
        for shape in applicable_shapes(arch):
            if args.shape and shape.name != args.shape:
                continue
            for mp in meshes:
                tag = f"{arch} × {shape.name} × {'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_cell(arch, shape, mp, remat=args.remat)
                    r = rec["roofline"]
                    print(f"[OK] {tag}: compile={rec['compile_s']}s "
                          f"peak={rec['memory']['peak_gb']:.2f}GB "
                          f"Tc={r['t_compute']*1e3:.2f}ms Tm={r['t_memory']*1e3:.2f}ms "
                          f"Tn={r['t_collective']*1e3:.2f}ms bound={r['bound']}",
                          flush=True)
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    rec = {"arch": arch, "shape": shape.name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": f"{type(e).__name__}: {e}"}
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")
    print("ALL DRY-RUN CELLS PASSED")


if __name__ == "__main__":
    main()
