"""Production meshes + jax-version compatibility shims.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).

The mesh APIs moved between jax 0.4.x and ≥0.5 (``axis_types`` kwarg,
``jax.set_mesh``); ``compat_make_mesh`` / ``use_mesh`` paper over the
difference so the same launch code runs on both.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes, **kw):
    """``jax.make_mesh`` with ``axis_types`` only where supported."""
    try:
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes), **kw)
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes, **kw)


def use_mesh(mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh`` on jax ≥0.6,
    ``jax.sharding.use_mesh`` on 0.5.x, the Mesh context itself on 0.4.x."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a 1×N ('data','model') mesh —
    used by CPU smoke tests and the examples."""
    n = len(jax.devices())
    return compat_make_mesh((1, n), ("data", "model"))
