"""Production meshes.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist locally, as a 1×N ('data','model') mesh —
    used by CPU smoke tests and the examples."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
