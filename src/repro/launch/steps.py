"""Step builders + ShapeDtypeStruct input specs for every (arch × shape).

``input_specs(cfg, shape, mesh)`` returns weak-type-correct, shardable
stand-ins (no device allocation) for:

* ``train``   — (params, opt_state, batch, step)
* ``prefill`` — (params, tokens, cache [, frontend stubs])
* ``decode``  — (params, token, cache, pos)

The modality frontends are stubs per the assignment: whisper receives
precomputed frame embeddings, paligemma precomputed patch embeddings.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ShapeSpec
from ..models import build_model
from ..models.common import dtype_of
from ..models.config import ArchConfig
from ..models.sharding import ShardingRules
from ..optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine


# ==================================================================================
# steps
# ==================================================================================
def make_train_step(cfg: ArchConfig, *, peak_lr: float = 3e-4,
                    warmup: int = 100, total: int = 10000,
                    remat: str = "full", opt: AdamWConfig = AdamWConfig()):
    model = build_model(cfg)

    def train_step(params, opt_state, batch, step):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch, remat=remat)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        lr = warmup_cosine(step, peak_lr=peak_lr, warmup=warmup, total=total)
        params, opt_state, om = adamw_update(grads, opt_state, params, lr, opt)
        out = {"loss": loss, "lr": lr, **metrics, **om}
        return params, opt_state, out

    return model, train_step


def make_prefill_step(cfg: ArchConfig):
    model = build_model(cfg)

    def prefill_step(params, tokens, cache, extras):
        kw = {k: v for k, v in extras.items()} if extras else {}
        if cfg.encdec:
            logits, cache = model.prefill(params, tokens, cache,
                                          encoder_frames=kw["encoder_frames"])
        elif cfg.vision_stub:
            logits, cache = model.prefill(params, tokens, cache,
                                          extra_embeddings=kw["extra_embeddings"])
        else:
            logits, cache = model.prefill(params, tokens, cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return model, prefill_step


def make_serve_step(cfg: ArchConfig):
    model = build_model(cfg)

    def serve_step(params, token, cache, pos):
        logits, cache = model.decode(params, token, cache, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return model, serve_step


# ==================================================================================
# ShapeDtypeStruct specs
# ==================================================================================
def _sds(tree_shape, spec_tree, mesh):
    def fn(leaf, spec):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(fn, tree_shape, spec_tree)


def param_structs(cfg: ArchConfig, mesh) -> Tuple[Any, Any]:
    """(params, opt_state) ShapeDtypeStructs with production shardings."""
    model = build_model(cfg)
    rules = ShardingRules(cfg, mesh)
    p_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_spec = rules.param_specs(p_shape)
    o_shape = jax.eval_shape(adamw_init, p_shape)
    o_spec = {"m": p_spec, "v": p_spec,
              "count": P()}
    params = _sds(p_shape, p_spec, mesh)
    opt = {"m": _sds(o_shape["m"], p_spec, mesh),
           "v": _sds(o_shape["v"], p_spec, mesh),
           "count": jax.ShapeDtypeStruct((), jnp.int32,
                                         sharding=NamedSharding(mesh, P()))}
    return params, opt


def batch_structs(cfg: ArchConfig, shape: ShapeSpec, mesh) -> Dict[str, Any]:
    """Training batch stand-ins."""
    rules = ShardingRules(cfg, mesh)
    B, S = shape.global_batch, shape.seq_len
    dt = dtype_of(cfg.dtype)
    tree = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.encdec:
        tree["encoder_frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), dt)
    if cfg.vision_stub:
        tree["extra_embeddings"] = jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), dt)
    spec = rules.batch_specs(tree, B)
    return _sds(tree, spec, mesh)


def serve_structs(cfg: ArchConfig, shape: ShapeSpec, mesh) -> Dict[str, Any]:
    """Serving stand-ins: token/tokens, cache, pos, frontend stubs."""
    model = build_model(cfg)
    rules = ShardingRules(cfg, mesh)
    B, S = shape.global_batch, shape.seq_len
    dt = dtype_of(cfg.dtype)
    cache_shape = jax.eval_shape(lambda: model.init_cache(B, S))
    cache_spec = rules.cache_specs(cache_shape, B)
    cache = _sds(cache_shape, cache_spec, mesh)
    plain: Dict[str, Any] = {}
    if shape.mode == "prefill":
        plain["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        extras = {}
        if cfg.encdec:
            extras["encoder_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), dt)
        if cfg.vision_stub:
            extras["extra_embeddings"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), dt)
        plain["extras"] = extras
    else:
        plain["token"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        plain["pos"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    spec = rules.batch_specs(plain, B)
    out = _sds(plain, spec, mesh)
    out["cache"] = cache
    return out


def step_struct(mesh):
    return jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
