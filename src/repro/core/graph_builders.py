"""Planning-graph builders: model architecture → ``ModelGraph``.

Generic transformer-family builder parameterized by the same
``ArchConfig`` the JAX model zoo consumes, plus builders for the paper's
own evaluation models (BERT-0.1B, Qwen3-0.6B/1.7B, Qwen-Omni-6B). The
multimodal builders produce *non-chain* DAGs (modality encoders feeding
a shared backbone), which is precisely what motivates the paper's
graph-based formulation (§4.1).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from .planning_graph import LayerNode, ModelGraph

BYTES = 2.0   # bf16


@dataclasses.dataclass(frozen=True)
class GraphSpec:
    """Minimal architecture description for planning purposes."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    gated_mlp: bool = True
    seq_len: int = 512
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    attn_free: bool = False
    # enc-dec / multimodal branches: list of (branch_name, n_layers, d_model_branch, merge_proj)
    branches: Tuple[Tuple[str, int, int], ...] = ()

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))


def _attn_flops(s: GraphSpec, seq: int) -> float:
    """Per-sample forward FLOPs of one attention block."""
    d, hd = s.d_model, s.hd
    q = 2 * seq * d * s.n_heads * hd
    kv = 2 * 2 * seq * d * s.n_kv_heads * hd
    o = 2 * seq * s.n_heads * hd * d
    core = 2 * 2 * seq * seq * s.n_heads * hd      # QK^T + PV
    return q + kv + o + core


def _attn_params(s: GraphSpec) -> float:
    d, hd = s.d_model, s.hd
    return BYTES * (d * s.n_heads * hd * 2 + d * s.n_kv_heads * hd * 2)


def _mlp_flops(s: GraphSpec, seq: int) -> float:
    mats = 3 if s.gated_mlp else 2
    return 2.0 * seq * mats * s.d_model * s.d_ff


def _mlp_params(s: GraphSpec, d_ff: Optional[int] = None) -> float:
    mats = 3 if s.gated_mlp else 2
    return BYTES * mats * s.d_model * (d_ff or s.d_ff)


def _ssm_flops(s: GraphSpec, seq: int) -> float:
    """Mamba2-style SSD block: projections + state update."""
    d_in = 2 * s.d_model
    proj = 2 * seq * s.d_model * (2 * d_in + 2 * s.ssm_state) + 2 * seq * d_in * s.d_model
    scan = 6 * seq * d_in * s.ssm_state
    return proj + scan


def _ssm_params(s: GraphSpec) -> float:
    d_in = 2 * s.d_model
    return BYTES * (s.d_model * (2 * d_in + 2 * s.ssm_state) + d_in * s.d_model)


def build_lm_graph(spec: GraphSpec, seq_len: Optional[int] = None) -> ModelGraph:
    """Decoder-only LM (or SSM / MoE / hybrid) planning graph as a chain:
    embed → L × block → head, one node per block pre-Δ-merge."""
    seq = seq_len or spec.seq_len
    act = BYTES * seq * spec.d_model
    nodes: List[LayerNode] = [LayerNode(
        name="embed", flops_fwd=0.0, param_bytes=BYTES * spec.vocab * spec.d_model,
        act_bytes=act)]
    for i in range(spec.n_layers):
        if spec.attn_free and spec.ssm_state:
            fl = _ssm_flops(spec, seq)
            pb = _ssm_params(spec)
            state = BYTES * 2 * spec.d_model * spec.ssm_state
        else:
            fl = _attn_flops(spec, seq)
            pb = _attn_params(spec)
            state = BYTES * 2 * seq * spec.n_kv_heads * spec.hd
            if spec.n_experts:
                # active compute: top-k experts per token; params: all experts
                fl += _mlp_flops(spec, seq) * spec.experts_per_token
                fl += 2.0 * seq * spec.d_model * spec.n_experts      # router
                pb += _mlp_params(spec) * spec.n_experts
            else:
                fl += _mlp_flops(spec, seq)
                pb += _mlp_params(spec)
        nodes.append(LayerNode(name=f"block{i}", flops_fwd=fl, param_bytes=pb,
                               act_bytes=act, state_bytes=state))
    nodes.append(LayerNode(
        name="head", flops_fwd=2.0 * seq * spec.d_model * spec.vocab,
        param_bytes=BYTES * spec.vocab * spec.d_model,
        act_bytes=BYTES * seq * spec.vocab))
    return ModelGraph.chain(nodes)


def build_multimodal_graph(spec: GraphSpec, seq_len: Optional[int] = None) -> ModelGraph:
    """Branches (modality encoders) merging into the LM backbone — a
    non-chain DAG (paper Fig. 5 / §4.1 second observation)."""
    backbone = build_lm_graph(spec, seq_len)
    nodes = list(backbone.nodes)
    edges = list(backbone.edges)
    merge_target = 1  # first backbone block consumes encoder outputs
    for bname, blayers, bdim in spec.branches:
        enc_spec = GraphSpec(name=bname, n_layers=blayers, d_model=bdim,
                             n_heads=max(bdim // 64, 1), n_kv_heads=max(bdim // 64, 1),
                             d_ff=4 * bdim, vocab=0, gated_mlp=False,
                             seq_len=spec.seq_len)
        seq_b = enc_spec.seq_len
        prev = None
        for i in range(blayers):
            idx = len(nodes)
            fl = _attn_flops(enc_spec, seq_b) + _mlp_flops(enc_spec, seq_b)
            nodes.append(LayerNode(name=f"{bname}{i}", flops_fwd=fl,
                                   param_bytes=_attn_params(enc_spec) + _mlp_params(enc_spec),
                                   act_bytes=BYTES * seq_b * bdim))
            if prev is not None:
                edges.append((prev, idx))
            prev = idx
        # projector into the backbone
        idx = len(nodes)
        nodes.append(LayerNode(name=f"{bname}_proj",
                               flops_fwd=2.0 * seq_b * bdim * spec.d_model,
                               param_bytes=BYTES * bdim * spec.d_model,
                               act_bytes=BYTES * seq_b * spec.d_model))
        edges.append((prev, idx))
        edges.append((idx, merge_target))
    return ModelGraph(nodes, edges)


# -- the paper's evaluation models (Table 1) -----------------------------------
def paper_model(name: str, seq_len: int = 512) -> ModelGraph:
    if name == "bert":
        return build_lm_graph(GraphSpec("bert", 12, 768, 12, 12, 3072, 30522,
                                        gated_mlp=False, seq_len=seq_len))
    if name == "qwen3-0.6b":
        return build_lm_graph(GraphSpec("qwen3-0.6b", 28, 1024, 16, 8, 3072,
                                        151936, head_dim=128, seq_len=seq_len))
    if name == "qwen3-1.7b":
        return build_lm_graph(GraphSpec("qwen3-1.7b", 28, 2048, 16, 8, 6144,
                                        151936, head_dim=128, seq_len=seq_len))
    if name == "qwen-omni":
        spec = GraphSpec("qwen-omni", 28, 2048, 16, 8, 6144, 151936,
                         head_dim=128, seq_len=seq_len,
                         branches=(("vision", 12, 1280), ("audio", 12, 1280)))
        return build_multimodal_graph(spec, seq_len)
    raise KeyError(name)
