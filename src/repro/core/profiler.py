"""Pipeline-latency estimators.

``gpipe_latency``/``one_f_one_b_latency`` compute the *exact* critical
path of the respective microbatch schedules by dynamic programming over
(stage, microbatch) cells; the paper's Appendix Algorithm 2
(StartPhaseTimeEst / EndPhaseTimeEst) is implemented literally in
``alg2_start_phase`` / ``alg2_end_phase`` and validated against the
exact evaluators in tests.

:class:`ProfiledCosts` is the measured counterpart to
``core.cost_model.AnalyticCosts``: both implement the ``CostProvider``
protocol, so any planner strategy can be fed kernel-/step-measured
rates instead of datasheet rooflines (``dora.plan(..., costs=...)``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Mapping, Optional, Sequence, Tuple


def gpipe_latency(bf: Sequence[float], bb: Sequence[float], n_micro: int,
                  comm_f: Sequence[float] = (), comm_b: Sequence[float] = ()) -> float:
    """Exact GPipe (all-forward, then all-backward) iteration latency.

    ``bf[s]``/``bb[s]`` — per-microbatch forward/backward compute time of
    stage ``s``; ``comm_f[s]`` — activation transfer time from stage s to
    s+1 (len S-1); ``comm_b[s]`` — gradient transfer time s+1 -> s.
    """
    S = len(bf)
    if S == 0 or n_micro == 0:
        return 0.0
    cf = list(comm_f) if comm_f else [0.0] * (S - 1)
    cb = list(comm_b) if comm_b else [0.0] * (S - 1)
    # forward wave
    f = [[0.0] * n_micro for _ in range(S)]
    for m in range(n_micro):
        for s in range(S):
            ready = 0.0
            if s > 0:
                ready = f[s - 1][m] + cf[s - 1]
            if m > 0:
                ready = max(ready, f[s][m - 1])
            f[s][m] = ready + bf[s]
    # backward wave (reverse stage order), starts after last fwd on last stage
    b = [[0.0] * n_micro for _ in range(S)]
    for m in range(n_micro):
        for s in range(S - 1, -1, -1):
            if s == S - 1:
                ready = f[s][n_micro - 1] if m == 0 else b[s][m - 1]
                ready = max(ready, f[s][m])
            else:
                ready = b[s + 1][m] + cb[s]
                if m > 0:
                    ready = max(ready, b[s][m - 1])
                ready = max(ready, f[s][m])
            b[s][m] = ready + bb[s]
    return b[0][n_micro - 1]


def one_f_one_b_latency(bf: Sequence[float], bb: Sequence[float], n_micro: int,
                        comm_f: Sequence[float] = (), comm_b: Sequence[float] = ()) -> float:
    """Exact 1F1B (PipeDream-flush) iteration latency via event DP.

    Each stage s runs ``min(S - s, n_micro)`` warm-up forwards then
    alternates 1F1B; we simulate per-stage instruction streams exactly.
    """
    S = len(bf)
    if S == 0 or n_micro == 0:
        return 0.0
    cf = list(comm_f) if comm_f else [0.0] * (S - 1)
    cb = list(comm_b) if comm_b else [0.0] * (S - 1)

    # instruction streams
    streams: List[List[tuple]] = []
    for s in range(S):
        warm = min(S - s, n_micro)
        ops: List[tuple] = [("F", m) for m in range(warm)]
        fm, bm = warm, 0
        while bm < n_micro:
            ops.append(("B", bm)); bm += 1
            if fm < n_micro:
                ops.append(("F", fm)); fm += 1
        streams.append(ops)

    f_done = [[None] * n_micro for _ in range(S)]
    b_done = [[None] * n_micro for _ in range(S)]
    dev_free = [0.0] * S
    ptr = [0] * S
    remaining = sum(len(x) for x in streams)
    while remaining:
        progressed = False
        for s in range(S):
            if ptr[s] >= len(streams[s]):
                continue
            kind, m = streams[s][ptr[s]]
            if kind == "F":
                if s > 0 and f_done[s - 1][m] is None:
                    continue
                dep_t = 0.0 if s == 0 else (f_done[s - 1][m] + cf[s - 1])
                start = max(dev_free[s], dep_t)
                f_done[s][m] = start + bf[s]
                dev_free[s] = f_done[s][m]
            else:
                if f_done[s][m] is None:
                    continue
                if s < S - 1 and b_done[s + 1][m] is None:
                    continue
                dep_t = f_done[s][m] if s == S - 1 else b_done[s + 1][m] + cb[s]
                start = max(dev_free[s], dep_t, f_done[s][m])
                b_done[s][m] = start + bb[s]
                dev_free[s] = b_done[s][m]
            ptr[s] += 1
            remaining -= 1
            progressed = True
        if not progressed:
            raise RuntimeError("1F1B schedule deadlocked (bug)")
    return max(dev_free)


# ---------------------------------------------------------------------------
# Profiled cost provider (CostProvider protocol, measured fidelity)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ProfiledCosts:
    """Cost provider recalibrated by measurements.

    ``compute_factor[device_name]`` scales that device's achievable
    compute rate (measured/analytic throughput ratio — e.g. from a
    kernel benchmark or a timed training step); ``bandwidth_factor``
    does the same per link-resource name (measured goodput / datasheet
    capacity).  Unlisted devices/links fall back to the ``default_*``
    factor, so a single global MFU correction is one constructor call.
    """

    compute_factor: Mapping[str, float] = dataclasses.field(default_factory=dict)
    bandwidth_factor: Mapping[str, float] = dataclasses.field(default_factory=dict)
    default_compute: float = 1.0
    default_bandwidth: float = 1.0
    name: str = "profiled"
    #: Where the factors came from (backend, jax version, measurement
    #: date, bench shapes, ...) — free-form strings, persisted by
    #: ``to_json`` so committed calibration artifacts stay diffable.
    provenance: Mapping[str, str] = dataclasses.field(default_factory=dict)

    def calibrate(self, topo):
        from .device import Topology
        devs = [dataclasses.replace(
                    d, compute_efficiency=d.compute_efficiency
                    * self.compute_factor.get(d.name, self.default_compute))
                for d in topo.devices]
        res = [dataclasses.replace(
                   r, capacity=r.capacity
                   * self.bandwidth_factor.get(r.name, self.default_bandwidth))
               for r in topo.resources.values()]
        return Topology(devs, res, topo._p2p)

    def cost_model(self, graph, topo, workload):
        from .cost_model import CostModel
        return CostModel(graph, self.calibrate(topo), workload)

    @classmethod
    def from_measurements(
            cls,
            device_seconds: Mapping[str, Tuple[float, float]] = (),
            link_bytes_per_s: Mapping[str, Tuple[float, float]] = (),
            ) -> "ProfiledCosts":
        """Build factors from ``(analytic, measured)`` pairs.

        ``device_seconds`` maps a device name to (analytic step seconds,
        measured step seconds): a device measured 2x slower than the
        roofline gets factor 0.5.  ``link_bytes_per_s`` maps a link name
        to (datasheet capacity, measured goodput).
        """
        comp = {k: a / m for k, (a, m) in dict(device_seconds).items()
                if a > 0.0 and m > 0.0}
        bw = {k: m / a for k, (a, m) in dict(link_bytes_per_s).items()
              if a > 0.0 and m > 0.0}
        return cls(compute_factor=comp, bandwidth_factor=bw)

    # -- persistence (committed calibration artifacts) ----------------------
    def to_dict(self) -> dict:
        return {
            "schema": "dora-profiled-costs/v1",
            "name": self.name,
            "compute_factor": dict(self.compute_factor),
            "bandwidth_factor": dict(self.bandwidth_factor),
            "default_compute": self.default_compute,
            "default_bandwidth": self.default_bandwidth,
            "provenance": dict(self.provenance),
        }

    def to_json(self, path: Optional[str] = None, indent: int = 1) -> str:
        """Strict-JSON serialization (optionally written to ``path``):
        factors + provenance, round-tripped exactly by :meth:`from_json`
        so calibration artifacts can be committed and diffed."""
        import json
        text = json.dumps(self.to_dict(), indent=indent, sort_keys=True,
                          allow_nan=False)
        if path is not None:
            with open(path, "w", encoding="utf-8") as f:
                f.write(text + "\n")
        return text

    @classmethod
    def from_dict(cls, doc: Mapping) -> "ProfiledCosts":
        schema = doc.get("schema", "dora-profiled-costs/v1")
        if not str(schema).startswith("dora-profiled-costs/"):
            raise ValueError(f"not a ProfiledCosts artifact: {schema!r}")
        return cls(
            compute_factor={str(k): float(v) for k, v
                            in doc.get("compute_factor", {}).items()},
            bandwidth_factor={str(k): float(v) for k, v
                              in doc.get("bandwidth_factor", {}).items()},
            default_compute=float(doc.get("default_compute", 1.0)),
            default_bandwidth=float(doc.get("default_bandwidth", 1.0)),
            name=str(doc.get("name", "profiled")),
            provenance={str(k): str(v) for k, v
                        in doc.get("provenance", {}).items()})

    @classmethod
    def from_json(cls, path_or_text: str) -> "ProfiledCosts":
        """Load from a JSON file path (or a raw JSON string)."""
        import json
        import os
        if os.path.exists(path_or_text):
            with open(path_or_text, encoding="utf-8") as f:
                doc = json.load(f)
        else:
            doc = json.loads(path_or_text)
        return cls.from_dict(doc)


# ---------------------------------------------------------------------------
# Paper Appendix Algorithm 2 — literal transcription.
# ``bf``/``bb`` are per-step forward/backward busy times; ``d`` is the
# stage depth the estimate is computed for.
# ---------------------------------------------------------------------------
def alg2_start_phase(bf: Sequence[float], bb: Sequence[float], d: int) -> float:
    """StartPhaseTimeEst(P, BList, d) — Algorithm 2 lines 1-13."""
    S = 2 * len(bf) - 1
    criti = 0.0
    for p in range(d, S + 1):
        cur = 0.0
        for i in range(0, min(p, len(bf) - 1) + 1):
            cur += bf[i]
        cur += (S - p) * max(bf[i] for i in range(0, min(p, len(bf) - 1) + 1))
        for i in range(min(p, len(bb) - 1), d, -1):
            cur += bb[i]
        criti = max(criti, cur)
    return criti


def alg2_end_phase(bf: Sequence[float], bb: Sequence[float], d: int) -> List[float]:
    """EndPhaseTimeEst(P, BList, d) — Algorithm 2 lines 15-30."""
    S = 2 * len(bf) - 1
    out: List[float] = []
    for s in range(0, S):
        criti = 0.0
        for p in range(max(s, d), S + 1):
            cur = 0.0
            for i in range(0, min(p, len(bb) - 1) + 1):
                cur += bb[i]
            cur += (S - p) * max(bb[i] for i in range(0, min(p, len(bb) - 1) + 1))
            for i in range(min(p, len(bf) - 1), d, -1):
                cur += bf[i]
            criti = max(criti, cur)
        out.append(criti)
    return out
