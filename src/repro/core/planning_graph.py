"""Planning-graph abstraction (§4.1).

The target model is a DAG ``G_M = (V_M, E_M)`` whose nodes are one or
more layers, annotated with per-sample compute/communication costs.
Adjacent nodes whose combined parameter share is below ``delta`` are
merged (lightweight compression), and the DAG is serial-decomposed into
an ordered list of *chains* that the partitioner's DP consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class LayerNode:
    """One (merged) model layer.

    Costs are *per sample* at the workload's sequence length so that a
    stage processing a microbatch of ``b`` samples costs ``b ×`` these.
    """

    name: str
    flops_fwd: float            # forward FLOPs per sample
    param_bytes: float          # parameter bytes (model state on the stage)
    act_bytes: float            # output-activation bytes per sample
    flops_bwd: Optional[float] = None   # defaults to 2 × fwd (dL/dx + dL/dw)
    state_bytes: float = 0.0    # recurrent/KV state bytes per sample (serving)

    def __post_init__(self) -> None:
        if self.flops_bwd is None:
            self.flops_bwd = 2.0 * self.flops_fwd

    def merged_with(self, other: "LayerNode") -> "LayerNode":
        return LayerNode(
            name=f"{self.name}+{other.name}",
            flops_fwd=self.flops_fwd + other.flops_fwd,
            param_bytes=self.param_bytes + other.param_bytes,
            act_bytes=other.act_bytes,       # boundary activation = last node's
            flops_bwd=self.flops_bwd + other.flops_bwd,
            state_bytes=self.state_bytes + other.state_bytes,
        )


class ModelGraph:
    """DAG of LayerNodes. Edges by node index."""

    def __init__(self, nodes: Sequence[LayerNode],
                 edges: Iterable[Tuple[int, int]]):
        self.nodes = list(nodes)
        self.edges = sorted(set(edges))
        n = len(self.nodes)
        for a, b in self.edges:
            if not (0 <= a < n and 0 <= b < n):
                raise ValueError(f"edge ({a},{b}) out of range")
        self._succ: Dict[int, List[int]] = {i: [] for i in range(n)}
        self._pred: Dict[int, List[int]] = {i: [] for i in range(n)}
        for a, b in self.edges:
            self._succ[a].append(b)
            self._pred[b].append(a)
        self._check_acyclic()

    # -- basics ----------------------------------------------------------------
    @classmethod
    def chain(cls, nodes: Sequence[LayerNode]) -> "ModelGraph":
        return cls(nodes, [(i, i + 1) for i in range(len(nodes) - 1)])

    def _check_acyclic(self) -> None:
        order = self.topological_order()
        if len(order) != len(self.nodes):
            raise ValueError("planning graph has a cycle")

    def topological_order(self) -> List[int]:
        indeg = {i: len(self._pred[i]) for i in range(len(self.nodes))}
        ready = sorted(i for i, d in indeg.items() if d == 0)
        out: List[int] = []
        while ready:
            i = ready.pop(0)
            out.append(i)
            for j in self._succ[i]:
                indeg[j] -= 1
                if indeg[j] == 0:
                    ready.append(j)
            ready.sort()
        return out

    @property
    def total_params(self) -> float:
        return sum(n.param_bytes for n in self.nodes)

    @property
    def total_flops_fwd(self) -> float:
        return sum(n.flops_fwd for n in self.nodes)

    # -- Δ-compression (§4.1) ----------------------------------------------------
    def compress(self, delta: float = 0.05) -> "ModelGraph":
        """Merge adjacent nodes whose combined size is < delta of total
        parameters. Only chain-internal (single-succ/single-pred) pairs
        merge so the DAG shape is preserved."""
        budget = delta * max(self.total_params, 1.0)
        nodes = [dataclasses.replace(n) for n in self.nodes]
        parent = list(range(len(nodes)))     # union-find into merged groups

        def find(i: int) -> int:
            while parent[i] != i:
                parent[i] = parent[parent[i]]
                i = parent[i]
            return i

        merged_into: Dict[int, LayerNode] = {i: nodes[i] for i in range(len(nodes))}
        order = self.topological_order()
        for i in order:
            succs = self._succ[i]
            if len(succs) != 1:
                continue
            j = succs[0]
            if len(self._pred[j]) != 1:
                continue
            ri, rj = find(i), find(j)
            if ri == rj:
                continue
            cand = merged_into[ri].merged_with(merged_into[rj])
            if cand.param_bytes < budget:
                parent[rj] = ri
                merged_into[ri] = cand
        # rebuild
        groups: Dict[int, int] = {}
        new_nodes: List[LayerNode] = []
        for i in range(len(nodes)):
            r = find(i)
            if r not in groups:
                groups[r] = len(new_nodes)
                new_nodes.append(merged_into[r])
        new_edges = set()
        for a, b in self.edges:
            ga, gb = groups[find(a)], groups[find(b)]
            if ga != gb:
                new_edges.add((ga, gb))
        return ModelGraph(new_nodes, new_edges)

    # -- serial decomposition (§4.1) ---------------------------------------------
    def serial_decompose(self) -> List[List[int]]:
        """Decompose the DAG into an ordered list of chains.

        A chain is a maximal path of nodes with in/out degree ≤ 1
        internally. Chains are emitted in topological order of their
        heads, giving the serialized sequence the DP walks (§4.1: parallel
        branches become adjacent chains that ``Q2`` may bundle into one
        stage).
        """
        chains: List[List[int]] = []
        assigned = set()
        for i in self.topological_order():
            if i in assigned:
                continue
            chain = [i]
            assigned.add(i)
            cur = i
            while True:
                succs = self._succ[cur]
                if len(succs) != 1:
                    break
                nxt = succs[0]
                if len(self._pred[nxt]) != 1 or nxt in assigned:
                    break
                chain.append(nxt)
                assigned.add(nxt)
                cur = nxt
            chains.append(chain)
        return chains
