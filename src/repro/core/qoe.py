"""QoE specifications and Dora's Lagrangian-relaxed objective (Eqs. 1-2)."""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class QoESpec:
    """User-facing QoE constraints for one workload.

    ``t_qoe``     — end-to-end latency target (sec per training iteration,
                    or sec per generated token for serving).
    ``e_qoe``     — per-device energy budget (J per iteration/token);
                    ``None`` means unconstrained.
    ``m_qoe``     — optional per-device memory cap override (bytes);
                    device memory from the profile is always enforced.
    ``lam``       — λ in Eq. (2): price of one second of QoE violation in
                    joules.
    ``deadline``  — optional long-horizon deadline (sec) for the runtime
                    adapter's uniform-progress heuristic (§4.3).
    """

    t_qoe: float = math.inf
    e_qoe: Optional[float] = None
    m_qoe: Optional[float] = None
    lam: float = 1.0
    deadline: Optional[float] = None

    def objective(self, energy: float, latency: float) -> float:
        """Eq. (2): total energy + λ · (T_plan − T_QoE)_+ ."""
        violation = max(0.0, latency - self.t_qoe)
        return energy + self.lam * violation

    def feasible_memory(self, per_device_bytes: Dict[int, float],
                        device_memory: Dict[int, float]) -> bool:
        for i, used in per_device_bytes.items():
            cap = device_memory[i]
            if self.m_qoe is not None:
                cap = min(cap, self.m_qoe)
            if used > cap:
                return False
        return True

    def feasible_energy(self, per_device_energy: Dict[int, float]) -> bool:
        if self.e_qoe is None:
            return True
        return all(e <= self.e_qoe for e in per_device_energy.values())

    def satisfied(self, plan,
                  device_memory: Optional[Dict[int, float]] = None) -> bool:
        """Full QoE verdict for one evaluated ``ParallelismPlan``: the
        latency target AND the per-device energy budget AND (when a cap
        applies) per-device memory — a plan that blows its energy budget
        does not "meet QoE" just because it is fast. ``device_memory``
        optionally supplies hardware memory caps; without it, memory is
        checked against ``m_qoe`` alone (the planner already enforces
        hardware caps at construction time).
        """
        if plan.latency > self.t_qoe:
            return False
        if not self.feasible_energy(plan.per_device_energy):
            return False
        caps = device_memory
        if caps is None and self.m_qoe is not None:
            caps = {d: math.inf for d in plan.per_device_memory}
        if caps is not None and not self.feasible_memory(plan.per_device_memory,
                                                        caps):
            return False
        return True
