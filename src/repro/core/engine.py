"""Discrete-event execution engine for plans.

Models heterogeneous compute (exclusive per-device executors) and
contention-prone networks at two fidelities:

* ``comm_mode="fair"`` — transfers start as soon as ready and *fluid-share*
  each network resource (max-min style equal split). This is what a real
  shared WiFi medium does to contention-oblivious planners (Fig. 2).
* ``comm_mode="scheduled"`` — Dora's Phase-2 behavior: transfers are
  chunked and each chunk occupies its resources exclusively, so the
  scheduler's priority order decides *when* bytes flow (spatial→temporal
  sharing, §4.2).

The same engine powers the network scheduler's evaluation, the edge
simulator behind every paper figure, and the runtime adapter's what-if
queries.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Task:
    name: str
    kind: str                       # "compute" | "comm"
    duration: float = 0.0           # compute seconds (at nominal speed)
    nbytes: float = 0.0             # comm payload bytes
    executor: Optional[str] = None  # compute resource token (exclusive)
    resources: Tuple[str, ...] = () # network resources traversed
    deps: Tuple[str, ...] = ()
    priority: float = 0.0           # larger = schedule earlier
    net_latency: float = 0.0        # fixed per-message latency (WiFi MAC/RTT)

    def clone(self, **kw) -> "Task":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class ScheduleResult:
    makespan: float
    start: Dict[str, float]
    finish: Dict[str, float]
    resource_busy: Dict[str, float]         # busy seconds per resource
    device_busy: Dict[str, float]           # busy seconds per executor

    def utilization(self, name: str) -> float:
        if self.makespan <= 0:
            return 0.0
        return self.resource_busy.get(name, self.device_busy.get(name, 0.0)) / self.makespan

    def busy_seconds(self, name: str) -> float:
        """Busy seconds of a resource or executor within the makespan."""
        return self.resource_busy.get(name, self.device_busy.get(name, 0.0))

    def idle_seconds(self, name: str) -> float:
        """Seconds ``name`` sat idle inside this schedule's makespan
        (the serving simulator's per-device idle-draw input)."""
        return max(self.makespan - self.busy_seconds(name), 0.0)


class EventEngine:
    def __init__(self, tasks: Sequence[Task], resource_caps: Dict[str, float],
                 comm_mode: str = "scheduled",
                 compute_speed: Optional[Dict[str, float]] = None):
        """``resource_caps`` — bytes/sec per network resource.
        ``compute_speed`` — multiplicative speed factor per executor
        (runtime dynamics: 0.5 = device at half speed)."""
        self.tasks = {t.name: t for t in tasks}
        self.caps = dict(resource_caps)
        self.mode = comm_mode
        self.speed = dict(compute_speed or {})
        self._succ: Dict[str, List[str]] = {n: [] for n in self.tasks}
        self._ndeps: Dict[str, int] = {}
        for t in self.tasks.values():
            missing = [d for d in t.deps if d not in self.tasks]
            if missing:
                raise ValueError(f"task {t.name} depends on unknown {missing}")
            self._ndeps[t.name] = len(t.deps)
            for d in t.deps:
                self._succ[d].append(t.name)

    # -- critical-path priorities -------------------------------------------------
    def assign_priorities(self) -> None:
        order = self._topo_order()
        dist: Dict[str, float] = {}
        for name in reversed(order):
            t = self.tasks[name]
            base = t.duration if t.kind == "compute" else self._full_bw_time(t)
            succ_max = max((dist[s] for s in self._succ[name]), default=0.0)
            dist[name] = base + succ_max
        for name, d in dist.items():
            self.tasks[name].priority = d

    def _full_bw_time(self, t: Task) -> float:
        if not t.resources or t.nbytes <= 0:
            return 0.0
        cap = min(self.caps[r] for r in t.resources)
        return t.net_latency + t.nbytes / cap

    def _topo_order(self) -> List[str]:
        indeg = dict(self._ndeps)
        ready = [n for n, d in indeg.items() if d == 0]
        out: List[str] = []
        while ready:
            n = ready.pop()
            out.append(n)
            for s in self._succ[n]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        if len(out) != len(self.tasks):
            raise ValueError("task graph has a cycle")
        return out

    # -- simulation -----------------------------------------------------------------
    def run(self) -> ScheduleResult:
        EPS = 1e-12
        ndeps = dict(self._ndeps)
        ready: List[Tuple[float, str]] = []     # (-priority, name)
        for n, d in ndeps.items():
            if d == 0:
                heapq.heappush(ready, (-self.tasks[n].priority, n))

        t_now = 0.0
        start: Dict[str, float] = {}
        finish: Dict[str, float] = {}
        res_busy: Dict[str, float] = {r: 0.0 for r in self.caps}
        dev_busy: Dict[str, float] = {}

        running_compute: List[Tuple[float, str]] = []     # heap (end, name)
        busy_exec: Dict[str, str] = {}                    # executor -> task
        busy_net: Dict[str, str] = {}                     # resource -> task (scheduled mode)
        active_comm: Dict[str, float] = {}                # task -> remaining bytes
        ready_at: Dict[str, float] = {}                   # comm -> end of latency phase

        def comm_rates() -> Dict[str, float]:
            share: Dict[str, int] = {}
            for name in active_comm:
                for r in self.tasks[name].resources:
                    share[r] = share.get(r, 0) + 1
            rates = {}
            for name in active_comm:
                t = self.tasks[name]
                rates[name] = min(self.caps[r] / share[r] for r in t.resources) \
                    if t.resources else math.inf
            return rates

        def try_start(name: str) -> bool:
            t = self.tasks[name]
            if t.kind == "compute":
                if t.executor is not None and t.executor in busy_exec:
                    return False
                dur = t.duration / self.speed.get(t.executor, 1.0)
                start[name] = t_now
                heapq.heappush(running_compute, (t_now + dur, name))
                if t.executor is not None:
                    busy_exec[t.executor] = name
                    dev_busy[t.executor] = dev_busy.get(t.executor, 0.0) + dur
                return True
            # comm
            if t.nbytes <= EPS or not t.resources:
                start[name] = t_now
                heapq.heappush(running_compute, (t_now, name))  # instantaneous
                return True
            if self.mode == "scheduled":
                if any(r in busy_net for r in t.resources):
                    return False
                for r in t.resources:
                    busy_net[r] = name
            start[name] = t_now
            active_comm[name] = t.nbytes
            ready_at[name] = t_now + t.net_latency   # bytes flow after the latency
            return True

        def complete(name: str) -> None:
            finish[name] = t_now
            t = self.tasks[name]
            if t.kind == "compute" and t.executor is not None:
                if busy_exec.get(t.executor) == name:
                    del busy_exec[t.executor]
            if t.kind == "comm":
                for r in t.resources:
                    if busy_net.get(r) == name:
                        del busy_net[r]
            for s in self._succ[name]:
                ndeps[s] -= 1
                if ndeps[s] == 0:
                    heapq.heappush(ready, (-self.tasks[s].priority, s))

        n_done = 0
        n_total = len(self.tasks)
        while n_done < n_total:
            # start everything we can, highest priority first
            requeue: List[Tuple[float, str]] = []
            progressed = True
            while progressed:
                progressed = False
                while ready:
                    pr, name = heapq.heappop(ready)
                    if try_start(name):
                        progressed = True
                    else:
                        requeue.append((pr, name))
                for item in requeue:
                    heapq.heappush(ready, item)
                requeue = []
                if progressed:
                    continue
            # advance time to next completion. Flows whose predicted
            # finish is the horizon are completed BY TIME, not by a
            # residual-byte check: on fast links (TPU ICI, multi-GbE) the
            # final drain can leave a few µbytes of float-cancellation
            # residue whose drain time rounds to zero ulps, pinning
            # t_now forever if completion only looked at bytes.
            rates = comm_rates()
            next_t = math.inf
            comm_finishers: List[str] = []
            if running_compute:
                next_t = running_compute[0][0]
            for name, rem in active_comm.items():
                r = rates[name]
                if r > 0:
                    eff_start = max(ready_at.get(name, 0.0), t_now)
                    f = eff_start + rem / r
                    tol = EPS + 1e-12 * abs(next_t if next_t < math.inf else f)
                    if f < next_t - tol:
                        next_t = f
                        comm_finishers = [name]
                    elif f <= next_t + tol:
                        comm_finishers.append(name)
            if next_t is math.inf:
                stuck = [n for n, d in ndeps.items() if d > 0 or n not in finish]
                raise RuntimeError(f"engine stalled at t={t_now}; pending={stuck[:5]}")
            # drain comm bytes (only past each task's latency phase)
            for name in list(active_comm):
                r = rates[name]
                flow_from = max(ready_at.get(name, 0.0), t_now)
                active_comm[name] -= r * max(next_t - flow_from, 0.0)
                for res in self.tasks[name].resources:
                    res_busy[res] += max(next_t - t_now, 0.0)
            t_now = next_t
            # completions
            while running_compute and running_compute[0][0] <= t_now + EPS:
                _, name = heapq.heappop(running_compute)
                complete(name)
                n_done += 1
            for name in comm_finishers:
                if name in active_comm:
                    del active_comm[name]
                    complete(name)
                    n_done += 1
            for name in list(active_comm):
                if active_comm[name] <= 1e-6:
                    del active_comm[name]
                    complete(name)
                    n_done += 1

        return ScheduleResult(makespan=t_now, start=start, finish=finish,
                              resource_busy=res_busy, device_busy=dev_busy)


def chunk_comm_tasks(tasks: Sequence[Task], w: int) -> List[Task]:
    """Split every comm task into ``w`` chained chunks (§4.2 chunking).

    Chunk 0 inherits the original deps; successors of the original task
    are re-pointed at the final chunk.
    """
    if w <= 1:
        return list(tasks)
    rename: Dict[str, str] = {}
    out: List[Task] = []
    for t in tasks:
        if t.kind != "comm" or t.nbytes <= 0:
            out.append(t)
            continue
        last = None
        for i in range(w):
            name = f"{t.name}#c{i}"
            deps = t.deps if i == 0 else (last,)
            out.append(t.clone(name=name, nbytes=t.nbytes / w, deps=tuple(deps)))
            last = name
        rename[t.name] = last
    fixed: List[Task] = []
    for t in out:
        deps = tuple(rename.get(d, d) for d in t.deps)
        fixed.append(t.clone(deps=deps) if deps != t.deps else t)
    return fixed
