"""Discrete-event execution engine for plans.

Models heterogeneous compute (exclusive per-device executors) and
contention-prone networks at two fidelities:

* ``comm_mode="fair"`` — transfers start as soon as ready and *fluid-share*
  each network resource (max-min style equal split). This is what a real
  shared WiFi medium does to contention-oblivious planners (Fig. 2).
* ``comm_mode="scheduled"`` — Dora's Phase-2 behavior: transfers are
  chunked and each chunk occupies its resources exclusively, so the
  scheduler's priority order decides *when* bytes flow (spatial→temporal
  sharing, §4.2).

The same engine powers the network scheduler's evaluation, the edge
simulator behind every paper figure, and the runtime adapter's what-if
queries.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Task:
    name: str
    kind: str                       # "compute" | "comm"
    duration: float = 0.0           # compute seconds (at nominal speed)
    nbytes: float = 0.0             # comm payload bytes
    executor: Optional[str] = None  # compute resource token (exclusive)
    resources: Tuple[str, ...] = () # network resources traversed
    deps: Tuple[str, ...] = ()
    priority: float = 0.0           # larger = schedule earlier
    net_latency: float = 0.0        # fixed per-message latency (WiFi MAC/RTT)

    def clone(self, **kw) -> "Task":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class ScheduleResult:
    makespan: float
    start: Dict[str, float]
    finish: Dict[str, float]
    resource_busy: Dict[str, float]         # busy seconds per resource
    device_busy: Dict[str, float]           # busy seconds per executor

    def utilization(self, name: str) -> float:
        if self.makespan <= 0:
            return 0.0
        return self.resource_busy.get(name, self.device_busy.get(name, 0.0)) / self.makespan

    def busy_seconds(self, name: str) -> float:
        """Busy seconds of a resource or executor within the makespan."""
        return self.resource_busy.get(name, self.device_busy.get(name, 0.0))

    def idle_seconds(self, name: str) -> float:
        """Seconds ``name`` sat idle inside this schedule's makespan
        (the serving simulator's per-device idle-draw input)."""
        return max(self.makespan - self.busy_seconds(name), 0.0)

    def admission_interval(self, n_stages: int, latency: float) -> float:
        """Steady-state admission interval of a pipeline executing this
        schedule (the serving kernel's what-if primitive).

        A pipeline's steady-state throughput is bounded by its
        *bottleneck* — the busiest stage executor (``exec{i}``) or
        network resource per request — not by the average stage span:
        stages overlap across requests, so admitting faster than the
        bottleneck span oversubscribes that device.  Falls back to the
        balanced-pipeline approximation ``latency / n_stages`` when the
        schedule carries no busy accounting (hand-built results)."""
        spans = [self.busy_seconds(f"exec{i}") for i in range(n_stages)]
        spans += list(self.resource_busy.values())
        bottleneck = max((s for s in spans if s), default=0.0)
        if bottleneck > 0.0:
            # the bottleneck span never exceeds the makespan, but guard
            # against hand-built schedules that claim otherwise
            return max(min(bottleneck, latency), 1e-9)
        return max(latency / max(n_stages, 1), 1e-9)


class EventEngine:
    def __init__(self, tasks: Sequence[Task], resource_caps: Dict[str, float],
                 comm_mode: str = "scheduled",
                 compute_speed: Optional[Dict[str, float]] = None,
                 structure: Optional[tuple] = None):
        """``resource_caps`` — bytes/sec per network resource.
        ``compute_speed`` — multiplicative speed factor per executor
        (runtime dynamics: 0.5 = device at half speed).
        ``structure`` — a previous engine's :meth:`structure` for the
        *same task list* (dependency graph + topological order), so
        repeated engines over one CEP graph skip the O(V+E) rebuild
        (see :class:`repro.core.cep.CEPCache`)."""
        self.caps = dict(resource_caps)
        self.mode = comm_mode
        self.speed = dict(compute_speed or {})
        if structure is None:
            structure = task_structure(tasks)
        self.tasks, self._succ, self._ndeps, self._order = structure

    def structure(self) -> tuple:
        """Shareable dependency structure: (tasks-by-name, successors,
        dependency counts, topological order). Valid for any engine
        built over the same task list."""
        return (self.tasks, self._succ, self._ndeps, self._order)

    # -- critical-path priorities -------------------------------------------------
    def assign_priorities(self,
                          dist: Optional[Dict[str, float]] = None
                          ) -> Dict[str, float]:
        """Set each task's priority to its downstream critical path.

        Pass a ``dist`` previously returned for the same (task graph,
        resource caps) to re-apply cached priorities without the O(V+E)
        recomputation; the mapping is returned either way so callers can
        cache it (priorities depend on caps but not on compute speed or
        comm mode)."""
        if dist is None:
            dist = {}
            for name in reversed(self._order):
                t = self.tasks[name]
                base = t.duration if t.kind == "compute" else self._full_bw_time(t)
                succ_max = max((dist[s] for s in self._succ[name]), default=0.0)
                dist[name] = base + succ_max
        for name, d in dist.items():
            self.tasks[name].priority = d
        return dist

    def _full_bw_time(self, t: Task) -> float:
        if not t.resources or t.nbytes <= 0:
            return 0.0
        cap = min(self.caps[r] for r in t.resources)
        return t.net_latency + t.nbytes / cap

    # -- simulation -----------------------------------------------------------------
    def run(self) -> ScheduleResult:
        EPS = 1e-12
        tasks = self.tasks
        succ = self._succ
        caps = self.caps
        speed = self.speed
        scheduled = self.mode == "scheduled"
        heappush, heappop = heapq.heappush, heapq.heappop
        ndeps = dict(self._ndeps)
        ready: List[Tuple[float, str]] = []     # (-priority, name)
        for n, d in ndeps.items():
            if d == 0:
                heappush(ready, (-tasks[n].priority, n))

        t_now = 0.0
        start: Dict[str, float] = {}
        finish: Dict[str, float] = {}
        res_busy: Dict[str, float] = {r: 0.0 for r in caps}
        dev_busy: Dict[str, float] = {}

        running_compute: List[Tuple[float, str]] = []     # heap (end, name)
        busy_exec: Dict[str, str] = {}                    # executor -> task
        busy_net: Dict[str, str] = {}                     # resource -> task (scheduled mode)
        active_comm: Dict[str, float] = {}                # task -> remaining bytes
        ready_at: Dict[str, float] = {}                   # comm -> end of latency phase
        share: Dict[str, int] = {}                        # active flows per resource
        # a task that fails to start parks under the executor/resource
        # tokens blocking it (they free exclusively in `complete`); each
        # waiting queue is a priority heap and a freed token promotes
        # only its best parked waiter into the ready heap — promoting
        # every waiter on every completion is quadratic on a shared
        # medium with hundreds of queued chunks
        waiting: Dict[str, List[Tuple[float, str]]] = {}  # token -> heap[(pr, name)]
        parked: set = set()
        # scheduled mode holds every resource exclusively, so an active
        # flow's rate is a constant: min capacity along its route
        fixed_rate: Dict[str, float] = {}

        def promote(token: str) -> None:
            """Move the best still-parked waiter of a freed token into
            the ready heap (stale heap entries are skipped)."""
            w = waiting.get(token)
            while w:
                item = heapq.heappop(w)
                if item[1] in parked:
                    parked.discard(item[1])
                    heappush(ready, item)
                    break

        def try_start(pr: float, name: str) -> None:
            t = tasks[name]
            if t.kind == "compute":
                ex = t.executor
                if ex is not None and ex in busy_exec:
                    parked.add(name)
                    heappush(waiting.setdefault(ex, []), (pr, name))
                    return
                dur = t.duration / speed.get(ex, 1.0)
                start[name] = t_now
                heappush(running_compute, (t_now + dur, name))
                if ex is not None:
                    busy_exec[ex] = name
                    dev_busy[ex] = dev_busy.get(ex, 0.0) + dur
                return
            # comm
            if t.nbytes <= EPS or not t.resources:
                start[name] = t_now
                heappush(running_compute, (t_now, name))  # instantaneous
                return
            if scheduled:
                holders = [r for r in t.resources if r in busy_net]
                if holders:
                    parked.add(name)
                    for r in holders:
                        heappush(waiting.setdefault(r, []), (pr, name))
                    # this task may have been the designated waiter of a
                    # token that is free right now — hand that token to
                    # its next waiter so it doesn't idle a whole wave
                    for r in t.resources:
                        if r not in busy_net and waiting.get(r):
                            promote(r)
                    return
                for r in t.resources:
                    busy_net[r] = name
                if name not in fixed_rate:
                    fixed_rate[name] = min(caps[r] for r in t.resources)
            start[name] = t_now
            active_comm[name] = t.nbytes
            ready_at[name] = t_now + t.net_latency   # bytes flow after the latency
            for r in t.resources:
                share[r] = share.get(r, 0) + 1
            return

        def complete(name: str) -> None:
            finish[name] = t_now
            t = tasks[name]
            if t.kind == "compute" and t.executor is not None:
                if busy_exec.get(t.executor) == name:
                    del busy_exec[t.executor]
                    promote(t.executor)
            if t.kind == "comm":
                for r in t.resources:
                    if busy_net.get(r) == name:
                        del busy_net[r]
                        promote(r)
            for s in succ[name]:
                ndeps[s] -= 1
                if ndeps[s] == 0:
                    heappush(ready, (-tasks[s].priority, s))

        n_done = 0
        n_total = len(tasks)
        while n_done < n_total:
            # start everything we can, highest priority first
            while ready:
                pr, name = heappop(ready)
                try_start(pr, name)
            # advance time to next completion. Flows whose predicted
            # finish is the horizon are completed BY TIME, not by a
            # residual-byte check: on fast links (TPU ICI, multi-GbE) the
            # final drain can leave a few µbytes of float-cancellation
            # residue whose drain time rounds to zero ulps, pinning
            # t_now forever if completion only looked at bytes.
            # max-min fluid share: each flow runs at its bottleneck
            # resource's capacity split over that resource's active flows
            # (the `share` counts, maintained incrementally). Scheduled
            # mode holds resources exclusively (share ≡ 1), so the rate
            # is each flow's precomputed route minimum.
            if scheduled:
                rates = fixed_rate
            else:
                rates = {name: min(caps[r] / share[r]
                                   for r in tasks[name].resources)
                         for name in active_comm}
            next_t = math.inf
            comm_finishers: List[str] = []
            if running_compute:
                next_t = running_compute[0][0]
            for name, rem in active_comm.items():
                r = rates[name]
                if r > 0:
                    eff_start = ready_at.get(name, 0.0)
                    if eff_start < t_now:
                        eff_start = t_now
                    f = eff_start + rem / r
                    tol = EPS + 1e-12 * abs(next_t if next_t < math.inf else f)
                    if f < next_t - tol:
                        next_t = f
                        comm_finishers = [name]
                    elif f <= next_t + tol:
                        comm_finishers.append(name)
            if next_t is math.inf:
                stuck = [n for n, d in ndeps.items() if d > 0 or n not in finish]
                raise RuntimeError(f"engine stalled at t={t_now}; pending={stuck[:5]}")
            # drain comm bytes (only past each task's latency phase)
            dt = next_t - t_now
            if dt < 0.0:
                dt = 0.0
            for name in active_comm:
                flow_from = ready_at.get(name, 0.0)
                if flow_from < t_now:
                    flow_from = t_now
                flow = next_t - flow_from
                if flow > 0.0:
                    active_comm[name] -= rates[name] * flow
                for res in tasks[name].resources:
                    res_busy[res] += dt
            t_now = next_t
            # completions
            while running_compute and running_compute[0][0] <= t_now + EPS:
                _, name = heappop(running_compute)
                complete(name)
                n_done += 1
            for name in comm_finishers:
                if name in active_comm:
                    del active_comm[name]
                    for r in tasks[name].resources:
                        share[r] -= 1
                    complete(name)
                    n_done += 1
            for name in list(active_comm):
                if active_comm[name] <= 1e-6:
                    del active_comm[name]
                    for r in tasks[name].resources:
                        share[r] -= 1
                    complete(name)
                    n_done += 1

        return ScheduleResult(makespan=t_now, start=start, finish=finish,
                              resource_busy=res_busy, device_busy=dev_busy)


def chunk_comm_tasks(tasks: Sequence[Task], w: int) -> List[Task]:
    """Split every comm task into ``w`` chained chunks (§4.2 chunking).

    Chunk 0 inherits the original deps; successors of the original task
    are re-pointed at the final chunk.
    """
    if w <= 1:
        return list(tasks)
    rename: Dict[str, str] = {}
    out: List[Task] = []
    for t in tasks:
        if t.kind != "comm" or t.nbytes <= 0:
            out.append(t)
            continue
        nb = t.nbytes / w
        last = None
        for i in range(w):
            name = f"{t.name}#c{i}"
            deps = t.deps if i == 0 else (last,)
            out.append(Task(name=name, kind=t.kind, duration=t.duration,
                            nbytes=nb, executor=t.executor,
                            resources=t.resources, deps=tuple(deps),
                            priority=t.priority, net_latency=t.net_latency))
            last = name
        rename[t.name] = last
    fixed: List[Task] = []
    for t in out:
        deps = tuple(rename.get(d, d) for d in t.deps)
        if deps != t.deps:
            t = Task(name=t.name, kind=t.kind, duration=t.duration,
                     nbytes=t.nbytes, executor=t.executor,
                     resources=t.resources, deps=deps,
                     priority=t.priority, net_latency=t.net_latency)
        fixed.append(t)
    return fixed


def task_structure(tasks: Sequence[Task],
                   base: Optional[tuple] = None) -> tuple:
    """Dependency structure for :class:`EventEngine`: ``(tasks-by-name,
    successors, dependency counts, topological order)``.

    With ``base`` — the structure of the *unchunked* task list the
    chunked ``tasks`` were derived from — everything is rebuilt by a
    single linear walk of the base topological order (a comm task's
    chunk chain slots into its position), skipping the dependency
    validation and Kahn's algorithm.
    """
    by_name = {t.name: t for t in tasks}
    succ: Dict[str, List[str]] = {n: [] for n in by_name}
    ndeps: Dict[str, int] = {}
    for t in tasks:
        missing = [d for d in t.deps if d not in by_name]
        if missing:
            raise ValueError(f"task {t.name} depends on unknown {missing}")
        ndeps[t.name] = len(t.deps)
        for d in t.deps:
            succ[d].append(t.name)
    if base is not None:
        base_order = base[3]
        order: List[str] = []
        for name in base_order:
            if name in by_name:
                order.append(name)
            else:                       # comm task replaced by its chunks
                i = 0
                while f"{name}#c{i}" in by_name:
                    order.append(f"{name}#c{i}")
                    i += 1
        return by_name, succ, ndeps, order
    indeg = dict(ndeps)
    ready = [n for n, d in indeg.items() if d == 0]
    order = []
    while ready:
        n = ready.pop()
        order.append(n)
        for s in succ[n]:
            indeg[s] -= 1
            if indeg[s] == 0:
                ready.append(s)
    if len(order) != len(by_name):
        raise ValueError("task graph has a cycle")
    return by_name, succ, ndeps, order
