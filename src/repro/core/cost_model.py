"""Analytic cost model for Phase-1 planning (§4.1).

All times use the *contention-free peak p2p* network relaxation; Phase 2
re-evaluates the survivors under real contention. Costs are analytic
roofline estimates (compute-bound FLOP time ⊕ memory-bound byte time);
``DeviceProfile.compute_efficiency`` calibrates to measured MFU.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Protocol, Sequence, Union, \
    runtime_checkable

from .device import Topology
from .planning_graph import ModelGraph
from .plans import ParallelismPlan, Stage
from . import profiler
from .qoe import QoESpec


DVFS_FLOOR = 0.15   # energy/FLOP at min frequency relative to peak (Fig. 3a)


def plan_device_energy(stages: Sequence[Stage], topo: Topology, n_micro: int,
                       training: bool, latency: float) -> Dict[int, float]:
    """Per-device energy for one iteration: compute + network tx + idle.

    Compute energy is DVFS-aware (the paper's Fig. 3a lever): a device
    that only needs fraction ``r`` of its peak rate to keep up with the
    plan runs at a lower voltage/frequency point, costing
    ``e_flop · (floor + (1-floor)·r²)`` per FLOP — slowing execution
    within QoE slack is what unlocks the order-of-magnitude savings the
    paper measures.

    The last stage's boundary activation is never transmitted; gradient
    return traffic is sized by the *upstream* boundary activation.
    """
    per_e: Dict[int, float] = {}
    S = len(stages)
    for idx, s in enumerate(stages):
        for d in s.devices:
            dev = topo.devices[d]
            share = s.microbatch_split[d]
            fl = (s.flops_fwd + s.flops_bwd) * n_micro * share / max(s.tp_degree, 1)
            busy = fl / dev.effective_flops(s.tp_degree)
            r = min(busy / max(latency, 1e-12), 1.0)
            dvfs = DVFS_FLOOR + (1.0 - DVFS_FLOOR) * r * r
            tx = s.sync_bytes
            if idx + 1 < S:
                tx += s.comm_bytes_out * n_micro * share          # activations down
            if training and idx > 0:
                tx += stages[idx - 1].comm_bytes_out * n_micro * share  # grads up
            e = dev.compute_energy(fl) * dvfs + dev.e_byte * tx \
                + dev.p_idle * latency
            per_e[d] = per_e.get(d, 0.0) + e
    return per_e


@dataclasses.dataclass(frozen=True)
class Workload:
    """One planning workload."""

    global_batch: int
    microbatch_size: int
    training: bool = True
    # training memory multiplier over bf16 params: grads + fp32 Adam m/v
    # (2 + 2 + 4 + 4 + 4)/2 = 8 over raw bf16 param bytes.
    optimizer_mult: float = 8.0
    # gradient-sync byte multiplier (0.25 = int8+EF compression on the
    # slow axis — see optim/compress.py)
    grad_compression: float = 1.0

    @property
    def n_microbatches(self) -> int:
        return max(1, self.global_batch // self.microbatch_size)


# The paper's §5 evaluation workloads — the single source of truth for
# the scenario catalogue (repro.scenarios.catalog) and the benchmark
# harnesses (repro.sim.runner.workload_for). Edge tuning keeps bf16
# params + grads + momentum → 3× param bytes of tuning state.
PAPER_TRAIN_WORKLOAD = Workload(global_batch=32, microbatch_size=4,
                                training=True, optimizer_mult=3.0)
PAPER_SERVE_WORKLOAD = Workload(global_batch=8, microbatch_size=1,
                                training=False)


@runtime_checkable
class CostProvider(Protocol):
    """Source of the costs every planner strategy consumes.

    Two fidelities share this protocol: :class:`AnalyticCosts` (pure
    datasheet rooflines, the Phase-1 default) and
    :class:`repro.core.profiler.ProfiledCosts` (the same rooflines
    recalibrated by measured step times / kernel benchmarks).  A provider
    is injected with ``dora.plan(..., costs=...)`` or passed to any
    ``PlannerStrategy.plan``; consumers either ask for a ready
    :class:`CostModel` or calibrate a topology and keep using their own
    cost code on top of it.
    """

    name: str

    def calibrate(self, topo: Topology) -> Topology:
        """Topology with device/link rates adjusted to this provider's
        view of the hardware (identity for analytic costs).  ``topo`` is
        always the *raw* datasheet topology — calibration is not
        idempotent for measured providers, so never re-calibrate an
        already-calibrated topology."""
        ...

    def cost_model(self, graph: ModelGraph, topo: Topology,
                   workload: Workload) -> "CostModel":
        """A :class:`CostModel` pricing ``graph`` for ``workload``.
        ``topo`` is the *raw* topology; the provider calibrates it
        internally (do not pass ``calibrate(topo)`` here)."""
        ...


@dataclasses.dataclass(frozen=True)
class AnalyticCosts:
    """The default provider: roofline costs straight from the
    ``DeviceProfile``/``LinkResource`` datasheet numbers."""

    name: str = "analytic"

    def calibrate(self, topo: Topology) -> Topology:
        return topo

    def cost_model(self, graph: ModelGraph, topo: Topology,
                   workload: Workload) -> "CostModel":
        return CostModel(graph, topo, workload)


#: Shared default instance (stateless, safe to reuse).
ANALYTIC_COSTS = AnalyticCosts()


#: ``costs=`` accepts a provider instance or a string reference:
#: ``"analytic"`` or ``"profiled:<path/to/artifact.json>"``.
CostRef = Union[None, str, CostProvider]


def resolve_costs(costs: CostRef) -> CostProvider:
    """``None`` -> the analytic default; a string resolves a named
    provider (``"analytic"``, ``"profiled:<path>"`` — a committed
    :meth:`ProfiledCosts.to_json` artifact); instances pass through."""
    if costs is None:
        return ANALYTIC_COSTS
    if isinstance(costs, str):
        if costs == "analytic":
            return ANALYTIC_COSTS
        if costs.startswith("profiled:"):
            from .profiler import ProfiledCosts
            return ProfiledCosts.from_json(costs[len("profiled:"):])
        raise ValueError(f"unknown cost provider {costs!r}: expected "
                         f"'analytic' or 'profiled:<path>'")
    return costs


class SegmentAggregates:
    """O(1) pricing of contiguous segments of a serialized node order.

    The partitioner's DP prices O(L²·N²) candidate stages, every one of
    which is a *contiguous* slice of one fixed serialization of the
    planning graph (chain slices, and bundles of adjacent chains).  This
    class memoizes the per-segment sums :meth:`CostModel.make_stage`
    needs — forward/backward FLOPs and parameter bytes — so each
    distinct segment is summed once and every repeat costs O(1).

    Sums are accumulated left-to-right exactly like ``sum(...)`` over
    the slice, so segment prices are bit-identical to the naive path
    (plan-parity golden tests depend on this).
    """

    __slots__ = ("order", "_nodes", "_memo")

    def __init__(self, graph: ModelGraph, order: Sequence[int]):
        self.order = list(order)
        self._nodes = [graph.nodes[i] for i in self.order]
        # (lo, hi) -> (flops_fwd, flops_bwd, param_bytes, state_bytes)
        # for order[lo:hi]
        self._memo: Dict[tuple, tuple] = {}

    def segment(self, lo: int, hi: int) -> tuple:
        """(flops_fwd, flops_bwd, param_bytes, state_bytes) summed over
        order[lo:hi]."""
        if hi <= lo:
            return (0.0, 0.0, 0.0, 0.0)
        memo = self._memo
        out = memo.get((lo, hi))
        if out is not None:
            return out
        h = hi - 1
        while h > lo and (lo, h) not in memo:
            h -= 1
        ff, fb, pb, sb = memo[(lo, h)] if h > lo else (0.0, 0.0, 0.0, 0.0)
        while h < hi:
            n = self._nodes[h]
            ff, fb, pb, sb = (ff + n.flops_fwd, fb + n.flops_bwd,
                              pb + n.param_bytes, sb + n.state_bytes)
            h += 1
            memo[(lo, h)] = (ff, fb, pb, sb)
        return memo[(lo, hi)]

    def boundary_act_bytes(self, hi: int) -> float:
        """Per-sample output-activation bytes of segment-final node
        ``order[hi-1]`` (the stage's downstream boundary)."""
        return self._nodes[hi - 1].act_bytes


class CostModel:
    def __init__(self, graph: ModelGraph, topo: Topology, workload: Workload):
        self.graph = graph
        self.topo = topo
        self.wl = workload
        self._eff: Dict[tuple, float] = {}      # (device, tp) -> eff FLOP/s

    # -- stage construction ----------------------------------------------------
    def make_stage(self, node_ids: Sequence[int], devices: Sequence[int],
                   next_devices: Optional[Sequence[int]] = None) -> Stage:
        b = self.wl.microbatch_size
        nodes = [self.graph.nodes[i] for i in node_ids]
        flops_f = sum(n.flops_fwd for n in nodes) * b
        flops_b = sum(n.flops_bwd for n in nodes) * b if self.wl.training else 0.0
        params = sum(n.param_bytes for n in nodes)
        boundary_act = nodes[-1].act_bytes * b
        state = sum(n.state_bytes for n in nodes)
        return self._build_stage(list(node_ids), flops_f, flops_b, params,
                                 boundary_act, state, devices, next_devices)

    def make_stage_span(self, agg: SegmentAggregates, lo: int, hi: int,
                        devices: Sequence[int],
                        next_devices: Optional[Sequence[int]] = None) -> Stage:
        """``make_stage`` for the contiguous segment ``agg.order[lo:hi]``,
        priced in O(1) from the memoized prefix aggregates."""
        b = self.wl.microbatch_size
        ff, fb, pb, sb = agg.segment(lo, hi)
        flops_f = ff * b
        flops_b = fb * b if self.wl.training else 0.0
        boundary_act = agg.boundary_act_bytes(hi) * b
        return self._build_stage(agg.order[lo:hi], flops_f, flops_b, pb,
                                 boundary_act, sb, devices, next_devices)

    def _build_stage(self, node_ids: List[int], flops_f: float, flops_b: float,
                     params: float, boundary_act: float, state: float,
                     devices: Sequence[int],
                     next_devices: Optional[Sequence[int]]) -> Stage:
        devs = list(devices)
        tp = 1
        if len(devs) == 1:
            tp = self.topo.devices[devs[0]].n_accel
        eff = self._eff
        speeds = {}
        for d in devs:
            v = eff.get((d, tp))
            if v is None:
                v = self.topo.devices[d].effective_flops(tp)
                eff[(d, tp)] = v
            speeds[d] = v
        total_speed = sum(speeds.values())
        split = {d: speeds[d] / total_speed for d in devs}

        # balanced execution time: every replica finishes together when
        # microbatches are split ∝ speed (§4.1 load-balance rule).
        # Per-device roofline: FLOP time ⊕ weight-streaming time (every DP
        # replica reads the full stage weights once per microbatch — the
        # dominant term for small-batch serving).
        w_read = params / max(tp, 1)
        t_f = max(flops_f / total_speed,
                  max(w_read / self.topo.devices[d].mem_bw for d in devs))
        t_b = max(flops_b / total_speed,
                  max(2.0 * w_read / self.topo.devices[d].mem_bw for d in devs)) \
            if self.wl.training else 0.0

        # activation send to the next stage at peak p2p bandwidth
        send_t = 0.0
        if next_devices:
            pairs = [(i, j) for i in devs for j in next_devices if i != j]
            if pairs:
                bw = min(self.topo.peak_bandwidth(i, j) for i, j in pairs)
                lat = max(self.topo.route_latency(i, j) for i, j in pairs)
                send_t = lat + boundary_act / bw

        sync_bytes = 0.0
        if self.wl.training and len(devs) > 1:
            g = len(devs)
            sync_bytes = 2.0 * params * (g - 1) / g \
                * self.wl.grad_compression              # ring all-reduce per device

        return Stage(node_ids=node_ids, devices=devs, microbatch_split=split,
                     tp_degree=tp, fwd_time=t_f + send_t, bwd_time=t_b + send_t,
                     comm_bytes_out=boundary_act, sync_bytes=sync_bytes,
                     param_bytes=params, flops_fwd=flops_f, flops_bwd=flops_b,
                     state_bytes=state)

    # -- memory ------------------------------------------------------------------
    def stage_memory(self, stage: Stage, n_stages_hint: int = 1,
                     schedule: str = "1f1b") -> Dict[int, float]:
        """Per-device bytes for a stage: params (+optimizer) + in-flight
        activations. 1F1B holds ≤ n_stages microbatches of activations."""
        mult = self.wl.optimizer_mult if self.wl.training else 1.0
        params_per_dev = stage.param_bytes * mult / max(stage.tp_degree, 1)
        in_flight = min(self.wl.n_microbatches, n_stages_hint) if schedule == "1f1b" \
            else self.wl.n_microbatches
        act = stage.comm_bytes_out * in_flight
        state = stage.state_bytes
        if state is None:       # hand-built Stage: fall back to the graph
            state = sum(self.graph.nodes[i].state_bytes for i in stage.node_ids)
        state = state * self.wl.microbatch_size
        out = {}
        for d in stage.devices:
            out[d] = params_per_dev + act * stage.microbatch_split[d] + state
        return out

    def memory_feasible(self, stage: Stage, qoe: QoESpec, n_stages_hint: int = 4) -> bool:
        mem = self.stage_memory(stage, n_stages_hint)
        for d, used in mem.items():
            cap = self.topo.devices[d].memory
            if qoe.m_qoe is not None:
                cap = min(cap, qoe.m_qoe)
            if used > cap:
                return False
        return True

    # -- full-plan evaluation (contention-free) -----------------------------------
    def boundary_comm_times(self, stages: List[Stage]) -> List[float]:
        """Per-boundary activation/gradient transfer time at peak p2p bw."""
        out: List[float] = []
        for a, b_ in zip(stages[:-1], stages[1:]):
            pairs = [(i, j) for i in a.devices for j in b_.devices if i != j]
            if not pairs:
                out.append(0.0)
                continue
            bw = min(self.topo.peak_bandwidth(i, j) for i, j in pairs)
            lat = max(self.topo.route_latency(i, j) for i, j in pairs)
            out.append(lat + a.comm_bytes_out / bw)
        return out

    def evaluate(self, stages: List[Stage], qoe: QoESpec,
                 schedule: str = "1f1b") -> ParallelismPlan:
        M = self.wl.n_microbatches
        bf = [s.fwd_time for s in stages]
        bb = [s.bwd_time for s in stages]
        comm = self.boundary_comm_times(stages)
        if self.wl.training:
            if schedule == "gpipe":
                lat = profiler.gpipe_latency(bf, bb, M, comm, comm)
            else:
                lat = profiler.one_f_one_b_latency(bf, bb, M, comm, comm)
            # gradient sync after the flush (phase-1: non-overlapped bound)
            sync_t = 0.0
            for s in stages:
                if s.sync_bytes > 0.0:
                    bw = min(self.topo.peak_bandwidth(i, j)
                             for i in s.devices for j in s.devices if i != j)
                    sync_t = max(sync_t, s.sync_bytes / bw)
            lat += sync_t
        else:
            # inference: forward wave only
            lat = profiler.gpipe_latency(bf, [0.0] * len(bf), M, comm, comm)

        per_e = plan_device_energy(stages, self.topo, M, self.wl.training, lat)
        per_m: Dict[int, float] = {}
        for s in stages:
            mem = self.stage_memory(s, len(stages), schedule)
            for d in s.devices:
                per_m[d] = max(per_m.get(d, 0.0), mem[d])

        energy = sum(per_e.values())
        plan = ParallelismPlan(
            stages=stages, microbatch_size=self.wl.microbatch_size,
            n_microbatches=M, training=self.wl.training, latency=lat,
            energy=energy, per_device_energy=per_e, per_device_memory=per_m,
            objective=qoe.objective(energy, lat))
        return plan
