"""Dora core: QoE-aware hybrid parallelism planning (the paper's contribution).

Most callers should go through the facade — ``repro.dora.plan(name)``
resolves a registered deployment scenario and runs this whole stack in
one call. The underlying API, for custom wiring:

    graph   = graph_builders.paper_model("qwen3-1.7b", seq_len=512)
    topo    = device.make_setting("smart_home_2")
    qoe     = QoESpec(t_qoe=0.2, lam=50.0)
    planner = DoraPlanner(graph, topo, qoe)
    result  = planner.plan(Workload(global_batch=32, microbatch_size=4))
    adapter = planner.make_adapter(result)
"""
from .adapter import AdapterConfig, DynamicsEvent, RuntimeAdapter, pareto_filter
from .cost_model import CostModel, Workload
from .device import CATALOG, DeviceProfile, LinkResource, Topology, make_setting
from .engine import EventEngine, ScheduleResult, Task, chunk_comm_tasks
from .graph_builders import GraphSpec, build_lm_graph, build_multimodal_graph, paper_model
from .partitioner import ModelPartitioner, PartitionerConfig
from .planner import DoraPlanner, PlanningResult
from .planning_graph import LayerNode, ModelGraph
from .plans import ParallelismPlan, Stage
from .qoe import QoESpec
from .scheduler import NetworkScheduler, SchedulerConfig

__all__ = [
    "AdapterConfig", "DynamicsEvent", "RuntimeAdapter", "pareto_filter",
    "CostModel", "Workload", "CATALOG", "DeviceProfile", "LinkResource",
    "Topology", "make_setting", "EventEngine", "ScheduleResult", "Task",
    "chunk_comm_tasks", "GraphSpec", "build_lm_graph", "build_multimodal_graph",
    "paper_model", "ModelPartitioner", "PartitionerConfig", "DoraPlanner",
    "PlanningResult", "LayerNode", "ModelGraph", "ParallelismPlan", "Stage",
    "QoESpec", "NetworkScheduler", "SchedulerConfig",
]
