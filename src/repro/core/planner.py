"""Algorithm 1 — QoE-aware hybrid parallelism planner (end-to-end)."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

from .adapter import AdapterConfig, RuntimeAdapter, pareto_filter
from .cost_model import CostModel, CostProvider, Workload, resolve_costs
from .device import Topology
from .partitioner import ModelPartitioner, PartitionerConfig
from .planning_graph import ModelGraph
from .plans import ParallelismPlan
from .qoe import QoESpec
from .scheduler import NetworkScheduler, SchedulerConfig


@dataclasses.dataclass
class PlanningResult:
    best: ParallelismPlan
    candidates: List[ParallelismPlan]       # Phase-2 refined, ranked
    pareto: List[ParallelismPlan]           # for the runtime adapter
    phase1_s: float
    phase2_s: float
    #: True when this result came from `DoraPlanner.replan`'s warm path
    #: (re-priced previous pool, no fresh DP search)
    warm_start: bool = False

    @property
    def total_s(self) -> float:
        return self.phase1_s + self.phase2_s


class DoraPlanner:
    """ParallelismPlanner(G_M, D) per Algorithm 1."""

    def __init__(self, graph: ModelGraph, topo: Topology, qoe: QoESpec,
                 partitioner_config: Optional[PartitionerConfig] = None,
                 scheduler_config: Optional[SchedulerConfig] = None,
                 adapter_config: Optional[AdapterConfig] = None,
                 costs: Optional[CostProvider] = None):
        self.graph = graph
        self.costs = resolve_costs(costs)
        # the whole stack (partitioner, scheduler, adapter) plans against
        # the provider's view of the hardware — analytic by default,
        # measurement-calibrated with ProfiledCosts
        self.topo = self.costs.calibrate(topo)
        self.qoe = qoe
        self.partitioner = ModelPartitioner(self.graph, self.topo, qoe,
                                            partitioner_config)
        self.scheduler = NetworkScheduler(self.topo, qoe, scheduler_config)
        self.adapter_config = adapter_config

    def plan(self, workload: Workload) -> PlanningResult:
        t0 = time.perf_counter()
        pool = self.partitioner.plan(workload, pool=True)  # lines 2-3 (top-K pool)
        t1 = time.perf_counter()
        refined = self.scheduler.refine_candidates(        # line 4
            pool, keep=self.partitioner.config.top_k)
        t2 = time.perf_counter()
        if not refined:
            raise RuntimeError("no QoE-feasible plan found")
        return PlanningResult(best=refined[0], candidates=refined,
                              pareto=pareto_filter(refined),
                              phase1_s=t1 - t0, phase2_s=t2 - t1)

    def make_adapter(self, result: PlanningResult) -> RuntimeAdapter:
        return RuntimeAdapter(result.candidates, self.topo, self.qoe,
                              self.scheduler, self.adapter_config)

    # -- warm-start replanning (§4.3 fast path) -----------------------------------
    def replan(self, workload: Workload,
               prev: Union[PlanningResult, Sequence[ParallelismPlan]],
               mapping: Optional[Dict[int, int]] = None,
               keep: Optional[int] = None) -> PlanningResult:
        """Warm-start replanning: re-price a previous result's
        candidate/Pareto pool on *this* planner's topology and re-refine
        only the head under real contention, falling back to the full
        fresh DP (:meth:`plan`) only when no re-priced candidate is
        QoE-feasible.

        ``prev`` — the previous :class:`PlanningResult` (or a plain plan
        sequence).  ``mapping`` translates the previous plans' device
        ids into this planner's topology (``None`` = identity); device
        ids missing from the mapping have left the fleet — their stages
        are rebuilt on the stage's surviving devices, and plans with a
        fully-departed or memory-infeasible stage drop out of the warm
        pool.  ``keep`` bounds the Phase-2 chunk-search head (defaults
        to the partitioner's ``top_k``); each kept plan is re-refined
        with its previously winning chunk count, so a steady-state churn
        replan prices ~pool-size schedules instead of re-running the
        whole DP × chunk-mode search.
        """
        t0 = time.perf_counter()
        if isinstance(prev, PlanningResult):
            pool: List[ParallelismPlan] = list(prev.candidates)
            for p in prev.pareto:
                if p not in pool:
                    pool.append(p)
        else:
            pool = list(prev)
        warm: List[ParallelismPlan] = []
        seen = set()
        for p in pool:
            q = self._warm_reprice(p, mapping, workload)
            if q is None:
                continue
            sig = tuple((tuple(s.node_ids), tuple(s.devices))
                        for s in q.stages) + (q.microbatch_size,)
            if sig in seen:
                continue
            seen.add(sig)
            warm.append(q)
        warm.sort(key=self.partitioner._rank_key)
        t1 = time.perf_counter()
        if warm:
            keep = keep if keep is not None else self.partitioner.config.top_k
            def refine_fast(p: ParallelismPlan) -> ParallelismPlan:
                w_prev = p.meta.get("chunks")
                modes = ((w_prev,) if w_prev else ()) \
                    if isinstance(w_prev, int) else None
                return self.scheduler.refine(p, modes=modes)

            ranked = [refine_fast(p) for p in warm[:keep]] + warm[keep:]
            ranked.sort(key=lambda p: p.objective)
            # the served winner must be contention-priced: a tail plan
            # still carrying its optimistic contention-free estimate may
            # outrank the refined head, so refine ranked[0] until a
            # refined plan genuinely tops the ranking (usually 0 extra
            # refines; bounded by the pool size)
            while ranked[0].schedule is None:
                ranked[0] = refine_fast(ranked[0])
                ranked.sort(key=lambda p: p.objective)
            t2 = time.perf_counter()
            if self.qoe.satisfied(ranked[0]):
                return PlanningResult(best=ranked[0], candidates=ranked,
                                      pareto=pareto_filter(ranked),
                                      phase1_s=t1 - t0, phase2_s=t2 - t1,
                                      warm_start=True)
        return self.plan(workload)

    def _warm_reprice(self, plan: ParallelismPlan,
                      mapping: Optional[Dict[int, int]],
                      workload: Workload) -> Optional[ParallelismPlan]:
        """One previous candidate re-priced on this planner's topology
        (contention-free; Phase 2 re-prices the head under contention).
        Returns ``None`` when the plan doesn't survive the fleet change.
        """
        part = self.partitioner
        wl = dataclasses.replace(workload,
                                 microbatch_size=plan.microbatch_size)
        if workload.global_batch % max(plan.microbatch_size, 1):
            return None
        cm = CostModel(part.graph, self.topo, wl)
        n_nodes = len(part.graph.nodes)
        stages = []
        for s in plan.stages:
            if any(i >= n_nodes for i in s.node_ids):
                return None     # planned against a different model graph
            if mapping is None:
                devs = list(s.devices)
            else:
                devs = [mapping[d] for d in s.devices if d in mapping]
            if not devs:
                return None     # the whole stage departed
            st = cm.make_stage(list(s.node_ids), devs)
            if not cm.memory_feasible(st, self.qoe, n_stages_hint=4):
                return None     # survivors can't absorb the lost device
            stages.append(st)
        new = cm.evaluate(stages, self.qoe, part.config.schedule)
        new.meta["warm"] = True
        w_prev = plan.meta.get("chunks")
        if isinstance(w_prev, int):
            new.meta["chunks"] = w_prev
        return new
