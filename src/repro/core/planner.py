"""Algorithm 1 — QoE-aware hybrid parallelism planner (end-to-end)."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

from .adapter import AdapterConfig, RuntimeAdapter, pareto_filter
from .cost_model import CostProvider, Workload, resolve_costs
from .device import Topology
from .partitioner import ModelPartitioner, PartitionerConfig
from .planning_graph import ModelGraph
from .plans import ParallelismPlan
from .qoe import QoESpec
from .scheduler import NetworkScheduler, SchedulerConfig


@dataclasses.dataclass
class PlanningResult:
    best: ParallelismPlan
    candidates: List[ParallelismPlan]       # Phase-2 refined, ranked
    pareto: List[ParallelismPlan]           # for the runtime adapter
    phase1_s: float
    phase2_s: float

    @property
    def total_s(self) -> float:
        return self.phase1_s + self.phase2_s


class DoraPlanner:
    """ParallelismPlanner(G_M, D) per Algorithm 1."""

    def __init__(self, graph: ModelGraph, topo: Topology, qoe: QoESpec,
                 partitioner_config: Optional[PartitionerConfig] = None,
                 scheduler_config: Optional[SchedulerConfig] = None,
                 adapter_config: Optional[AdapterConfig] = None,
                 costs: Optional[CostProvider] = None):
        self.graph = graph
        self.costs = resolve_costs(costs)
        # the whole stack (partitioner, scheduler, adapter) plans against
        # the provider's view of the hardware — analytic by default,
        # measurement-calibrated with ProfiledCosts
        self.topo = self.costs.calibrate(topo)
        self.qoe = qoe
        self.partitioner = ModelPartitioner(self.graph, self.topo, qoe,
                                            partitioner_config)
        self.scheduler = NetworkScheduler(self.topo, qoe, scheduler_config)
        self.adapter_config = adapter_config

    def plan(self, workload: Workload) -> PlanningResult:
        t0 = time.perf_counter()
        pool = self.partitioner.plan(workload, pool=True)  # lines 2-3 (top-K pool)
        t1 = time.perf_counter()
        refined = self.scheduler.refine_candidates(        # line 4
            pool, keep=self.partitioner.config.top_k)
        t2 = time.perf_counter()
        if not refined:
            raise RuntimeError("no QoE-feasible plan found")
        return PlanningResult(best=refined[0], candidates=refined,
                              pareto=pareto_filter(refined),
                              phase1_s=t1 - t0, phase2_s=t2 - t1)

    def make_adapter(self, result: PlanningResult) -> RuntimeAdapter:
        return RuntimeAdapter(result.candidates, self.topo, self.qoe,
                              self.scheduler, self.adapter_config)
