"""Hybrid-parallelism plan datatypes (§4.1's ``G_P``)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .planning_graph import ModelGraph


@dataclasses.dataclass
class Stage:
    """One pipeline stage: a model subgraph on a data-parallel device group.

    ``microbatch_split[d]`` is the fraction of every microbatch device
    ``d`` processes (§4.1's load-balance rule; fractions sum to 1).
    """

    node_ids: List[int]
    devices: List[int]
    microbatch_split: Dict[int, float]
    tp_degree: int = 1

    # filled by the cost model
    fwd_time: float = 0.0            # per-microbatch forward time (incl. send)
    bwd_time: float = 0.0            # per-microbatch backward time (incl. send)
    comm_bytes_out: float = 0.0      # activation bytes sent downstream per microbatch
    sync_bytes: float = 0.0          # gradient all-reduce bytes per device
    param_bytes: float = 0.0
    flops_fwd: float = 0.0           # per microbatch
    flops_bwd: float = 0.0
    # per-sample recurrent/KV state bytes; None on hand-built stages
    # (the cost model then re-derives it from the graph)
    state_bytes: Optional[float] = None

    @property
    def dp_degree(self) -> int:
        return len(self.devices)


@dataclasses.dataclass
class ParallelismPlan:
    """A complete plan: ordered pipeline stages + microbatching."""

    stages: List[Stage]
    microbatch_size: int
    n_microbatches: int
    training: bool = True

    # evaluated metrics (cost model / scheduler / simulator fill these)
    latency: float = 0.0                 # end-to-end iteration (or token) latency, sec
    energy: float = 0.0                  # total J per iteration across devices
    per_device_energy: Dict[int, float] = dataclasses.field(default_factory=dict)
    per_device_memory: Dict[int, float] = dataclasses.field(default_factory=dict)
    objective: float = 0.0               # Eq. (2) value
    schedule: Optional[object] = None    # Phase-2 refined schedule (core.scheduler)
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def devices(self) -> List[int]:
        out: List[int] = []
        for s in self.stages:
            out.extend(s.devices)
        return out

    def device_param_bytes(self) -> Dict[int, float]:
        """Parameter bytes resident per device (for delta-switching §4.3)."""
        out: Dict[int, float] = {}
        for s in self.stages:
            per_dev = s.param_bytes / max(s.tp_degree, 1)
            for d in s.devices:
                out[d] = out.get(d, 0.0) + per_dev
        return out

    def device_layers(self) -> Dict[int, frozenset]:
        """Which planning-graph nodes each device hosts (delta switching)."""
        out: Dict[int, frozenset] = {}
        for s in self.stages:
            ids = frozenset(s.node_ids)
            for d in s.devices:
                out[d] = out.get(d, frozenset()) | ids
        return out

    def summary(self) -> str:
        parts = []
        for i, s in enumerate(self.stages):
            parts.append(
                f"stage{i}[nodes={len(s.node_ids)} devs={s.devices} dp={s.dp_degree} tp={s.tp_degree}]")
        return (f"Plan(mb={self.microbatch_size}x{self.n_microbatches}, "
                f"lat={self.latency * 1e3:.1f}ms, E={self.energy:.2f}J, "
                f"obj={self.objective:.2f}): " + " -> ".join(parts))
