"""Phase 1 — heterogeneity- and QoE-aware model partitioner (§4.1).

Graph-level dynamic programming over the serial-decomposed planning
graph, per Eqs. (3)-(5):

  Q1(j,l,s,n) — first j-1 chains + first l layers of chain j in s stages
                on the first n devices;
  Q2(j,k,s,n) — chains k..j bundled into one stage, preceding k-1 chains
                in s-1 stages, all on the first n devices;
  Q(j,s,n)    — min(Q1(j,L_j,s,n), min_k Q2(j,k,s,n)).

Every DP cell keeps the **top-K** partial plans (the paper's insight:
the contention-aware optimum stays near the top of the contention-free
ranking), evaluated with the Lagrangian objective of Eq. (2).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from .cost_model import CostModel, Workload
from .device import Topology
from .planning_graph import ModelGraph
from .plans import ParallelismPlan, Stage
from .qoe import QoESpec


@dataclasses.dataclass(frozen=True)
class _Partial:
    stages: Tuple[Stage, ...]
    comm_f: Tuple[float, ...]       # per-boundary activation transfer times
    energy: float                   # running compute+comm energy estimate
    sum_t: float                    # Σ (bf+bb) over stages
    max_t: float                    # max (bf+bb) over stages
    sync_t: float = 0.0             # max contention-free gradient-sync time

    def key(self, qoe: QoESpec, n_micro: int, mode: str = "e2e") -> float:
        if mode == "throughput":
            # cloud-planner objective (L2): steady-state iteration rate —
            # bottleneck stage + contention-free sync; pipeline fill/drain,
            # per-message latency and contention are invisible to it.
            return n_micro * self.max_t + self.sync_t
        lat_est = (n_micro - 1) * self.max_t + self.sum_t + 2 * sum(self.comm_f)
        return qoe.objective(self.energy, lat_est)


@dataclasses.dataclass
class PartitionerConfig:
    top_k: int = 4
    max_stages: Optional[int] = None
    delta: float = 0.05                       # Δ-merge threshold
    schedule: str = "1f1b"
    device_orderings: Sequence[str] = ("fast_first", "slow_first")
    microbatch_sizes: Sequence[int] = ()      # empty -> use workload's
    objective_mode: str = "e2e"               # "e2e" (Dora) | "throughput" (L2 baselines)


class ModelPartitioner:
    def __init__(self, graph: ModelGraph, topo: Topology, qoe: QoESpec,
                 config: Optional[PartitionerConfig] = None):
        self.config = config or PartitionerConfig()
        self.raw_graph = graph
        self.graph = graph.compress(self.config.delta)
        self.topo = topo
        self.qoe = qoe
        self.chains = self.graph.serial_decompose()

    # -- public ------------------------------------------------------------------
    def plan(self, workload: Workload,
             pool: bool = False) -> List[ParallelismPlan]:
        """Return the top-K QoE-compliant candidate plans (Alg. 1 lines 2-3).

        ``pool=True`` returns the wider DP pool (≤ 8·K plans) for Phase-2
        re-ranking under real contention — the paper's 'tunable search
        space' knob (Fig. 13)."""
        mb_sizes = list(self.config.microbatch_sizes) or [workload.microbatch_size]
        candidates: List[ParallelismPlan] = []
        for mb in mb_sizes:
            if workload.global_batch % mb:
                continue
            wl = dataclasses.replace(workload, microbatch_size=mb)
            candidates.extend(self._plan_one(wl))
        candidates.sort(key=self._rank_key)
        candidates = self._dedupe(candidates)
        if pool:
            return self._diverse_top(candidates, 8 * self.config.top_k)
        return self._diverse_top(candidates, self.config.top_k)

    def _rank_key(self, p: ParallelismPlan) -> float:
        if self.config.objective_mode == "throughput":
            # rate-optimal ranking: steady-state iteration time =
            # microbatches × bottleneck stage + contention-free gradient
            # sync. Blind to pipeline fill/drain, per-message latency and
            # link contention — the L2 failure mode.
            bott = max(s.fwd_time + s.bwd_time for s in p.stages)
            sync = 0.0
            for s in p.stages:
                if s.sync_bytes > 0 and s.dp_degree > 1:
                    bw = min(self.topo.peak_bandwidth(i, j)
                             for i in s.devices for j in s.devices if i != j)
                    sync = max(sync, s.sync_bytes / bw)
            return p.n_microbatches * bott + sync
        return p.objective

    @staticmethod
    def _diverse_top(plans: List[ParallelismPlan], k: int) -> List[ParallelismPlan]:
        """Top-K candidate selection. The contention-free ranking is only a
        *proxy* (§4.1 — the real-network optimum stays 'near the top'), so
        half the K slots take the outright best plans (rank inversions
        happen within a shape class too) and half cover distinct plan
        shapes (stage count × max DP width × device count × microbatch).
        Phase 2 re-ranks everything under true contention."""
        head = plans[: max(k // 2, 1)]
        chosen = {id(p) for p in head}
        sigs = {(p.n_stages, max(s.dp_degree for s in p.stages),
                 len(set(p.devices)), p.microbatch_size) for p in head}
        out = list(head)
        for p in plans:                       # fill with unseen shapes
            if len(out) >= k:
                break
            sig = (p.n_stages, max(s.dp_degree for s in p.stages),
                   len(set(p.devices)), p.microbatch_size)
            if sig in sigs or id(p) in chosen:
                continue
            out.append(p)
            chosen.add(id(p))
            sigs.add(sig)
        for p in plans:                       # densify with runners-up
            if len(out) >= k:
                break
            if id(p) not in chosen:
                out.append(p)
                chosen.add(id(p))
        return out

    # -- DP ----------------------------------------------------------------------
    def _plan_one(self, wl: Workload) -> List[ParallelismPlan]:
        cm = CostModel(self.graph, self.topo, wl)
        out: List[ParallelismPlan] = []
        for ordering in self.config.device_orderings:
            devices = self._order_devices(ordering)
            out.extend(self._dp(cm, wl, devices))
        return out

    def _order_devices(self, ordering: str) -> List[int]:
        idx = list(range(self.topo.n))
        speed = lambda d: self.topo.devices[d].effective_flops()
        if ordering == "fast_first":
            idx.sort(key=speed, reverse=True)
        elif ordering == "slow_first":
            idx.sort(key=speed)
        return idx

    def _dp(self, cm: CostModel, wl: Workload, dev_order: List[int]) -> List[ParallelismPlan]:
        K = self.config.top_k
        N = len(dev_order)
        J = len(self.chains)
        S_max = self.config.max_stages or min(N, len(self.graph.nodes))
        M = wl.n_microbatches
        qoe = self.qoe
        mode = self.config.objective_mode
        stage_cache: Dict[Tuple, Optional[Stage]] = {}

        def block(n0: int, n1: int) -> List[int]:
            return [dev_order[i] for i in range(n0, n1)]

        def make_stage(node_ids: Tuple[int, ...], n0: int, n1: int) -> Optional[Stage]:
            key = (node_ids, n0, n1)
            if key not in stage_cache:
                st = cm.make_stage(list(node_ids), block(n0, n1))
                if not cm.memory_feasible(st, qoe, n_stages_hint=4):
                    st = None
                stage_cache[key] = st
            return stage_cache[key]

        def extend(p: _Partial, st: Stage) -> _Partial:
            comm_t = 0.0
            if p.stages:
                prev = p.stages[-1]
                pairs = [(i, j) for i in prev.devices for j in st.devices if i != j]
                if pairs:
                    bw = min(self.topo.peak_bandwidth(i, j) for i, j in pairs)
                    comm_t = prev.comm_bytes_out / bw
            sync_t = p.sync_t
            if st.sync_bytes > 0 and st.dp_degree > 1:
                bw = min(self.topo.peak_bandwidth(i, j)
                         for i in st.devices for j in st.devices if i != j)
                sync_t = max(sync_t, st.sync_bytes / bw)
            e = p.energy + self._stage_energy(st, M)
            t = st.fwd_time + st.bwd_time
            return _Partial(p.stages + (st,), p.comm_f + ((comm_t,) if p.stages else ()),
                            e, p.sum_t + t, max(p.max_t, t), sync_t)

        def push(cell: List[_Partial], cand: _Partial) -> None:
            cell.append(cand)
            cell.sort(key=lambda q: q.key(qoe, M, mode))
            del cell[K:]

        empty = _Partial((), (), 0.0, 0.0, 0.0)
        # Q[(j, s, n)] / Q1[(j, l, s, n)] hold top-K partials
        Q: Dict[Tuple[int, int, int], List[_Partial]] = {(0, 0, n): [empty] for n in range(N + 1)}
        Q[(0, 0, 0)] = [empty]
        final: List[_Partial] = []

        for j in range(1, J + 1):
            chain = self.chains[j - 1]
            L = len(chain)
            Q1: Dict[Tuple[int, int, int], List[_Partial]] = {}
            for s in range(0, S_max + 1):
                for n in range(0, N + 1):
                    # base: Q1(j, 0, s, n) = Q(j-1, s, n)
                    prev = Q.get((j - 1, s, n))
                    if prev:
                        Q1[(0, s, n)] = list(prev)
            for s in range(1, S_max + 1):
                for n in range(1, N + 1):
                    for l in range(1, L + 1):
                        cell: List[_Partial] = []
                        # Eq. (3): extend with a stage of layers l'+1..l on devices n'+1..n
                        for lp in range(0, l):
                            seg = tuple(chain[lp:l])
                            for np_ in range(0, n):
                                st = make_stage(seg, np_, n)
                                if st is None:
                                    continue
                                for p in Q1.get((lp, s - 1, np_), ()):  # noqa: B020
                                    push(cell, extend(p, st))
                        if cell:
                            Q1[(l, s, n)] = cell
                    # Eq. (4)+(5): Q(j, s, n)
                    qcell: List[_Partial] = list(Q1.get((L, s, n), ()))
                    for k in range(1, j + 1):
                        bundle = tuple(itertools.chain.from_iterable(
                            self.chains[t] for t in range(k - 1, j)))
                        for np_ in range(0, n):
                            st = make_stage(bundle, np_, n)
                            if st is None:
                                continue
                            for p in Q.get((k - 1, s - 1, np_), ()):  # noqa: B020
                                push(qcell, extend(p, st))
                    if qcell:
                        qcell.sort(key=lambda q: q.key(qoe, M, mode))
                        Q[(j, s, n)] = qcell[:K]
            # allow chain j to end at any s/n — final candidates come from j == J
        for s in range(1, S_max + 1):
            for n in range(1, N + 1):
                final.extend(Q.get((J, s, n), ()))

        plans: List[ParallelismPlan] = []
        for p in final:
            if not p.stages:
                continue
            plan = cm.evaluate(list(p.stages), qoe, self.config.schedule)
            plan.meta["dev_order"] = tuple(dev_order)
            plans.append(plan)
        plans.sort(key=self._rank_key)
        return plans[: 4 * K]

    def _stage_energy(self, st: Stage, n_micro: int) -> float:
        e = 0.0
        for d in st.devices:
            dev = self.topo.devices[d]
            share = st.microbatch_split[d]
            fl = (st.flops_fwd + st.flops_bwd) * n_micro * share / max(st.tp_degree, 1)
            e += dev.compute_energy(fl)
            e += dev.e_byte * (st.comm_bytes_out * n_micro * share + st.sync_bytes)
        return e

    @staticmethod
    def _dedupe(plans: List[ParallelismPlan]) -> List[ParallelismPlan]:
        seen = set()
        out = []
        for p in plans:
            sig = tuple((tuple(s.node_ids), tuple(s.devices)) for s in p.stages) \
                + (p.microbatch_size,)
            if sig in seen:
                continue
            seen.add(sig)
            out.append(p)
        return out
