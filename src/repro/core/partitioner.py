"""Phase 1 — heterogeneity- and QoE-aware model partitioner (§4.1).

Graph-level dynamic programming over the serial-decomposed planning
graph, per Eqs. (3)-(5):

  Q1(j,l,s,n) — first j-1 chains + first l layers of chain j in s stages
                on the first n devices;
  Q2(j,k,s,n) — chains k..j bundled into one stage, preceding k-1 chains
                in s-1 stages, all on the first n devices;
  Q(j,s,n)    — min(Q1(j,L_j,s,n), min_k Q2(j,k,s,n)).

Every DP cell keeps the **top-K** partial plans (the paper's insight:
the contention-aware optimum stays near the top of the contention-free
ranking), evaluated with the Lagrangian objective of Eq. (2).

Hot-path structure (plan-parity preserving — golden tests lock the
output): every candidate stage is a contiguous slice of one fixed
serialization of the chains, so stages are priced in O(1) via
:class:`~.cost_model.SegmentAggregates` prefix sums and cached by
``(segment span, device span)``; DP cells are bounded max-heaps keyed
on the partial's precomputed objective (plus an insertion counter that
reproduces the old stable-sort tie order exactly); and because the
objective is monotone under extension, a partial whose own key already
exceeds a full cell's K-th best is pruned without pricing the child
(the cells are read in ascending key order, so the scan breaks early).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost_model import CostModel, SegmentAggregates, Workload
from .device import Topology
from .planning_graph import ModelGraph
from .plans import ParallelismPlan, Stage
from .qoe import QoESpec


class _StageInfo:
    """One feasible candidate stage, as the scalars the DP loop reads:
    per-microbatch time, energy (for the workload's microbatch count),
    contention-free gradient-sync time, boundary activation bytes, the
    (segment, device-block) spans, and ``min_key`` — a lower bound on
    the key of *any* partial ending in this stage (the objective is
    monotone, so a stage whose bound already exceeds a full cell's K-th
    best prunes every extension).  The scalars come from the vectorized
    segment×block tables; the actual :class:`Stage` object is only
    materialized (``stage``) for partials that reach the final ranking.
    """

    __slots__ = ("stage", "lo", "hi", "n0", "n1", "t", "energy", "sync_t",
                 "comm_out", "min_key")

    def __init__(self, lo: int, hi: int, n0: int, n1: int, t: float,
                 energy: float, sync_t: float, comm_out: float,
                 min_key: float):
        self.stage: Optional[Stage] = None
        self.lo = lo
        self.hi = hi
        self.n0 = n0
        self.n1 = n1
        self.t = t
        self.energy = energy
        self.sync_t = sync_t
        self.comm_out = comm_out
        self.min_key = min_key


class _Partial:
    """One DP partial plan (plain ``__slots__`` class: these are created
    tens of thousands of times per planning call)."""

    __slots__ = ("stages", "comm_sum", "energy", "sum_t", "max_t", "sync_t",
                 "key", "seq", "last")

    def __init__(self, stages: Tuple[_StageInfo, ...], comm_sum: float,
                 energy: float, sum_t: float, max_t: float, sync_t: float,
                 key: float, seq: int, last: Optional[_StageInfo]):
        self.stages = stages
        self.comm_sum = comm_sum    # Σ per-boundary activation transfer times
        self.energy = energy        # running compute+comm energy estimate
        self.sum_t = sum_t          # Σ (bf+bb) over stages
        self.max_t = max_t          # max (bf+bb) over stages
        self.sync_t = sync_t        # max contention-free gradient-sync time
        self.key = key              # ranking objective (monotone under extend)
        self.seq = seq              # creation counter: stable tie order
        self.last = last            # info of the final stage (comm pricing)


@dataclasses.dataclass
class PartitionerConfig:
    top_k: int = 4
    max_stages: Optional[int] = None
    delta: float = 0.05                       # Δ-merge threshold
    schedule: str = "1f1b"
    device_orderings: Sequence[str] = ("fast_first", "slow_first")
    microbatch_sizes: Sequence[int] = ()      # empty -> use workload's
    objective_mode: str = "e2e"               # "e2e" (Dora) | "throughput" (L2 baselines)


class ModelPartitioner:
    def __init__(self, graph: ModelGraph, topo: Topology, qoe: QoESpec,
                 config: Optional[PartitionerConfig] = None):
        self.config = config or PartitionerConfig()
        self.raw_graph = graph
        self.graph = graph.compress(self.config.delta)
        self.topo = topo
        self.qoe = qoe
        self.chains = self.graph.serial_decompose()
        # fixed serialization of the chains: every DP stage (chain slice
        # or bundle of adjacent chains) is a contiguous span of it
        self._serial: List[int] = [i for c in self.chains for i in c]
        self._offs: List[int] = [0]
        for c in self.chains:
            self._offs.append(self._offs[-1] + len(c))
        self._agg = SegmentAggregates(self.graph, self._serial)
        # pairwise peak-bandwidth matrix (lazy): DP block-min inputs
        self._peak_bw: Dict[Tuple[int, int], float] = {}

    # -- public ------------------------------------------------------------------
    def plan(self, workload: Workload,
             pool: bool = False) -> List[ParallelismPlan]:
        """Return the top-K QoE-compliant candidate plans (Alg. 1 lines 2-3).

        ``pool=True`` returns the wider DP pool (≤ 8·K plans) for Phase-2
        re-ranking under real contention — the paper's 'tunable search
        space' knob (Fig. 13)."""
        mb_sizes = list(self.config.microbatch_sizes) or [workload.microbatch_size]
        candidates: List[ParallelismPlan] = []
        for mb in mb_sizes:
            if workload.global_batch % mb:
                continue
            wl = dataclasses.replace(workload, microbatch_size=mb)
            candidates.extend(self._plan_one(wl))
        candidates.sort(key=self._rank_key)
        candidates = self._dedupe(candidates)
        if pool:
            return self._diverse_top(candidates, 8 * self.config.top_k)
        return self._diverse_top(candidates, self.config.top_k)

    def _rank_key(self, p: ParallelismPlan) -> float:
        if self.config.objective_mode == "throughput":
            # rate-optimal ranking: steady-state iteration time =
            # microbatches × bottleneck stage + contention-free gradient
            # sync. Blind to pipeline fill/drain, per-message latency and
            # link contention — the L2 failure mode.
            bott = max(s.fwd_time + s.bwd_time for s in p.stages)
            sync = 0.0
            for s in p.stages:
                if s.sync_bytes > 0 and s.dp_degree > 1:
                    bw = min(self.topo.peak_bandwidth(i, j)
                             for i in s.devices for j in s.devices if i != j)
                    sync = max(sync, s.sync_bytes / bw)
            return p.n_microbatches * bott + sync
        return p.objective

    @staticmethod
    def _diverse_top(plans: List[ParallelismPlan], k: int) -> List[ParallelismPlan]:
        """Top-K candidate selection. The contention-free ranking is only a
        *proxy* (§4.1 — the real-network optimum stays 'near the top'), so
        half the K slots take the outright best plans (rank inversions
        happen within a shape class too) and half cover distinct plan
        shapes (stage count × max DP width × device count × microbatch).
        Phase 2 re-ranks everything under true contention."""
        head = plans[: max(k // 2, 1)]
        chosen = {id(p) for p in head}
        sigs = {(p.n_stages, max(s.dp_degree for s in p.stages),
                 len(set(p.devices)), p.microbatch_size) for p in head}
        out = list(head)
        for p in plans:                       # fill with unseen shapes
            if len(out) >= k:
                break
            sig = (p.n_stages, max(s.dp_degree for s in p.stages),
                   len(set(p.devices)), p.microbatch_size)
            if sig in sigs or id(p) in chosen:
                continue
            out.append(p)
            chosen.add(id(p))
            sigs.add(sig)
        for p in plans:                       # densify with runners-up
            if len(out) >= k:
                break
            if id(p) not in chosen:
                out.append(p)
                chosen.add(id(p))
        return out

    # -- DP ----------------------------------------------------------------------
    def _plan_one(self, wl: Workload) -> List[ParallelismPlan]:
        cm = CostModel(self.graph, self.topo, wl)
        out: List[ParallelismPlan] = []
        for ordering in self.config.device_orderings:
            devices = self._order_devices(ordering)
            out.extend(self._dp(cm, wl, devices))
        return out

    def _order_devices(self, ordering: str) -> List[int]:
        idx = list(range(self.topo.n))
        speed = lambda d: self.topo.devices[d].effective_flops()
        if ordering == "fast_first":
            idx.sort(key=speed, reverse=True)
        elif ordering == "slow_first":
            idx.sort(key=speed)
        return idx

    def _pair_bw(self, i: int, j: int) -> float:
        bw = self._peak_bw.get((i, j))
        if bw is None:
            bw = self.topo.peak_bandwidth(i, j)
            self._peak_bw[(i, j)] = bw
        return bw

    def _dp(self, cm: CostModel, wl: Workload, dev_order: List[int]) -> List[ParallelismPlan]:
        K = self.config.top_k
        N = len(dev_order)
        J = len(self.chains)
        S_max = self.config.max_stages or min(N, len(self.graph.nodes))
        M = wl.n_microbatches
        qoe = self.qoe
        mode = self.config.objective_mode
        offs = self._offs
        agg = self._agg
        stage_cache: Dict[Tuple[int, int, int, int], Optional[_StageInfo]] = {}
        cross_bw: Dict[Tuple[int, int, int, int], float] = {}
        intra_bw: Dict[Tuple[int, int], float] = {}
        seq = 0

        if mode == "throughput":
            def key_of(energy: float, sum_t: float, max_t: float,
                       comm_sum: float, sync_t: float) -> float:
                # cloud-planner objective (L2): steady-state iteration
                # rate — bottleneck stage + contention-free sync;
                # pipeline fill/drain, per-message latency and
                # contention are invisible to it.
                return M * max_t + sync_t
        else:
            # Eq. (2) inlined (`qoe.objective` on the contention-free
            # latency estimate): the λ·0 branch is algebraically the
            # bare energy, so the values are bit-identical
            lam, t_qoe = qoe.lam, qoe.t_qoe

            def key_of(energy: float, sum_t: float, max_t: float,
                       comm_sum: float, sync_t: float) -> float:
                lat_est = (M - 1) * max_t + sum_t + 2 * comm_sum
                if lat_est > t_qoe:
                    return energy + lam * (lat_est - t_qoe)
                return energy

        def block(n0: int, n1: int) -> List[int]:
            return [dev_order[i] for i in range(n0, n1)]

        def block_pair_bw(a0: int, a1: int, b0: int, b1: int) -> float:
            """min peak bandwidth across two disjoint device blocks."""
            bw = cross_bw.get((a0, a1, b0, b1))
            if bw is None:
                bw = min(self._pair_bw(i, j)
                         for i in dev_order[a0:a1] for j in dev_order[b0:b1])
                cross_bw[(a0, a1, b0, b1)] = bw
            return bw

        mem_mult = wl.optimizer_mult if wl.training else 1.0
        training = wl.training
        b = wl.microbatch_size
        gc = wl.grad_compression
        devices = self.topo.devices
        m_qoe = qoe.m_qoe
        in_flight = min(M, 4)       # memory_feasible's n_stages_hint=4, 1f1b
        Lt = len(self._serial)
        W = Lt + 1                  # flat segment index: lo * W + hi

        # -- vectorized segment×block stage tables --------------------------
        # Every candidate stage's scalars (time, energy, sync time, memory
        # feasibility, pruning bound) are computed for ALL segments of a
        # device block in one numpy pass, bit-identical to pricing each
        # stage through `CostModel._build_stage` + `_stage_energy` +
        # `memory_feasible`: per-device reductions stay scalar loops in
        # device order (preserving float association) and only the
        # segment dimension is vectorized.  Stage *objects* are no longer
        # built during the DP at all — see the finals materialization.
        ffb = np.zeros(W * W)       # flops_fwd · b   per segment
        fbb = np.zeros(W * W)       # flops_bwd · b   (zeros when serving)
        pb_ = np.zeros(W * W)       # param bytes     per segment
        actb = np.zeros(W * W)      # boundary activation · b
        stmem = np.zeros(W * W)     # state bytes · b (stage_memory's term)
        for lo in range(Lt):
            for hi in range(lo + 1, Lt + 1):
                ff, fb, pb, sb = agg.segment(lo, hi)
                i = lo * W + hi
                ffb[i] = ff * b
                if training:
                    fbb[i] = fb * b
                pb_[i] = pb
                actb[i] = agg.boundary_act_bytes(hi) * b
                stmem[i] = sb * b
        flo = ffb + fbb             # flops_fwd + flops_bwd (stage fields)
        act_mem = actb * in_flight  # in-flight activation bytes
        act_m = actb * M            # per-iteration activation traffic

        tables: Dict[Tuple[int, int], tuple] = {}

        def block_table(n0: int, n1: int) -> tuple:
            """(t, energy, sync_t, min_key, feasible) lists over the flat
            segment index, for stages on device block (n0, n1)."""
            tb = tables.get((n0, n1))
            if tb is not None:
                return tb
            devs = [dev_order[i] for i in range(n0, n1)]
            g = len(devs)
            tp = devices[devs[0]].n_accel if g == 1 else 1
            tp_ = max(tp, 1)
            eff = cm._eff
            speeds = []
            for d in devs:
                v = eff.get((d, tp))
                if v is None:
                    v = devices[d].effective_flops(tp)
                    eff[(d, tp)] = v
                speeds.append(v)
            total = sum(speeds)
            split = [v / total for v in speeds]
            membw = min(devices[d].mem_bw for d in devs)
            # time: roofline max over devices == division by the block's
            # min memory bandwidth (monotone float division)
            w_read = pb_ / tp_
            t = np.maximum(ffb / total, w_read / membw)
            if training:
                t = t + np.maximum(fbb / total, 2.0 * w_read / membw)
            # gradient-sync bytes/time (ring all-reduce per device)
            if training and g > 1:
                sy = 2.0 * pb_ * (g - 1) / g * gc
                bw = intra_bw.get((n0, n1))
                if bw is None:
                    bw = min(self._pair_bw(i, j)
                             for i in devs for j in devs if i != j)
                    intra_bw[(n0, n1)] = bw
                sy_t = np.where(sy > 0.0, sy / bw, 0.0)
            else:
                sy = np.zeros(W * W)
                sy_t = sy
            # energy (`_stage_energy`): two adds per device, device order
            e = np.zeros(W * W)
            for d, share in zip(devs, split):
                dev = devices[d]
                e = e + flo * M * share / tp_ * dev.e_flop
                e = e + dev.e_byte * (act_m * share + sy)
            # memory feasibility (`stage_memory` at n_stages_hint=4)
            ppd = pb_ * mem_mult / tp_
            feas = np.ones(W * W, dtype=bool)
            for d, share in zip(devs, split):
                cap = devices[d].memory
                if m_qoe is not None:
                    cap = min(cap, m_qoe)
                feas &= ~(ppd + act_mem * share + stmem > cap)
            # min_key: key_of(energy, t, t, 0, 0) — a floor for any
            # partial ending in this stage
            if mode == "throughput":
                mk = M * t + sy_t
            else:
                lat = (M - 1) * t + t
                mk = np.where(lat > t_qoe, e + lam * (lat - t_qoe), e)
            tb = (t.tolist(), e.tolist(), sy_t.tolist(), mk.tolist(),
                  feas.tolist())
            tables[(n0, n1)] = tb
            return tb

        act_list = actb.tolist()

        def stage_info(lo: int, hi: int, n0: int, n1: int
                       ) -> Optional[_StageInfo]:
            key = (lo, hi, n0, n1)
            info = stage_cache.get(key, False)
            if info is not False:
                return info
            t, e, sy_t, mk, feas = block_table(n0, n1)
            i = lo * W + hi
            if not feas[i]:
                info = None
            else:
                info = _StageInfo(lo, hi, n0, n1, t[i], e[i], sy_t[i],
                                  act_list[i], mk[i])
            stage_cache[key] = info
            return info

        heappush, heapreplace = heapq.heappush, heapq.heapreplace

        def extend_cell(cell: List[tuple], src: List[_Partial],
                        info: _StageInfo) -> None:
            """Push every useful extension of ``src``'s partials by
            ``info`` into the bounded max-heap ``cell``.

            The cell keeps the K best partials by (key, creation order)
            — the same set and tie order a sort-per-insert kept, at
            O(log K) per insert — and children are only *materialized*
            (stage-tuple concat + dataclass) once their key is known to
            make the cut.  ``src`` is key-sorted and the key is monotone
            under extension, so the scan breaks at the first partial
            that can no longer beat the cell's K-th best.
            """
            nonlocal seq
            for p in src:
                full = len(cell) == K
                if full:
                    worst = -cell[0][0]
                    if p.key >= worst or info.min_key >= worst:
                        break
                comm_sum = p.comm_sum
                last = p.last
                if last is not None:
                    comm_sum = comm_sum + last.comm_out / block_pair_bw(
                        last.n0, last.n1, info.n0, info.n1)
                sync_t = p.sync_t if info.sync_t <= p.sync_t else info.sync_t
                e = p.energy + info.energy
                t = info.t
                sum_t = p.sum_t + t
                max_t = p.max_t if t <= p.max_t else t
                k = key_of(e, sum_t, max_t, comm_sum, sync_t)
                seq += 1
                negk = -k
                if not full:
                    heappush(cell, (negk, -seq, _Partial(
                        p.stages + (info,), comm_sum, e, sum_t, max_t,
                        sync_t, k, seq, info)))
                elif negk > cell[0][0]:
                    # ties on key never displace: the incumbent was
                    # created earlier (smaller seq) and wins the tiebreak
                    heapreplace(cell, (negk, -seq, _Partial(
                        p.stages + (info,), comm_sum, e, sum_t, max_t,
                        sync_t, k, seq, info)))

        def finalize(cell: List[tuple]) -> List[_Partial]:
            cell.sort(reverse=True)           # (key, seq) ascending
            return [it[2] for it in cell]

        empty = _Partial((), 0.0, 0.0, 0.0, 0.0, 0.0,
                         key_of(0.0, 0.0, 0.0, 0.0, 0.0), 0, None)
        # Q[(j, s, n)] / Q1[(j, l, s, n)] hold the top-K partials, in
        # ascending (key, seq) order
        Q: Dict[Tuple[int, int, int], List[_Partial]] = \
            {(0, 0, n): [empty] for n in range(N + 1)}
        final: List[_Partial] = []

        for j in range(1, J + 1):
            off = offs[j - 1]
            L = offs[j] - off
            Q1: Dict[Tuple[int, int, int], List[_Partial]] = {}
            for s in range(0, S_max + 1):
                for n in range(0, N + 1):
                    # base: Q1(j, 0, s, n) = Q(j-1, s, n)
                    prev = Q.get((j - 1, s, n))
                    if prev:
                        Q1[(0, s, n)] = prev
            for s in range(1, S_max + 1):
                for n in range(1, N + 1):
                    for l in range(1, L + 1):
                        cell: List[tuple] = []
                        # Eq. (3): extend with a stage of layers l'+1..l on devices n'+1..n
                        for lp in range(0, l):
                            for np_ in range(0, n):
                                src = Q1.get((lp, s - 1, np_))
                                if not src:
                                    continue
                                if len(cell) == K and src[0].key >= -cell[0][0]:
                                    continue    # even src's best is pruned
                                info = stage_info(off + lp, off + l, np_, n)
                                if info is not None:
                                    extend_cell(cell, src, info)
                        if cell:
                            Q1[(l, s, n)] = finalize(cell)
                    # Eq. (4)+(5): Q(j, s, n)
                    base = Q1.get((L, s, n))
                    qcell: List[tuple] = \
                        [(-p.key, -p.seq, p) for p in base] if base else []
                    heapq.heapify(qcell)
                    for k in range(1, j + 1):
                        for np_ in range(0, n):
                            src = Q.get((k - 1, s - 1, np_))
                            if not src:
                                continue
                            if len(qcell) == K and src[0].key >= -qcell[0][0]:
                                continue
                            info = stage_info(offs[k - 1], offs[j], np_, n)
                            if info is not None:
                                extend_cell(qcell, src, info)
                    if qcell:
                        Q[(j, s, n)] = finalize(qcell)
            # allow chain j to end at any s/n — final candidates come from j == J
        for s in range(1, S_max + 1):
            for n in range(1, N + 1):
                final.extend(Q.get((J, s, n), ()))

        plans: List[ParallelismPlan] = []
        for p in final:
            if not p.stages:
                continue
            # materialize the real Stage objects (shared across partials
            # that picked the same segment×block, like the old per-DP
            # stage cache) only for partials that reached the finals
            stages: List[Stage] = []
            for inf in p.stages:
                st = inf.stage
                if st is None:
                    st = cm.make_stage_span(agg, inf.lo, inf.hi,
                                            block(inf.n0, inf.n1))
                    inf.stage = st
                stages.append(st)
            plan = cm.evaluate(stages, qoe, self.config.schedule)
            plan.meta["dev_order"] = tuple(dev_order)
            plans.append(plan)
        plans.sort(key=self._rank_key)
        return plans[: 4 * K]

    def _stage_energy(self, st: Stage, n_micro: int) -> float:
        e = 0.0
        for d in st.devices:
            dev = self.topo.devices[d]
            share = st.microbatch_split[d]
            fl = (st.flops_fwd + st.flops_bwd) * n_micro * share / max(st.tp_degree, 1)
            e += dev.compute_energy(fl)
            e += dev.e_byte * (st.comm_bytes_out * n_micro * share + st.sync_bytes)
        return e

    @staticmethod
    def _dedupe(plans: List[ParallelismPlan]) -> List[ParallelismPlan]:
        seen = set()
        out = []
        for p in plans:
            sig = tuple((tuple(s.node_ids), tuple(s.devices)) for s in p.stages) \
                + (p.microbatch_size,)
            if sig in seen:
                continue
            seen.add(sig)
            out.append(p)
        return out
