"""Device, link and topology models for Dora's planner.

The paper plans over heterogeneous edge devices (phones, laptops, edge
servers) joined by contention-prone networks (shared WiFi, wired rings).
``DeviceProfile`` captures compute/memory/energy envelopes; ``Topology``
captures the communication substrate at two fidelities:

* ``peak_bandwidth(i, j)`` — the *contention-free* point-to-point
  bandwidth used by Phase 1's relaxed model (§4.1);
* ``resources_between(i, j)`` — the set of shared resources a transfer
  occupies, used by Phase 2's contention-aware scheduler (§4.2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

MBPS = 1e6 / 8.0  # bytes/sec per Mbps
GBPS = 1e9 / 8.0


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """A single edge device (or TPU slice when planning for pods)."""

    name: str
    flops: float                  # peak FLOP/s (fp16/bf16)
    memory: float                 # bytes of accelerator-visible memory
    mem_bw: float = 50e9          # bytes/sec HBM/LPDDR bandwidth
    e_flop: float = 5e-12         # joules per FLOP at full tilt
    e_byte: float = 30e-9         # joules per network byte (radio/NIC)
    p_idle: float = 2.0           # watts while participating but idle
    n_accel: int = 1              # accelerators per node (TP stays in-node, §4.1)
    tp_efficiency: float = 0.85   # scaling efficiency of in-node TP
    compute_efficiency: float = 0.45  # achievable fraction of peak (MFU-ish)
    #: finite battery budget in joules (None = wall-powered); drained by
    #: the serving kernel's energy attribution when battery tracking is
    #: armed (:class:`repro.control.plane.ControlConfig`)
    battery_j: Optional[float] = None

    def effective_flops(self, tp_degree: int = 1) -> float:
        tp = min(max(tp_degree, 1), self.n_accel)
        eff = self.compute_efficiency * (self.tp_efficiency ** max(tp - 1, 0))
        return self.flops * tp * eff

    def compute_time(self, flops: float, tp_degree: int = 1) -> float:
        if flops <= 0.0:
            return 0.0
        return flops / self.effective_flops(tp_degree)

    def compute_energy(self, flops: float) -> float:
        return flops * self.e_flop


@dataclasses.dataclass(frozen=True)
class LinkResource:
    """A schedulable network resource with a capacity (bytes/sec).

    A shared WiFi medium is one resource that *every* flow between its
    members occupies; a wired p2p link is a resource only its endpoints
    use. The bandwidth-feasibility constraint of Eq. (6) is enforced per
    resource.
    """

    name: str
    capacity: float               # bytes/sec
    members: FrozenSet[int]       # device indices attached
    shared: bool = True           # shared medium vs dedicated pair link
    latency: float = 0.0          # per-message latency (sec): WiFi MAC/RTT


class Topology:
    """Network topology over an ordered set of devices."""

    def __init__(self, devices: Sequence[DeviceProfile],
                 resources: Sequence[LinkResource],
                 p2p: Optional[Dict[Tuple[int, int], List[str]]] = None):
        self.devices = list(devices)
        self.resources = {r.name: r for r in resources}
        # explicit routing table: (i, j) -> list of resource names the
        # transfer traverses. When absent we fall back to any shared
        # medium containing both endpoints.
        self._p2p = dict(p2p or {})
        # route/bandwidth memos — a Topology is immutable after
        # construction (calibration and churn build new instances), and
        # the planner asks for the same pairs millions of times
        self._route_cache: Dict[Tuple[int, int], List[LinkResource]] = {}
        self._bw_cache: Dict[Tuple[int, int], float] = {}
        self._lat_cache: Dict[Tuple[int, int], float] = {}

    # -- construction helpers -------------------------------------------------
    @classmethod
    def shared_medium(cls, devices: Sequence[DeviceProfile], capacity_mbps: float,
                      name: str = "wifi", latency: float = 3e-3) -> "Topology":
        """All devices hang off one shared medium (home WiFi)."""
        res = LinkResource(name=name, capacity=capacity_mbps * MBPS,
                           members=frozenset(range(len(devices))), shared=True,
                           latency=latency)
        return cls(devices, [res])

    @classmethod
    def ring(cls, devices: Sequence[DeviceProfile], link_mbps: float,
             name: str = "ring", latency: float = 0.5e-3) -> "Topology":
        """Wired ring: dedicated links between neighbours; multi-hop
        transfers traverse every intermediate link."""
        n = len(devices)
        resources = []
        for i in range(n):
            j = (i + 1) % n
            resources.append(LinkResource(
                name=f"{name}-{i}-{j}", capacity=link_mbps * MBPS,
                members=frozenset((i, j)), shared=False, latency=latency))
        p2p: Dict[Tuple[int, int], List[str]] = {}
        for i in range(n):
            for j in range(n):
                if i == j:
                    continue
                # take the shorter arc around the ring
                fwd = [(k % n, (k + 1) % n) for k in range(i, i + (j - i) % n)]
                bwd_len = n - (j - i) % n
                bwd = [((k - 1) % n, k % n) for k in range(i, i - bwd_len, -1)]
                hops = fwd if len(fwd) <= len(bwd) else bwd
                p2p[(i, j)] = [f"{name}-{min(a, b)}-{max(a, b)}"
                               if False else _ring_link_name(name, a, b, n)
                               for a, b in hops]
        return cls(devices, resources, p2p)

    @classmethod
    def mixed(cls, devices: Sequence[DeviceProfile],
              resources: Sequence[LinkResource],
              p2p: Optional[Dict[Tuple[int, int], List[str]]] = None) -> "Topology":
        return cls(devices, resources, p2p)

    @classmethod
    def from_edges(cls, devices: Sequence[DeviceProfile],
                   edges: Sequence[Tuple[int, int]], link_mbps: float,
                   name: str = "link", latency: float = 0.5e-3) -> "Topology":
        """Dedicated p2p links along an explicit edge list; every other
        pair routes over a fewest-hops path (multi-hop transfers occupy
        every intermediate link).  The generic constructor behind
        :meth:`star`, :meth:`line` and :meth:`mesh`.  Raises
        ``ValueError`` when the edge list leaves the fleet disconnected
        or references unknown devices.
        """
        n = len(devices)
        resources: List[LinkResource] = []
        adj: Dict[int, Dict[int, str]] = {}
        seen: set = set()
        for a, b in edges:
            if not (0 <= a < n and 0 <= b < n) or a == b:
                raise ValueError(f"bad edge ({a}, {b}) for a {n}-device fleet")
            lo, hi = min(a, b), max(a, b)
            if (lo, hi) in seen:
                continue
            seen.add((lo, hi))
            lname = f"{name}-{lo}-{hi}"
            resources.append(LinkResource(
                name=lname, capacity=link_mbps * MBPS,
                members=frozenset((lo, hi)), shared=False, latency=latency))
            adj.setdefault(lo, {})[hi] = lname
            adj.setdefault(hi, {})[lo] = lname
        p2p: Dict[Tuple[int, int], List[str]] = {}
        for i in range(n):
            for j in range(n):
                if i == j:
                    continue
                route = _shortest_route(adj, i, j)
                if route is None:
                    raise ValueError(
                        f"edge list leaves devices {i} and {j} disconnected")
                p2p[(i, j)] = route
        return cls(devices, resources, p2p)

    @classmethod
    def star(cls, devices: Sequence[DeviceProfile], link_mbps: float,
             name: str = "star", latency: float = 0.5e-3,
             hub: int = 0) -> "Topology":
        """Hub-and-spoke: dedicated hub↔leaf links; leaf↔leaf transfers
        traverse both legs through the hub.  The hub defaults to device
        0 (the partitioner's DP grows plans over device prefixes, so the
        best-connected device should lead)."""
        edges = [(hub, i) for i in range(len(devices)) if i != hub]
        return cls.from_edges(devices, edges, link_mbps, name=name,
                              latency=latency)

    @classmethod
    def line(cls, devices: Sequence[DeviceProfile], link_mbps: float,
             name: str = "hop", latency: float = 0.5e-3) -> "Topology":
        """Multi-hop chain 0–1–…–(n-1): each transfer traverses every
        intermediate link (vehicle convoys, daisy-chained gateways)."""
        edges = [(i, i + 1) for i in range(len(devices) - 1)]
        return cls.from_edges(devices, edges, link_mbps, name=name,
                              latency=latency)

    @classmethod
    def mesh(cls, devices: Sequence[DeviceProfile], link_mbps: float,
             name: str = "mesh", latency: float = 0.5e-3,
             edges: Optional[Sequence[Tuple[int, int]]] = None) -> "Topology":
        """Dedicated pairwise links — a full mesh by default, or a
        partial mesh over an explicit ``edges`` list (missing pairs
        route multi-hop; a disconnected edge list raises
        ``ValueError``)."""
        if edges is None:
            n = len(devices)
            edges = [(i, j) for i in range(n) for j in range(i + 1, n)]
        return cls.from_edges(devices, edges, link_mbps, name=name,
                              latency=latency)

    # -- queries ---------------------------------------------------------------
    def resources_between(self, i: int, j: int) -> List[LinkResource]:
        if i == j:
            return []
        key = (i, j)
        route = self._route_cache.get(key)
        if route is not None:
            return route
        if key in self._p2p:
            route = [self.resources[n] for n in self._p2p[key]]
        else:
            out = []
            for r in self.resources.values():
                if r.shared and i in r.members and j in r.members:
                    out.append(r)
            if not out:
                raise KeyError(f"no route between device {i} and {j}")
            route = [min(out, key=lambda r: -r.capacity)]  # best shared medium
        self._route_cache[key] = route
        return route

    def peak_bandwidth(self, i: int, j: int) -> float:
        """Contention-free peak p2p bandwidth (Phase-1 relaxation)."""
        if i == j:
            return math.inf
        bw = self._bw_cache.get((i, j))
        if bw is None:
            bw = min(r.capacity for r in self.resources_between(i, j))
            self._bw_cache[(i, j)] = bw
        return bw

    def route_latency(self, i: int, j: int) -> float:
        if i == j:
            return 0.0
        lat = self._lat_cache.get((i, j))
        if lat is None:
            lat = sum(r.latency for r in self.resources_between(i, j))
            self._lat_cache[(i, j)] = lat
        return lat

    def transfer_time(self, i: int, j: int, nbytes: float) -> float:
        if i == j or nbytes <= 0.0:
            return 0.0
        return self.route_latency(i, j) + nbytes / self.peak_bandwidth(i, j)

    @property
    def n(self) -> int:
        return len(self.devices)

    def scale_resources(self, factors: Dict[str, float]) -> "Topology":
        """A new topology with link capacities scaled per resource name.

        ``factors`` maps resource names to capacity multipliers (0.5 =
        half the bandwidth); unnamed resources keep their capacity.
        Routing (explicit p2p routes and shared-medium fallbacks) is
        preserved.  The multi-tenant fleet planner uses this to price a
        shared medium at its fluid-fair share when several tenants'
        pipelines transfer over it concurrently.
        """
        bad = [n for n in factors if n not in self.resources]
        if bad:
            raise KeyError(f"unknown resources {sorted(bad)}; topology has "
                           f"{sorted(self.resources)}")
        resources = [dataclasses.replace(r, capacity=r.capacity
                                         * factors.get(r.name, 1.0))
                     for r in self.resources.values()]
        return Topology(self.devices, resources, self._p2p)

    # -- churn (runtime join/leave) --------------------------------------------
    def subset(self, keep: Sequence[int]
               ) -> Tuple["Topology", Dict[int, int]]:
        """The surviving fleet after devices leave (or rejoin).

        ``keep`` — indices *of this topology* that remain. Returns the
        shrunk topology (devices re-indexed ``0..len(keep)-1`` in sorted
        order) plus the old→new index mapping. Link resources keep their
        names (so accumulated ``bandwidth_scale`` entries stay valid)
        but drop departed members; resources left with fewer than two
        members disappear. Explicit routes that traversed a dropped
        resource are re-derived over the surviving links (ring fleets:
        traffic hops the other way around the departed node); a pair
        covered by a surviving shared medium needs no explicit route.
        Raises ``ValueError`` if the surviving fleet is disconnected.
        """
        uniq = sorted(set(keep))
        if not uniq:
            raise ValueError("subset needs at least one device")
        bad = [k for k in uniq if not (0 <= k < self.n)]
        if bad:
            raise ValueError(f"unknown device indices {bad} (fleet has "
                             f"{self.n} devices)")
        mapping = {old: new for new, old in enumerate(uniq)}
        devices = [self.devices[i] for i in uniq]
        resources: List[LinkResource] = []
        for r in self.resources.values():
            members = frozenset(mapping[m] for m in r.members if m in mapping)
            if len(members) >= 2:
                resources.append(dataclasses.replace(r, members=members))
        alive = {r.name for r in resources}
        p2p: Dict[Tuple[int, int], List[str]] = {}
        for (i, j), names in self._p2p.items():
            if i in mapping and j in mapping and all(n in alive for n in names):
                p2p[(mapping[i], mapping[j])] = list(names)
        # re-route pairs whose explicit route died with a departed device
        adj: Dict[int, Dict[int, str]] = {}
        for r in resources:
            for a in r.members:
                for b in r.members:
                    if a != b:
                        adj.setdefault(a, {}).setdefault(b, r.name)
        for i in range(len(devices)):
            for j in range(len(devices)):
                if i == j or (i, j) in p2p:
                    continue
                if any(r.shared and i in r.members and j in r.members
                       for r in resources):
                    continue        # resources_between falls back to it
                route = _shortest_route(adj, i, j)
                if route is None:
                    raise ValueError(
                        f"subset disconnects devices {uniq[i]} and "
                        f"{uniq[j]}: no surviving link or shared medium "
                        f"joins them")
                p2p[(i, j)] = route
        return Topology(devices, resources, p2p), mapping


def _shortest_route(adj: Dict[int, Dict[int, str]], src: int, dst: int
                    ) -> Optional[List[str]]:
    """BFS over link adjacency: the resource names a transfer traverses
    on a fewest-hops path src→dst, or ``None`` if disconnected."""
    prev: Dict[int, Tuple[int, str]] = {}
    frontier = [src]
    seen = {src}
    while frontier and dst not in seen:
        nxt: List[int] = []
        for a in frontier:
            for b, link in adj.get(a, {}).items():
                if b not in seen:
                    seen.add(b)
                    prev[b] = (a, link)
                    nxt.append(b)
        frontier = nxt
    if dst not in prev and dst != src:
        return None
    route: List[str] = []
    cur = dst
    while cur != src:
        cur, link = prev[cur]
        route.append(link)
    return list(reversed(route))


def _ring_link_name(name: str, a: int, b: int, n: int) -> str:
    """Canonical name of the ring link between neighbours a and b."""
    lo, hi = (a, b) if (a + 1) % n == b else (b, a)
    return f"{name}-{lo}-{(lo + 1) % n}"


# ----------------------------------------------------------------------------
# Catalogue: devices from Table 2 and TPU v5e slices for pod planning.
# FLOP/s values are public fp16/bf16 peaks; energy coefficients are derived
# from TDP / peak and calibrated against Figure 3a's order-of-magnitude
# energy-vs-speed spread.
# ----------------------------------------------------------------------------
CATALOG: Dict[str, DeviceProfile] = {
    "s25": DeviceProfile("s25", flops=2.8e12, memory=12e9, mem_bw=77e9,
                         e_flop=2.4e-12, e_byte=40e-9, p_idle=1.2),
    "mi15": DeviceProfile("mi15", flops=2.8e12, memory=12e9, mem_bw=77e9,
                          e_flop=2.4e-12, e_byte=40e-9, p_idle=1.2),
    "genio520": DeviceProfile("genio520", flops=1.6e12, memory=16e9, mem_bw=51e9,
                              e_flop=3.0e-12, e_byte=35e-9, p_idle=2.0),
    "genio720": DeviceProfile("genio720", flops=2.4e12, memory=16e9, mem_bw=68e9,
                              e_flop=2.6e-12, e_byte=35e-9, p_idle=2.2),
    "rtx4050": DeviceProfile("rtx4050", flops=15.0e12, memory=6e9, mem_bw=216e9,
                             e_flop=6.0e-12, e_byte=25e-9, p_idle=14.0),
    "rtx4060": DeviceProfile("rtx4060", flops=20.0e12, memory=8e9, mem_bw=272e9,
                             e_flop=5.8e-12, e_byte=25e-9, p_idle=16.0),
    "rtx4060ti": DeviceProfile("rtx4060ti", flops=22.0e12, memory=8e9, mem_bw=288e9,
                               e_flop=5.9e-12, e_byte=25e-9, p_idle=17.0),
    "v100": DeviceProfile("v100", flops=112.0e12, memory=16e9, mem_bw=900e9,
                          e_flop=2.2e-12, e_byte=15e-9, p_idle=55.0),
    "a40": DeviceProfile("a40", flops=149.0e12, memory=16e9, mem_bw=696e9,
                         e_flop=2.0e-12, e_byte=15e-9, p_idle=60.0),
    # TPU v5e chip as a "device" for pod-level planning (hardware target).
    "v5e": DeviceProfile("v5e", flops=197e12, memory=16e9, mem_bw=819e9,
                         e_flop=1.0e-12, e_byte=5e-9, p_idle=60.0,
                         compute_efficiency=0.55),
}


def make_setting(name: str) -> Topology:
    """The four representative edge settings of Table 3."""
    c = CATALOG
    if name == "smart_home_1":
        devs = [c["rtx4060ti"], c["rtx4060ti"], c["rtx4050"], c["rtx4050"], c["rtx4050"]]
        return Topology.shared_medium(devs, 900.0)
    if name == "smart_home_2":
        devs = [c["rtx4050"], c["rtx4050"], c["mi15"], c["mi15"], c["s25"]]
        return Topology.shared_medium(devs, 600.0)
    if name == "traffic_monitor":
        devs = [c["genio720"], c["genio720"], c["genio520"], c["genio520"]]
        wifi = LinkResource("wifi", 600.0 * MBPS, frozenset(range(4)), shared=True,
                            latency=3e-3)
        ring = Topology.ring(devs, 200.0)
        resources = list(ring.resources.values()) + [wifi]
        # route over the wired ring for neighbours, wifi otherwise
        return Topology.mixed(devs, resources, ring._p2p)
    if name == "edge_cluster":
        devs = [c["a40"], c["a40"], c["v100"], c["v100"]]
        return Topology.ring(devs, 4000.0, name="lan", latency=0.2e-3)
    raise KeyError(f"unknown setting {name}")
