"""Communication-Expanded Planning (CEP) graph construction (§4.2).

Expands a ``ParallelismPlan`` into per-(stage, microbatch) compute and
communication tasks with full dependency edges, annotated with durations
(compute) and byte counts + traversed network resources (comm). The
Phase-2 scheduler and the edge simulator both execute this graph.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from .device import Topology
from .engine import Task
from .plans import ParallelismPlan


def _route(topo: Topology, src_devs, dst_devs) -> Tuple[str, ...]:
    """Network resources an inter-stage transfer traverses (representative
    bottleneck pair: every sample crosses the same shared medium in WiFi
    settings; for rings we take the first-device route)."""
    pairs = [(i, j) for i in src_devs for j in dst_devs if i != j]
    if not pairs:
        return ()
    i, j = pairs[0]
    return tuple(r.name for r in topo.resources_between(i, j))


def _group_route(topo: Topology, devs) -> Tuple[str, ...]:
    """Resources a data-parallel gradient all-reduce occupies."""
    names: List[str] = []
    for a, b in zip(devs[:-1], devs[1:]):
        for r in topo.resources_between(a, b):
            if r.name not in names:
                names.append(r.name)
    if len(devs) > 1:
        for r in topo.resources_between(devs[-1], devs[0]):
            if r.name not in names:
                names.append(r.name)
    return tuple(names)


def build_cep(plan: ParallelismPlan, topo: Topology) -> List[Task]:
    """CEP tasks for one training iteration (or one inference forward)."""
    S = len(plan.stages)
    M = plan.n_microbatches
    training = plan.training
    tasks: List[Task] = []

    def _lat(route: Tuple[str, ...]) -> float:
        return sum(topo.resources[r].latency for r in route)

    for s, st in enumerate(plan.stages):
        exec_name = f"exec{s}"
        down_route = _route(topo, st.devices, plan.stages[s + 1].devices) \
            if s + 1 < S else ()
        up_route = _route(topo, st.devices, plan.stages[s - 1].devices) \
            if s > 0 else ()
        for m in range(M):
            fdeps: List[str] = []
            if s > 0:
                fdeps.append(f"A{s - 1}.{m}")           # upstream activations
            tasks.append(Task(name=f"F{s}.{m}", kind="compute",
                              duration=st.fwd_time, executor=exec_name,
                              deps=tuple(fdeps)))
            if s + 1 < S:
                tasks.append(Task(name=f"A{s}.{m}", kind="comm",
                                  nbytes=st.comm_bytes_out,
                                  resources=down_route,
                                  net_latency=_lat(down_route),
                                  deps=(f"F{s}.{m}",)))
            if training:
                bdeps = [f"F{s}.{m}"]
                if s + 1 < S:
                    bdeps.append(f"G{s + 1}.{m}")       # downstream grads
                tasks.append(Task(name=f"B{s}.{m}", kind="compute",
                                  duration=st.bwd_time, executor=exec_name,
                                  deps=tuple(bdeps)))
                if s > 0:
                    # grad wrt inputs has the size of the *upstream boundary*
                    # activation (stage s-1's output), not this stage's output
                    tasks.append(Task(name=f"G{s}.{m}", kind="comm",
                                      nbytes=plan.stages[s - 1].comm_bytes_out,
                                      resources=up_route,
                                      net_latency=_lat(up_route),
                                      deps=(f"B{s}.{m}",)))
        if training and st.dp_degree > 1 and st.sync_bytes > 0:
            ar_route = _group_route(topo, st.devices)
            tasks.append(Task(name=f"AR{s}", kind="comm",
                              nbytes=st.sync_bytes * st.dp_degree,
                              resources=ar_route,
                              net_latency=_lat(ar_route),
                              deps=tuple(f"B{s}.{m}" for m in range(M))))
    return tasks


def cep_resource_caps(topo: Topology) -> Dict[str, float]:
    return {name: r.capacity for name, r in topo.resources.items()}
