"""Communication-Expanded Planning (CEP) graph construction (§4.2).

Expands a ``ParallelismPlan`` into per-(stage, microbatch) compute and
communication tasks with full dependency edges, annotated with durations
(compute) and byte counts + traversed network resources (comm). The
Phase-2 scheduler and the edge simulator both execute this graph.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

from .device import Topology
from .engine import EventEngine, Task, chunk_comm_tasks, task_structure
from .plans import ParallelismPlan


def _route(topo: Topology, src_devs, dst_devs) -> Tuple[str, ...]:
    """Network resources an inter-stage transfer traverses (representative
    bottleneck pair: every sample crosses the same shared medium in WiFi
    settings; for rings we take the first-device route)."""
    pairs = [(i, j) for i in src_devs for j in dst_devs if i != j]
    if not pairs:
        return ()
    i, j = pairs[0]
    return tuple(r.name for r in topo.resources_between(i, j))


def _group_route(topo: Topology, devs) -> Tuple[str, ...]:
    """Resources a data-parallel gradient all-reduce occupies."""
    names: List[str] = []
    for a, b in zip(devs[:-1], devs[1:]):
        for r in topo.resources_between(a, b):
            if r.name not in names:
                names.append(r.name)
    if len(devs) > 1:
        for r in topo.resources_between(devs[-1], devs[0]):
            if r.name not in names:
                names.append(r.name)
    return tuple(names)


def build_cep(plan: ParallelismPlan, topo: Topology) -> List[Task]:
    """CEP tasks for one training iteration (or one inference forward)."""
    S = len(plan.stages)
    M = plan.n_microbatches
    training = plan.training
    tasks: List[Task] = []

    def _lat(route: Tuple[str, ...]) -> float:
        return sum(topo.resources[r].latency for r in route)

    for s, st in enumerate(plan.stages):
        exec_name = f"exec{s}"
        down_route = _route(topo, st.devices, plan.stages[s + 1].devices) \
            if s + 1 < S else ()
        up_route = _route(topo, st.devices, plan.stages[s - 1].devices) \
            if s > 0 else ()
        for m in range(M):
            fdeps: List[str] = []
            if s > 0:
                fdeps.append(f"A{s - 1}.{m}")           # upstream activations
            tasks.append(Task(name=f"F{s}.{m}", kind="compute",
                              duration=st.fwd_time, executor=exec_name,
                              deps=tuple(fdeps)))
            if s + 1 < S:
                tasks.append(Task(name=f"A{s}.{m}", kind="comm",
                                  nbytes=st.comm_bytes_out,
                                  resources=down_route,
                                  net_latency=_lat(down_route),
                                  deps=(f"F{s}.{m}",)))
            if training:
                bdeps = [f"F{s}.{m}"]
                if s + 1 < S:
                    bdeps.append(f"G{s + 1}.{m}")       # downstream grads
                tasks.append(Task(name=f"B{s}.{m}", kind="compute",
                                  duration=st.bwd_time, executor=exec_name,
                                  deps=tuple(bdeps)))
                if s > 0:
                    # grad wrt inputs has the size of the *upstream boundary*
                    # activation (stage s-1's output), not this stage's output
                    tasks.append(Task(name=f"G{s}.{m}", kind="comm",
                                      nbytes=plan.stages[s - 1].comm_bytes_out,
                                      resources=up_route,
                                      net_latency=_lat(up_route),
                                      deps=(f"B{s}.{m}",)))
        if training and st.dp_degree > 1 and st.sync_bytes > 0:
            ar_route = _group_route(topo, st.devices)
            tasks.append(Task(name=f"AR{s}", kind="comm",
                              nbytes=st.sync_bytes * st.dp_degree,
                              resources=ar_route,
                              net_latency=_lat(ar_route),
                              deps=tuple(f"B{s}.{m}" for m in range(M))))
    return tasks


def cep_resource_caps(topo: Topology) -> Dict[str, float]:
    return {name: r.capacity for name, r in topo.resources.items()}


class CEPCache:
    """Per-plan CEP reuse: build the task graph once, derive everything
    else lazily and keep it.

    One ``refine`` used to expand the same plan into the same CEP graph
    and re-run ``assign_priorities`` up to 7 times (fair eval + every
    chunk mode + the LP lower bound); the runtime adapter then repeated
    all of it on every dynamics event.  This cache memoizes, per plan:

    * the base (unchunked) task list — built once;
    * each chunked variant (``chunk_comm_tasks`` clones of the cached
      base tasks) and its dependency structure/topological order;
    * the critical-path priority map per ``(chunks, caps)`` — priorities
      depend on resource capacities (bandwidth-scale events) but not on
      compute speed or comm mode.

    ``engine`` hands back a ready-to-``run`` :class:`EventEngine` with
    the cached structure and priorities applied.  Chunk counts ``w <= 1``
    share the base task list (the fair/null schedule and the unchunked
    scheduled search use the same graph).
    """

    def __init__(self, plan: ParallelismPlan, topo: Topology,
                 shared_structs: Optional[Dict[tuple, tuple]] = None):
        self.plan = plan
        self.topo = topo
        self._tasks: Dict[int, List[Task]] = {}
        self._structs: Dict[int, tuple] = {}
        self._dists: Dict[Tuple[int, tuple], Dict[str, float]] = {}
        self._applied: Dict[int, tuple] = {}    # w -> caps sig last applied
        self._runs: "OrderedDict[tuple, object]" = OrderedDict()
        # (succ, ndeps, order) keyed by CEP *shape*, shared across plans:
        # the dependency graph depends only on stage/microbatch counts
        # and which transfers exist — not on durations, byte sizes or
        # routes — so a candidate pool of like-shaped plans builds it once
        self._shared = shared_structs

    def _shape(self, w: int) -> tuple:
        p = self.plan
        return (w, len(p.stages), p.n_microbatches, p.training,
                tuple(s.comm_bytes_out > 0 for s in p.stages),
                tuple(p.training and s.dp_degree > 1 and s.sync_bytes > 0
                      for s in p.stages))

    def tasks(self, chunks: int = 1) -> List[Task]:
        w = max(int(chunks), 1)
        out = self._tasks.get(w)
        if out is None:
            if w == 1:
                out = build_cep(self.plan, self.topo)
            else:
                out = chunk_comm_tasks(self.tasks(1), w)
            self._tasks[w] = out
        return out

    def _structure(self, w: int) -> tuple:
        struct = self._structs.get(w)
        if struct is not None:
            return struct
        shape = self._shape(w) if self._shared is not None else None
        shared = self._shared.get(shape) if shape is not None else None
        if shared is not None:
            # same dependency graph, this plan's task objects
            struct = ({t.name: t for t in self.tasks(w)},) + shared
        else:
            if w == 1:
                struct = task_structure(self.tasks(1))
            else:       # derived from the base order in one linear walk
                struct = task_structure(self.tasks(w), base=self._structure(1))
            if shape is not None:
                self._shared[shape] = struct[1:]
        self._structs[w] = struct
        return struct

    def engine(self, chunks: int, caps: Dict[str, float],
               comm_mode: str = "scheduled",
               compute_speed: Optional[Dict[str, float]] = None
               ) -> EventEngine:
        w = max(int(chunks), 1)
        eng = EventEngine(self.tasks(w), caps, comm_mode=comm_mode,
                          compute_speed=compute_speed,
                          structure=self._structure(w))
        caps_sig = tuple(sorted(caps.items()))
        sig = (w, caps_sig)
        if self._applied.get(w) == caps_sig and sig in self._dists:
            return eng     # this task list already carries these priorities
        self._dists[sig] = eng.assign_priorities(self._dists.get(sig))
        # chunk variants share their non-comm Task objects with the base
        # list, so applying priorities for one w stales every other
        self._applied = {w: caps_sig}
        return eng

    def priorities(self, chunks: int, caps: Dict[str, float]
                   ) -> Dict[str, float]:
        """Critical-path priority map for one (chunk count, caps) pair
        (the ``lower_bound`` input), cached like :meth:`engine`'s."""
        sig = (max(int(chunks), 1), tuple(sorted(caps.items())))
        dist = self._dists.get(sig)
        if dist is None:
            self.engine(chunks, caps)
            dist = self._dists[sig]
        return dist

    def run(self, chunks: int, caps: Dict[str, float],
            comm_mode: str = "scheduled",
            compute_speed: Optional[Dict[str, float]] = None):
        """Memoized schedule execution: the engine is deterministic, so
        one ``(chunks, comm_mode, caps, speeds)`` configuration is
        simulated once and every repeat — the fair pre-ranking pass
        followed by ``refine``'s null schedule, or the adapter
        re-refining its Pareto set under unchanged conditions — returns
        the cached :class:`~repro.core.engine.ScheduleResult`."""
        sig = (max(int(chunks), 1), comm_mode,
               tuple(sorted(caps.items())),
               tuple(sorted((compute_speed or {}).items())))
        res = self._runs.get(sig)
        if res is None:
            res = self.engine(chunks, caps, comm_mode, compute_speed).run()
            self._runs[sig] = res
            while len(self._runs) > 64:
                self._runs.popitem(last=False)
        else:
            self._runs.move_to_end(sig)
        return res
