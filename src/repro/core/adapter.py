"""Phase 3 — runtime adapter (§4.3).

Two deployment-driven paths:

* **Interruptible workloads** (training/tuning): the *uniform-progress*
  heuristic amortizes the deadline over horizons
  (``EP_Δ = (Δ/D_rem)·W_rem``) and a small LP (Eqs. 7-8) picks a mixture
  of Pareto-optimal plans that meets the horizon's progress at minimum
  energy. Deficits from transient slowdowns are re-absorbed because the
  next horizon recomputes ``W_rem/D_rem``.
* **Continuous workloads** (serving): fluctuations below a threshold are
  absorbed by re-running only the Phase-2 network scheduler (sub-second,
  no model-state migration); larger shifts trigger replanning with
  **asynchronous** (prefetch immutable weights during execution) and
  **delta** (transfer only missing layers) switching.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
from scipy.optimize import linprog

from .device import Topology
from .plans import ParallelismPlan
from .qoe import QoESpec
from .scheduler import NetworkScheduler


# -- dynamics events ------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DynamicsEvent:
    """A runtime condition change at ``t`` (seconds).

    ``compute_speed``/``bandwidth_scale`` are *absolute* multipliers vs
    nominal (0.5 = half speed), keyed by device index / resource name.
    ``leave``/``join`` are fleet churn: device indices (of the original
    deployment topology) that drop out of or rejoin the fleet at ``t``.
    Churn always forces a full replan — the plan's device set changed.

    The remaining fields are **unannounced faults** — ground-truth
    changes the runtime cannot observe at ``t`` and only acts on once
    the heartbeat detector notices (``miss_limit × beat_interval``
    later; see ``repro.resilience``):

    * ``crash`` — devices that stop silently (no leave announcement,
      no further heartbeats); repair is announced via a later ``join``.
    * ``link_down``/``link_up`` — link resources (by name) that go dark
      / come back; requests routed over a dark link fail.
    * ``straggler`` — silent per-device slowdown factors: the device
      keeps heartbeating nominal numbers while actually serving slower.

    Fault fields never contribute to :meth:`magnitude` — they are
    invisible to the announced-event adapter path by construction.
    """

    t: float
    compute_speed: Dict[str, float] = dataclasses.field(default_factory=dict)
    bandwidth_scale: Dict[str, float] = dataclasses.field(default_factory=dict)
    leave: Tuple[int, ...] = ()
    join: Tuple[int, ...] = ()
    crash: Tuple[int, ...] = ()
    link_down: Tuple[str, ...] = ()
    link_up: Tuple[str, ...] = ()
    straggler: Dict[int, float] = dataclasses.field(default_factory=dict)

    @property
    def is_churn(self) -> bool:
        return bool(self.leave or self.join)

    @property
    def is_fault(self) -> bool:
        """True when the event carries unannounced fault content."""
        return bool(self.crash or self.link_down or self.link_up
                    or self.straggler)

    @property
    def is_announced(self) -> bool:
        """True when the event carries content the runtime can see at
        ``t`` (condition shifts or churn announcements)."""
        return bool(self.compute_speed or self.bandwidth_scale
                    or self.is_churn)

    def magnitude(self) -> float:
        if self.is_churn:
            return math.inf
        devs = [abs(1.0 - v) for v in self.compute_speed.values()]
        bws = [abs(1.0 - v) for v in self.bandwidth_scale.values()]
        return max(devs + bws + [0.0])


@dataclasses.dataclass(frozen=True)
class RuntimeState:
    """Accumulated runtime conditions: the merge of every event so far.

    Events are deltas against *nominal*, not against each other — a
    bandwidth drop at t=10 stays in force when a compute-speed event
    arrives at t=20. ``apply`` folds one more event in;
    ``delta`` measures how far an event moves conditions from this
    accumulated state (the §4.3 fluctuation-threshold input).
    """

    compute_speed: Dict[int, float] = dataclasses.field(default_factory=dict)
    bandwidth_scale: Dict[str, float] = dataclasses.field(default_factory=dict)

    def apply(self, event: DynamicsEvent) -> "RuntimeState":
        speed = dict(self.compute_speed)
        speed.update(event.compute_speed)
        bw = dict(self.bandwidth_scale)
        bw.update(event.bandwidth_scale)
        return RuntimeState(compute_speed=speed, bandwidth_scale=bw)

    def delta(self, event: DynamicsEvent) -> float:
        """Largest shift ``event`` causes relative to this state."""
        if event.is_churn:
            return math.inf
        shifts = [abs(self.compute_speed.get(k, 1.0) - v)
                  for k, v in event.compute_speed.items()]
        shifts += [abs(self.bandwidth_scale.get(k, 1.0) - v)
                   for k, v in event.bandwidth_scale.items()]
        return max(shifts + [0.0])


@dataclasses.dataclass
class AdapterConfig:
    horizon_s: float = 60.0
    fluctuation_threshold: float = 0.10     # §5: ≤10% → network-only replan
    switch_drain_s: float = 2.0             # pipeline drain on plan switch
    async_switching: bool = True
    delta_switching: bool = True
    #: DEFER-style streamed migration: on the *synchronous* switch path
    #: (async prefetch off — e.g. recovery from a dead pipeline is
    #: priced sync), overlap the next plan's weight transfer with the
    #: current plan's remaining execution so the priced stall drops from
    #: the full reload toward the exposed (non-overlapped) remainder
    streamed_migration: bool = False
    #: fraction of the migration link's bandwidth the stream may steal
    #: from serving traffic while overlapping
    stream_bw_fraction: float = 0.5


def _plan_tiebreak(p: ParallelismPlan) -> tuple:
    """Deterministic total order over plans with equal (latency, energy):
    structural signature, independent of construction/input order."""
    return (p.n_stages, p.microbatch_size,
            tuple((tuple(s.node_ids), tuple(s.devices)) for s in p.stages))


def pareto_filter(plans: Sequence[ParallelismPlan]) -> List[ParallelismPlan]:
    """Keep plans Pareto-optimal in (latency, energy).

    Domination is strict-with-tiebreak: a plan is dropped iff some kept
    plan is no worse on both metrics and strictly better on at least
    one.  Plans fully tied on (latency, energy) keep exactly one
    deterministic representative (smallest structural signature), so the
    result never depends on input order.
    """
    ranked = sorted(plans, key=lambda p: (p.latency, p.energy, _plan_tiebreak(p)))
    out: List[ParallelismPlan] = []
    best_e = math.inf
    for p in ranked:
        # strict: any genuine energy improvement survives, ties collapse
        # onto the representative already kept at equal-or-lower latency
        if p.energy < best_e:
            out.append(p)
            best_e = p.energy
    return out


def cold_load_stall(plan: ParallelismPlan, topo: Topology,
                    config: AdapterConfig) -> float:
    """Service stall of loading ``plan`` onto a fleet with *nothing*
    resident (no surviving placement to delta-switch from): drain the
    pipeline, then stream the largest per-device parameter shard at the
    slowest involved peak bandwidth.  Shared by the single-tenant and
    fleet churn paths."""
    nbytes = max(plan.device_param_bytes().values(), default=0.0)
    bw = min((topo.peak_bandwidth(i, j)
              for i in plan.devices for j in plan.devices if i != j),
             default=math.inf)
    load_t = nbytes / bw if bw != math.inf else 0.0
    return config.switch_drain_s + load_t


class RuntimeAdapter:
    def __init__(self, plans: Sequence[ParallelismPlan], topo: Topology,
                 qoe: QoESpec, scheduler: NetworkScheduler,
                 config: Optional[AdapterConfig] = None):
        if not plans:
            raise ValueError("adapter needs at least one plan")
        self.all_plans = list(plans)
        self.plans = pareto_filter(plans)
        self.topo = topo
        self.qoe = qoe
        self.scheduler = scheduler
        self.config = config or AdapterConfig()

    # -- switching cost (§4.3 async + delta + DEFER streaming) -------------------
    def switch_cost(self, old: Optional[ParallelismPlan],
                    new: ParallelismPlan,
                    overlap_s: Optional[float] = None) -> float:
        """Seconds of *service stall* incurred by switching old→new.

        ``overlap_s`` is the execution span still ahead of the current
        plan that a streamed migration may overlap with (defaults to
        one iteration, ``old.latency``); it only matters when
        ``streamed_migration`` is armed and the switch is priced
        synchronously (``async_switching`` covers the announced path
        with its own full-prefetch overlap)."""
        if old is None or old is new:
            return 0.0
        cfg = self.config
        if cfg.delta_switching:
            old_layers = old.device_layers()
            nbytes = 0.0
            for st in new.stages:
                per_param = st.param_bytes / max(len(st.node_ids), 1)
                for d in st.devices:
                    have = old_layers.get(d, frozenset())
                    missing = [i for i in st.node_ids if i not in have]
                    nbytes = max(nbytes, len(missing) * per_param)
        else:
            nbytes = max(new.device_param_bytes().values())
        # conservative: weights stream at the slowest involved peak bandwidth
        bw = min((self.topo.peak_bandwidth(i, j)
                  for i in new.devices for j in new.devices if i != j),
                 default=math.inf)
        load_t = nbytes / bw if bw != math.inf else 0.0
        if cfg.async_switching:
            # prefetch overlaps with ongoing execution; stall is the drain
            return cfg.switch_drain_s + max(0.0, load_t - old.latency)
        if cfg.streamed_migration and bw != math.inf:
            # DEFER-style send-compute-receive overlap: while the current
            # plan keeps executing, a fraction of the link streams the
            # next plan's weights ahead; only the non-overlapped
            # remainder is exposed as stall
            overlap = old.latency if overlap_s is None else max(overlap_s,
                                                                0.0)
            shipped = overlap * bw * cfg.stream_bw_fraction
            exposed = max(0.0, nbytes - shipped) / bw
            return cfg.switch_drain_s + exposed
        return cfg.switch_drain_s + load_t

    # -- Eqs. (7)-(8): horizon mixture LP -----------------------------------------
    def mix_for_horizon(self, w_rem: float, d_rem: float,
                        current: Optional[ParallelismPlan] = None,
                        horizon: Optional[float] = None
                        ) -> List[Tuple[ParallelismPlan, float]]:
        """Fractions x_p of the horizon per plan meeting EP_Δ at min energy.

        ``w_rem`` — remaining work in iterations; ``d_rem`` — seconds to
        deadline. Returns [(plan, fraction)] with Σ fraction ≤ 1.
        """
        delta = min(horizon or self.config.horizon_s, max(d_rem, 1e-9))
        # pace to finish slightly early: switching stalls and horizon
        # rounding otherwise push completion just past the deadline
        d_eff = max(d_rem * 0.97, 1e-9)
        ep = min((delta / d_eff) * w_rem, w_rem)       # expected progress
        P = self.plans
        rate = np.array([1.0 / p.latency for p in P])            # iters/sec
        e_rate = np.array([p.energy / p.latency for p in P])     # J/sec
        d_p = np.array([self.switch_cost(current, p) for p in P])
        useful = np.maximum(delta - d_p, 0.0)
        # min Σ e_rate_p·Δ·x_p   s.t.  Σ rate_p·useful_p·x_p ≥ EP,  Σ x_p ≤ 1
        c = e_rate * delta
        a_ub = np.vstack([-(rate * useful), np.ones(len(P))])
        b_ub = np.array([-ep, 1.0])
        res = linprog(c, A_ub=a_ub, b_ub=b_ub, bounds=[(0.0, 1.0)] * len(P),
                      method="highs")
        if not res.success:
            # infeasible horizon: run the fastest plan flat out; the next
            # horizon's EP_Δ recomputation absorbs the deficit (§4.3)
            fastest = int(np.argmax(rate * np.maximum(delta - d_p, 0.0)))
            return [(P[fastest], 1.0)]
        out = [(P[i], float(x)) for i, x in enumerate(res.x) if x > 1e-6]
        return out or [(P[int(np.argmax(rate))], 1.0)]

    # -- interruptible-workload simulation (Fig. 12) --------------------------------
    def run_interruptible(self, total_iters: float, deadline: float,
                          dynamics: Sequence[DynamicsEvent] = (),
                          horizon: Optional[float] = None) -> Dict[str, object]:
        """Simulate horizon-by-horizon plan mixing until the job finishes.

        Returns trace with total energy, completion time, QoE verdict.
        """
        cfg = self.config
        delta = horizon or cfg.horizon_s
        t, done, energy = 0.0, 0.0, 0.0
        stall_s, stall_energy = 0.0, 0.0
        current: Optional[ParallelismPlan] = None
        events = sorted(dynamics, key=lambda e: e.t)
        trace: List[Dict[str, float]] = []
        speed: Dict[str, float] = {}
        bw: Dict[str, float] = {}
        # streamed migration overlaps the next switch's weight transfer
        # with the execution span just completed on the current plan
        prev_exec = 0.0
        while done < total_iters and t < 10 * deadline:
            while events and events[0].t <= t:
                ev = events.pop(0)
                speed.update(ev.compute_speed)
                bw.update(ev.bandwidth_scale)
                self._refresh_plans(speed, bw)
            mixture = self.mix_for_horizon(total_iters - done, deadline - t,
                                           current, delta)
            spent = 0.0
            for plan, frac in mixture:
                span = frac * delta
                if span <= 0:
                    continue
                stall = self.switch_cost(
                    current, plan,
                    overlap_s=prev_exec if cfg.streamed_migration else None)
                # migration is not free energy-wise: every device involved
                # (old placement draining + new placement loading) keeps
                # drawing idle power while it lasts — capped at the
                # mixture slice, which is all the wall-clock this
                # component occupies
                stall_eff = min(stall, span)
                if stall_eff > 0.0:
                    involved = set(plan.devices)
                    if current is not None:
                        involved |= set(current.devices)
                    idle_w = sum(self.topo.devices[d].p_idle
                                 for d in involved)
                    stall_s += stall_eff
                    stall_energy += idle_w * stall_eff
                    energy += idle_w * stall_eff
                exec_span = max(span - stall, 0.0)
                iters = min(exec_span / plan.latency, total_iters - done)
                done += iters
                energy += (plan.energy / plan.latency) * (iters * plan.latency)
                spent += stall + iters * plan.latency
                current = plan
                prev_exec = iters * plan.latency
                trace.append(dict(t=t, plan=id(plan), frac=frac, iters=iters,
                                  lat=plan.latency, stall=stall,
                                  exec_energy=plan.energy * iters))
                if done >= total_iters:
                    break
            # advance by the true elapsed time once the job finishes
            t += delta if done < total_iters else min(spent, delta)
        return dict(energy=energy, finished_at=t, done=done,
                    met_deadline=(done >= total_iters
                                  and t <= deadline * (1.0 + 1e-3)),
                    stall_s=stall_s, stall_energy=stall_energy,
                    trace=trace)

    # -- continuous-workload path (Fig. 16) ------------------------------------------
    def on_dynamics(self, current: ParallelismPlan, event: DynamicsEvent,
                    replan_fn: Optional[Callable[[], Sequence[ParallelismPlan]]] = None,
                    state: Optional[RuntimeState] = None
                    ) -> Tuple[ParallelismPlan, str, float]:
        """React to one runtime event. Returns (plan, action, react_seconds).

        ``state`` carries the conditions accumulated from *earlier*
        events; the event is merged into it so a bandwidth drop at t=10
        is still in force when a compute-speed event arrives at t=20.
        Without ``state`` the event is taken as the complete picture
        (the legacy single-event behavior). The fluctuation threshold
        compares the event against the accumulated state, not nominal.
        (Thin adapter over :func:`repro.control.plane.react_once` —
        the reaction layer lives in the control plane.)
        """
        from ..control.plane import react_once
        return react_once(self, current, event, replan_fn, state)

    def react(self, current: ParallelismPlan, conditions: RuntimeState,
              magnitude: float,
              replan_fn: Optional[Callable[[], Sequence[ParallelismPlan]]] = None
              ) -> Tuple[ParallelismPlan, str, float]:
        """Adapt to the *merged* runtime conditions.

        Small shifts (``magnitude`` ≤ threshold) re-run only the Phase-2
        scheduler on the current plan. Large shifts replan: every fresh
        candidate is priced under the merged conditions **with its
        migration stall amortized into the choice** — the stall is pure
        QoE-violation seconds spread over the requests one adaptation
        horizon serves, charged at λ like any other violation (Eq. 2).
        Keeping the (rescheduled) current plan costs no stall and wins
        whenever no candidate's gain covers its own migration; the
        returned plan's ``meta["switch_stall_s"]`` is then 0.
        """
        t0 = time.perf_counter()
        speed = dict(conditions.compute_speed)
        bwsc = dict(conditions.bandwidth_scale)
        if magnitude <= self.config.fluctuation_threshold or replan_fn is None:
            refined = self.scheduler.refine(current, compute_speed=speed,
                                            bandwidth_scale=bwsc)
            return refined, "reschedule", time.perf_counter() - t0
        # substantial shift: full replan + async/delta switch
        fresh = list(replan_fn())
        refined = [self.scheduler.refine(p, compute_speed=speed,
                                         bandwidth_scale=bwsc) for p in fresh]
        kept = self.scheduler.refine(current, compute_speed=speed,
                                     bandwidth_scale=bwsc)
        horizon = max(self.config.horizon_s, 1e-9)

        def amortized(p: ParallelismPlan, stall: float) -> float:
            return p.objective + self.qoe.lam * stall * (p.latency / horizon)

        best, best_score, best_stall = kept, amortized(kept, 0.0), 0.0
        for p in refined:
            stall = self.switch_cost(current, p)
            score = amortized(p, stall)
            if score < best_score - 1e-12:
                best, best_score, best_stall = p, score, stall
        best.meta["switch_stall_s"] = best_stall
        self.plans = pareto_filter(refined + [kept])
        return best, "replan", time.perf_counter() - t0

    # -- helpers -----------------------------------------------------------------------
    def _refresh_plans(self, speed: Dict[str, float], bw: Dict[str, float]) -> None:
        """Re-evaluate the Pareto set under current conditions (fast: the
        Phase-2 scheduler only; no repartitioning)."""
        refreshed = [self.scheduler.refine(p, compute_speed=dict(speed),
                                           bandwidth_scale=dict(bw))
                     for p in self.all_plans]
        self.plans = pareto_filter(refreshed)
