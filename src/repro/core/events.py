"""The serving kernel: one vectorized event core for every request sim.

``sim/serving.py`` (single tenant), ``sim/fleet.py`` (multi-tenant) and
the plan-level :mod:`repro.core.engine` used to carry three divergent
event-processing loops that had to agree on the fluid model.  This
module is the single owner of the request-level machinery they share:

* **Arrival generation** — an arrival-process zoo
  (:class:`PoissonArrivals`, :class:`DiurnalArrivals`,
  :class:`MMPPArrivals`, :class:`FlashCrowdArrivals`,
  :class:`TraceArrivals`) plus multi-class request tiers
  (:class:`RequestClass`), both carried by :class:`ServingLoad`.
* **Admission/queueing** — :class:`Stream`: between dynamics events the
  fluid pipeline model is *closed form*, so each inter-event segment is
  processed as array ops.  With carried queue state ``f`` (the time the
  pipeline next admits), admission interval ``I`` and latency ``L``, the
  k-th arrival ``a_k`` of a segment starts at::

      start_k = I*k + max(f, cummax_j<=k(a_j - I*j))        (Lindley)
      finish_k = start_k + L
      f' = start_last + I

  which is exactly the per-request recurrence ``start = max(a, f);
  f = start + I`` unrolled — a chunk size of 1 reproduces the old
  discrete loop bit-for-bit, which the segmentation property tests
  exploit.  Discrete stepping survives only at segment boundaries:
  adapter reactions, migration stalls and churn.
* **Dynamics segmentation** — :func:`replay` drives any number of
  streams through one labeled timeline, serving every arrival strictly
  before each event's ``t`` (events at ``t <= a`` fire before ``a`` is
  admitted, matching the historical loop), then firing the adapter.
* **Energy attribution** — :class:`PresenceTracker` bills idle draw
  only over a device's presence interval (a device that leaves at ``t``
  stops drawing idle power at ``t``); :class:`OwnershipTracker`
  prorates fleet idle draw across the tenants that owned a device,
  by ownership interval, instead of billing the final owner for the
  whole horizon.

The steady-state admission interval itself comes from
:meth:`repro.core.engine.ScheduleResult.admission_interval` — the same
what-if primitive the plan-level engine exposes — so all three layers
price throughput identically.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, FrozenSet, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .adapter import DynamicsEvent

#: Default number of requests when a load doesn't specify one.
DEFAULT_N_REQUESTS = 200

#: Hard cap on rate-segment blocks when inverting an inhomogeneous
#: process — a runaway guard, far above any real horizon.
_MAX_RATE_BLOCKS = 100_000


def _json_num(x: Optional[float]) -> Optional[float]:
    """inf/nan -> None so exports stay strict-JSON parseable."""
    if x is None or math.isinf(x) or math.isnan(x):
        return None
    return x


# -- arrival processes ---------------------------------------------------------
def poisson_arrivals(rate: float, n_requests: int, seed: int = 0) -> np.ndarray:
    """Arrival times of an open-loop Poisson process (deterministic per
    seed; gaps are standard exponentials scaled by ``1/rate``, so the
    same seed at a higher rate yields a pointwise-compressed trace)."""
    if rate <= 0.0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    if n_requests <= 0:
        raise ValueError(f"n_requests must be positive, got {n_requests}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=int(n_requests)))


def _invert_unit_process(u: np.ndarray, block_fn) -> np.ndarray:
    """Warp unit-rate Poisson positions ``u`` through a piecewise-
    constant rate curve (the standard time-change construction of an
    inhomogeneous Poisson process).

    ``block_fn(i)`` returns ``(durations, rates)`` arrays for the i-th
    block of rate segments; blocks are appended until their cumulative
    mass ``sum(d*r)`` covers ``u[-1]``.  Within a constant-rate segment
    the inversion is linear, so the mapping is exact (no grid error)
    for piecewise-constant rates.
    """
    durs: List[np.ndarray] = []
    rates: List[np.ndarray] = []
    mass = 0.0
    for i in range(_MAX_RATE_BLOCKS):
        d, r = block_fn(i)
        d = np.asarray(d, dtype=np.float64)
        r = np.asarray(r, dtype=np.float64)
        durs.append(d)
        rates.append(r)
        mass += float(np.sum(d * r))
        if mass >= u[-1]:
            break
    else:
        raise ValueError("arrival process never accumulated enough rate "
                         "mass — is the mean rate positive?")
    d = np.concatenate(durs)
    r = np.concatenate(rates)
    seg_mass = d * r
    mass0 = np.concatenate(([0.0], np.cumsum(seg_mass)))[:-1]
    t0 = np.concatenate(([0.0], np.cumsum(d)))[:-1]
    pos = r > 0.0
    # zero-rate segments carry no mass: u never lands strictly inside
    # one, so the positive segments alone cover the inversion
    mass0, t0, r = mass0[pos], t0[pos], r[pos]
    idx = np.searchsorted(mass0, u, side="right") - 1
    idx = np.clip(idx, 0, len(mass0) - 1)
    return t0[idx] + (u - mass0[idx]) / r[idx]


class ArrivalProcess:
    """Base class of the arrival zoo.  ``sample(rate, n, seed)`` returns
    ``n`` sorted non-negative arrival times; ``rate`` is the load's mean
    request rate, which modulating processes scale (so traces stay
    monotone in the load's rate, like the plain Poisson process)."""

    def sample(self, rate: float, n_requests: int,
               seed: int = 0) -> np.ndarray:
        raise NotImplementedError

    def _unit_positions(self, rng: np.random.Generator,
                        n_requests: int) -> np.ndarray:
        if n_requests <= 0:
            raise ValueError(f"n_requests must be positive, got {n_requests}")
        return np.cumsum(rng.exponential(1.0, size=int(n_requests)))


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson process at the load's rate — the default,
    bit-identical to :func:`poisson_arrivals`."""

    def sample(self, rate: float, n_requests: int,
               seed: int = 0) -> np.ndarray:
        return poisson_arrivals(rate, n_requests, seed)


@dataclasses.dataclass(frozen=True)
class TraceArrivals(ArrivalProcess):
    """Replay an explicit arrival trace (seconds).  Ignores the load's
    rate and seed; serves the first ``n_requests`` entries when the
    trace is longer, the whole trace when shorter."""

    times: Tuple[float, ...]

    def sample(self, rate: float, n_requests: int,
               seed: int = 0) -> np.ndarray:
        arr = np.sort(np.asarray(self.times, dtype=np.float64))
        if len(arr) and arr[0] < 0.0:
            raise ValueError("arrival times must be non-negative")
        if n_requests and len(arr) > n_requests:
            arr = arr[:int(n_requests)]
        return arr


@dataclasses.dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Sinusoidal day/night rate curve around the load's mean rate:
    ``rate(t) = rate * (1 + amplitude * sin(2*pi*(t - phase_s)/period))``.
    The sinusoid is discretized to ``steps_per_period`` constant-rate
    segments (midpoint rule) before the exact piecewise inversion."""

    period_s: float = 86_400.0
    amplitude: float = 0.8          # 0..1, peak-to-mean swing
    phase_s: float = 0.0
    steps_per_period: int = 256

    def __post_init__(self) -> None:
        if not 0.0 <= self.amplitude <= 1.0:
            raise ValueError(f"amplitude must be in [0, 1], "
                             f"got {self.amplitude}")
        if self.period_s <= 0.0:
            raise ValueError(f"period_s must be positive, got {self.period_s}")

    def sample(self, rate: float, n_requests: int,
               seed: int = 0) -> np.ndarray:
        if rate <= 0.0:
            raise ValueError(f"arrival rate must be positive, got {rate}")
        rng = np.random.default_rng(seed)
        u = self._unit_positions(rng, n_requests)
        step = self.period_s / self.steps_per_period

        def block(i: int) -> Tuple[np.ndarray, np.ndarray]:
            mid = (np.arange(self.steps_per_period) + 0.5) * step \
                + i * self.period_s
            r = rate * (1.0 + self.amplitude * np.sin(
                2.0 * math.pi * (mid - self.phase_s) / self.period_s))
            return np.full(self.steps_per_period, step), np.maximum(r, 0.0)

        return _invert_unit_process(u, block)


@dataclasses.dataclass(frozen=True)
class MMPPArrivals(ArrivalProcess):
    """Markov-modulated Poisson process: the rate jumps between states
    (``rate * multipliers[s]``) with exponentially distributed sojourns
    — the standard bursty-traffic model.  States cycle in order
    (2 states = the classic on/off burst process)."""

    multipliers: Tuple[float, ...] = (0.25, 4.0)
    mean_sojourn_s: Tuple[float, ...] = (300.0, 60.0)
    start_state: int = 0

    def __post_init__(self) -> None:
        if len(self.multipliers) < 2:
            raise ValueError("MMPP needs at least 2 states")
        if len(self.mean_sojourn_s) != len(self.multipliers):
            raise ValueError("multipliers and mean_sojourn_s must have "
                             "the same length")
        if min(self.multipliers) < 0.0 or max(self.multipliers) <= 0.0:
            raise ValueError("state multipliers must be non-negative with "
                             "at least one positive")
        if min(self.mean_sojourn_s) <= 0.0:
            raise ValueError("mean sojourns must be positive")

    def sample(self, rate: float, n_requests: int,
               seed: int = 0) -> np.ndarray:
        if rate <= 0.0:
            raise ValueError(f"arrival rate must be positive, got {rate}")
        rng = np.random.default_rng(seed)
        u = self._unit_positions(rng, n_requests)
        k = len(self.multipliers)
        mults = np.asarray(self.multipliers, dtype=np.float64)
        means = np.asarray(self.mean_sojourn_s, dtype=np.float64)
        batch = 256

        def block(i: int) -> Tuple[np.ndarray, np.ndarray]:
            states = (self.start_state + i * batch
                      + np.arange(batch)) % k
            durs = rng.exponential(1.0, size=batch) * means[states]
            return durs, rate * mults[states]

        return _invert_unit_process(u, block)


@dataclasses.dataclass(frozen=True)
class FlashCrowdArrivals(ArrivalProcess):
    """A flash crowd on top of baseline traffic: the rate ramps from the
    load's rate to ``peak_multiplier``x starting at ``t_start``, holds,
    and ramps back down.  Ramps are discretized to ``ramp_steps``
    constant-rate segments."""

    peak_multiplier: float = 8.0
    t_start: float = 60.0
    ramp_s: float = 15.0
    hold_s: float = 60.0
    ramp_steps: int = 32

    def __post_init__(self) -> None:
        if self.peak_multiplier < 1.0:
            raise ValueError("peak_multiplier must be >= 1")
        if min(self.t_start, self.ramp_s, self.hold_s) < 0.0:
            raise ValueError("t_start/ramp_s/hold_s must be non-negative")

    def sample(self, rate: float, n_requests: int,
               seed: int = 0) -> np.ndarray:
        if rate <= 0.0:
            raise ValueError(f"arrival rate must be positive, got {rate}")
        rng = np.random.default_rng(seed)
        u = self._unit_positions(rng, n_requests)
        peak = rate * self.peak_multiplier
        tail_block = max(self.t_start + 2.0 * self.ramp_s + self.hold_s,
                         1.0)

        def block(i: int) -> Tuple[np.ndarray, np.ndarray]:
            if i > 0:                       # flat baseline tail forever
                return (np.asarray([tail_block]), np.asarray([rate]))
            durs: List[float] = []
            rates: List[float] = []
            if self.t_start > 0.0:
                durs.append(self.t_start)
                rates.append(rate)
            if self.ramp_s > 0.0:
                step = self.ramp_s / self.ramp_steps
                frac = (np.arange(self.ramp_steps) + 0.5) / self.ramp_steps
                durs.extend([step] * self.ramp_steps)
                rates.extend(rate + (peak - rate) * frac)
            if self.hold_s > 0.0:
                durs.append(self.hold_s)
                rates.append(peak)
            if self.ramp_s > 0.0:
                step = self.ramp_s / self.ramp_steps
                frac = (np.arange(self.ramp_steps) + 0.5) / self.ramp_steps
                durs.extend([step] * self.ramp_steps)
                rates.extend(peak - (peak - rate) * frac)
            return np.asarray(durs), np.asarray(rates)

        return _invert_unit_process(u, block)


# -- request classes -----------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RequestClass:
    """One SLO tier of a multi-class load (e.g. interactive vs. batch).
    ``slo_s=None`` inherits the load/scenario default SLO; ``weight`` is
    the tier's relative share of arrivals; ``priority > 0`` marks the
    tier preemptive — with preemption armed
    (:class:`~repro.control.plane.ControlConfig`) its requests jump
    queued lower-priority admissions at the bottleneck stage."""

    name: str
    slo_s: Optional[float] = None
    weight: float = 1.0
    priority: int = 0

    def __post_init__(self) -> None:
        if self.weight <= 0.0:
            raise ValueError(f"class weight must be positive, "
                             f"got {self.weight}")


def interactive_batch(interactive_slo: float, batch_slo: float,
                      interactive_share: float = 0.7
                      ) -> Tuple[RequestClass, RequestClass]:
    """The canonical two-tier mix: latency-sensitive interactive
    requests alongside throughput-oriented batch ones."""
    if not 0.0 < interactive_share < 1.0:
        raise ValueError("interactive_share must be in (0, 1)")
    return (RequestClass("interactive", slo_s=interactive_slo,
                         weight=interactive_share, priority=1),
            RequestClass("batch", slo_s=batch_slo,
                         weight=1.0 - interactive_share))


def assign_classes(n_requests: int, classes: Sequence[RequestClass],
                   seed: int = 0) -> np.ndarray:
    """Seeded per-request class ids (int16), weighted by class weight.
    The stream is drawn independently of the arrival process so the same
    arrivals can be re-tiered without moving in time."""
    w = np.asarray([c.weight for c in classes], dtype=np.float64)
    rng = np.random.default_rng([0xC1A55, int(seed) & 0xFFFFFFFF])
    return rng.choice(len(classes), size=int(n_requests),
                      p=w / w.sum()).astype(np.int16)


@dataclasses.dataclass(eq=False)
class PreemptionSpec:
    """Stage-level priority preemption for one :class:`Stream`.

    ``class_id`` aligns with the stream's arrivals; ``interactive``
    holds the indices of the priority classes (``priority > 0``);
    ``overhead_s`` is the pipeline-state save/restore cost one
    preemption bills the displaced batch request.
    """

    class_id: np.ndarray
    interactive: FrozenSet[int]
    overhead_s: float = 0.005

    def __post_init__(self) -> None:
        self.class_id = np.asarray(self.class_id)
        if self.overhead_s < 0.0:
            raise ValueError(f"overhead_s must be non-negative, "
                             f"got {self.overhead_s}")


def preemption_spec(classes: Sequence[RequestClass],
                    class_id: Optional[np.ndarray],
                    overhead_s: float = 0.005
                    ) -> Optional[PreemptionSpec]:
    """The :class:`PreemptionSpec` of a class-tiered load, or ``None``
    when nothing can preempt (classless load, or no ``priority > 0``
    tier) — callers then stay on the exact FIFO kernel path."""
    if class_id is None or not classes:
        return None
    interactive = frozenset(i for i, c in enumerate(classes)
                            if c.priority > 0)
    if not interactive:
        return None
    return PreemptionSpec(class_id=class_id, interactive=interactive,
                          overhead_s=overhead_s)


# -- load ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServingLoad:
    """Open-loop request load for one serving simulation.

    ``rate`` — mean arrivals per second; ``n_requests`` — how many
    requests to generate; ``slo_s`` — per-request latency SLO (defaults
    to the scenario's ``t_qoe``); ``seed`` — arrival-process seed (same
    seed + same rate → identical arrivals; the exponential gaps scale
    with ``1/rate``, so traces at different rates are coupled and
    queueing is monotone in rate).  ``arrival`` picks a process from the
    zoo (default: homogeneous Poisson at ``rate``); ``classes`` splits
    requests into SLO tiers (default: one implicit class at ``slo_s``).
    """

    rate: float
    n_requests: int = DEFAULT_N_REQUESTS
    slo_s: Optional[float] = None
    seed: int = 0
    arrival: Optional[ArrivalProcess] = None
    classes: Tuple[RequestClass, ...] = ()

    def sample_arrivals(self) -> np.ndarray:
        proc = self.arrival if self.arrival is not None else \
            PoissonArrivals()
        arr = np.asarray(proc.sample(self.rate, self.n_requests, self.seed),
                         dtype=np.float64)
        if len(arr) and (arr[0] < 0.0 or np.any(np.diff(arr) < 0.0)):
            raise ValueError(f"{type(proc).__name__} produced an unsorted "
                             "or negative arrival trace")
        return arr

    def sample_class_ids(self, n: int) -> Optional[np.ndarray]:
        if not self.classes:
            return None
        return assign_classes(n, self.classes, self.seed)


# -- request records -----------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RequestRecord:
    """One request's life: arrival → service start → finish.
    ``finish`` is ``inf`` when the request could not be served (the
    static plan lost a device to churn)."""

    arrival: float
    start: float
    finish: float
    request_class: str = ""

    @property
    def latency(self) -> float:
        return self.finish - self.arrival

    @property
    def waiting(self) -> float:
        return self.start - self.arrival

    @property
    def served(self) -> bool:
        return math.isfinite(self.finish)


class RequestLog(Sequence):
    """Array-backed request records — the ``ServingTrace.requests``
    container.  Iterating yields :class:`RequestRecord` views for
    compatibility, but metrics read the arrays directly so 10^6-request
    traces never materialize a million objects.

    The optional resilience arrays (``attempts``, ``hedged``) are only
    populated by the chaos engine (:mod:`repro.resilience.engine`):
    ``attempts[i]`` counts how many times request ``i`` was issued
    (1 = served first try; >1 = retried), ``hedged[i]`` marks requests
    whose retry was hedged (re-issued without backoff).  Fault-free
    runs leave them ``None`` — all existing consumers see the exact
    historical container shape.
    """

    __slots__ = ("arrival", "start", "finish", "class_id", "classes",
                 "attempts", "hedged")

    def __init__(self, arrival, start, finish,
                 class_id: Optional[np.ndarray] = None,
                 classes: Tuple[RequestClass, ...] = (),
                 attempts: Optional[np.ndarray] = None,
                 hedged: Optional[np.ndarray] = None):
        self.arrival = np.asarray(arrival, dtype=np.float64)
        self.start = np.asarray(start, dtype=np.float64)
        self.finish = np.asarray(finish, dtype=np.float64)
        if not (len(self.arrival) == len(self.start) == len(self.finish)):
            raise ValueError("arrival/start/finish lengths differ")
        self.class_id = (None if class_id is None
                         else np.asarray(class_id))
        self.classes = tuple(classes)
        if self.class_id is not None and len(self.class_id) != len(self):
            raise ValueError("class_id length differs from arrivals")
        self.attempts = (None if attempts is None
                         else np.asarray(attempts, dtype=np.int64))
        self.hedged = (None if hedged is None
                       else np.asarray(hedged, dtype=bool))
        for name in ("attempts", "hedged"):
            arr = getattr(self, name)
            if arr is not None and len(arr) != len(self):
                raise ValueError(f"{name} length differs from arrivals")

    @property
    def n_retried(self) -> int:
        """Requests that needed more than one attempt."""
        if self.attempts is None:
            return 0
        return int(np.count_nonzero(self.attempts > 1))

    @property
    def n_hedged(self) -> int:
        if self.hedged is None:
            return 0
        return int(np.count_nonzero(self.hedged))

    @classmethod
    def from_records(cls, records: Sequence[RequestRecord]) -> "RequestLog":
        return cls(np.asarray([r.arrival for r in records]),
                   np.asarray([r.start for r in records]),
                   np.asarray([r.finish for r in records]))

    def __len__(self) -> int:
        return len(self.arrival)

    def _class_name(self, i: int) -> str:
        if self.class_id is None:
            return ""
        return self.classes[int(self.class_id[i])].name

    def __getitem__(self, i):
        if isinstance(i, slice):
            cid = None if self.class_id is None else self.class_id[i]
            att = None if self.attempts is None else self.attempts[i]
            hed = None if self.hedged is None else self.hedged[i]
            return RequestLog(self.arrival[i], self.start[i],
                              self.finish[i], cid, self.classes,
                              attempts=att, hedged=hed)
        if i < 0:
            i += len(self)
        if not 0 <= i < len(self):
            raise IndexError(i)
        return RequestRecord(float(self.arrival[i]), float(self.start[i]),
                             float(self.finish[i]), self._class_name(i))

    def latencies(self) -> np.ndarray:
        return self.finish - self.arrival

    def waits(self) -> np.ndarray:
        return self.start - self.arrival

    @property
    def served(self) -> np.ndarray:
        return np.isfinite(self.finish)

    def slo_values(self, default_slo: float) -> np.ndarray:
        """Per-request SLO: the request's class SLO, falling back to
        ``default_slo`` for classless logs and classes without one."""
        if self.class_id is None or not self.classes:
            return np.full(len(self), default_slo)
        per_class = np.asarray(
            [c.slo_s if c.slo_s is not None else default_slo
             for c in self.classes])
        return per_class[self.class_id]


# -- plan snapshots ------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ActivePlan:
    """The kernel's view of whichever plan is currently live, with
    device keys mapped back to *original* topology indices and the
    per-request *non-idle* energy pre-stripped (the presence-interval
    idle billing below prices each idle second exactly once)."""

    latency: float
    interval: float
    per_device_energy: Dict[int, float]
    non_idle_energy: Dict[int, float]
    compute_busy: Dict[int, float]  # schedule compute-busy secs per request
    devices: Tuple[int, ...]


def service_interval(plan) -> float:
    """Steady-state admission interval of a plan's pipeline (fluid
    model): inference requests overlap across stages, so throughput is
    bounded by the bottleneck stage/resource span — delegated to
    :meth:`ScheduleResult.admission_interval`, the shared what-if
    primitive; training iterations serialize on the pipeline flush +
    gradient sync (full latency)."""
    if plan.training:
        return max(plan.latency, 1e-9)
    sched = plan.schedule
    if sched is not None and hasattr(sched, "admission_interval"):
        return sched.admission_interval(plan.n_stages, plan.latency)
    return max(plan.latency / max(plan.n_stages, 1), 1e-9)


def freeze_plan(plan, active: Sequence[int], topo=None) -> ActivePlan:
    """Snapshot a (possibly re-indexed) plan into original device space.

    ``compute_busy`` comes from the Phase-2 schedule
    (``ScheduleResult.busy_seconds`` of each stage's executor) when the
    plan carries one — a device whose stage computes for 80 ms of a
    300 ms request is *computing* 80 ms — falling back to the full plan
    latency for unrefined plans.  ``non_idle_energy`` strips the idle
    draw the plan priced into its own window (``p_idle * latency``) so
    the kernel's presence-interval idle billing prices each idle second
    exactly once even when pipelined windows overlap; pass ``topo=None``
    only when energy attribution is not needed.
    """
    idx = list(active)
    sched = plan.schedule
    compute: Dict[int, float] = {}
    for i, s in enumerate(plan.stages):
        t = None
        if sched is not None and hasattr(sched, "busy_seconds"):
            t = sched.busy_seconds(f"exec{i}") or None
        if t is None:
            t = plan.latency
        for d in s.devices:
            compute[idx[d]] = max(compute.get(idx[d], 0.0), t)
    energy = {idx[d]: e for d, e in plan.per_device_energy.items()}
    if topo is not None:
        non_idle = {
            d: max(e - topo.devices[d].p_idle * plan.latency, 0.0)
            for d, e in energy.items()}
    else:
        non_idle = {d: max(e, 0.0) for d, e in energy.items()}
    return ActivePlan(
        latency=plan.latency,
        interval=service_interval(plan),
        per_device_energy=energy,
        non_idle_energy=non_idle,
        compute_busy=compute,
        devices=tuple(sorted({idx[d] for d in plan.devices})))


# -- the vectorized admission core ---------------------------------------------
class Stream:
    """One admission queue replayed against a dynamics timeline.

    Owns the queue state (``next_free``), the request start/finish
    arrays, and the per-device energy/busy tallies.  ``serve_to(t)``
    vectorizes every pending arrival strictly before ``t`` under the
    current :class:`ActivePlan` via the Lindley recurrence (module
    docstring); ``chunk`` bounds the per-call array width — results are
    invariant to it (chunk=1 degenerates to the historical per-request
    loop), which the segmentation property tests assert.
    """

    def __init__(self, arrivals: np.ndarray,
                 plan: Optional[ActivePlan] = None,
                 alive: bool = True,
                 chunk: Optional[int] = None,
                 preempt: Optional[PreemptionSpec] = None):
        if chunk is not None and chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.arrivals = np.ascontiguousarray(arrivals, dtype=np.float64)
        self.plan = plan
        self.alive = alive
        self.chunk = chunk
        self.next_free = 0.0
        self.service_energy: Dict[int, float] = {}
        self.busy: Dict[int, float] = {}
        self._i = 0
        self._starts: List[np.ndarray] = []
        self._finishes: List[np.ndarray] = []
        # preemption is decided ONCE at construction: a spec whose trace
        # carries no interactive request at all stays on the exact
        # vectorized FIFO path (bit-identity with preemption unarmed)
        self.preempt: Optional[PreemptionSpec] = None
        if preempt is not None:
            ids = np.asarray(preempt.class_id)
            if len(ids) != len(self.arrivals):
                raise ValueError(
                    f"preempt.class_id length {len(ids)} differs from "
                    f"{len(self.arrivals)} arrivals")
            hot = np.isin(ids, list(preempt.interactive))
            if hot.any():
                self.preempt = preempt
                self._hot = hot
                self._fi = 0.0          # interactive admission frontier
                self._fb = 0.0          # batch admission frontier
                #: open interactive occupancy [start, end, charged]
                self._windows: List[List[float]] = []
                #: displaceable batch slots [req index, start, occ end]
                self._pending: List[List[float]] = []
                self._start_arr = np.zeros(len(self.arrivals))
                self._fin_arr = np.zeros(len(self.arrivals))

    def serve_to(self, t: float) -> None:
        """Serve every pending arrival with ``a < t`` (events at
        ``t <= a`` fire before ``a`` is admitted)."""
        self._serve(int(np.searchsorted(self.arrivals, t, side="left")))

    def drain(self) -> None:
        self._serve(len(self.arrivals))

    def stall(self, t: float, stall_s: float) -> None:
        """A migration stall pauses admissions: the pipeline is busy
        moving state until ``max(next_free, t) + stall_s``."""
        if stall_s > 0.0:
            self.next_free = max(self.next_free, t) + stall_s
            if self.preempt is not None:
                self._fi = max(self._fi, t) + stall_s
                self._fb = max(self._fb, t) + stall_s

    def _serve(self, j: int) -> None:
        i = self._i
        if j <= i:
            return
        a = self.arrivals[i:j]
        self._i = j
        n = j - i
        if not self.alive or self.plan is None:
            # degraded: the plan lost a device — requests fail outright,
            # consuming no pipeline capacity and no energy
            if self.preempt is not None:
                self._start_arr[i:j] = a
                self._fin_arr[i:j] = math.inf
            else:
                self._starts.append(a.copy())
                self._finishes.append(np.full(n, math.inf))
            return
        p = self.plan
        if self.preempt is not None:
            self._serve_preemptive(a, i)
        else:
            step = n if self.chunk is None else self.chunk
            for c in range(0, n, step):
                seg = a[c:c + step]
                if len(seg) == 1:   # degenerate chunk = the old loop
                    start = np.asarray([max(float(seg[0]), self.next_free)])
                else:
                    k = np.arange(len(seg), dtype=np.float64)
                    shifted = seg - p.interval * k
                    start = p.interval * k + np.maximum(
                        self.next_free, np.maximum.accumulate(shifted))
                self._starts.append(start)
                self._finishes.append(start + p.latency)
                self.next_free = float(start[-1]) + p.interval
        for d, e in p.non_idle_energy.items():
            self.service_energy[d] = self.service_energy.get(d, 0.0) + n * e
        for d, b in p.compute_busy.items():
            self.busy[d] = self.busy.get(d, 0.0) + n * b

    def _serve_preemptive(self, a: np.ndarray, i0: int) -> None:
        """The two-class priority sweep (scalar — preemption is a
        per-request control decision, so the closed-form segment trick
        doesn't apply; state carries across calls, so results stay
        chunk- and segmentation-invariant).

        Interactive requests run a pure Lindley recurrence on their own
        frontier — they only ever queue behind other interactive
        requests.  Batch requests chain on the batch frontier but (a)
        may not *begin* inside a known interactive occupancy window
        (the interactive is already holding the stage) and (b) are
        *suspended* by every interactive window that opens strictly
        inside their occupancy: each such preemption extends the slot
        (and the request's finish) by the interactive's occupancy plus
        the save/restore overhead.  An interactive arriving later whose
        window opens inside an already-admitted pending slot displaces
        it retroactively, re-propagating the chain of later pending
        slots.  Each interactive window displaces at most one batch
        slot (occupancies never overlap).
        """
        p = self.plan
        interval, lat = p.interval, p.latency
        oh = self.preempt.overhead_s
        for k in range(len(a)):
            i = i0 + k
            t = float(a[k])
            # windows fully in the past can no longer cover or suspend
            # any future admission; settled batch slots are final
            self._windows = [w for w in self._windows if w[1] > t]
            self._pending = [s for s in self._pending if s[2] > t]
            if self._hot[i]:
                s = max(t, self._fi)
                w = [s, s + interval, False]
                # retroactive preemption: this window opens inside an
                # already-admitted (still displaceable) batch slot
                for kk, slot in enumerate(self._pending):
                    if slot[1] < s < slot[2]:
                        w[2] = True
                        bump = interval + oh
                        slot[2] += bump
                        self._fin_arr[int(slot[0])] += bump
                        prev_end = slot[2]
                        for later in self._pending[kk + 1:]:
                            if later[1] < prev_end:
                                d = prev_end - later[1]
                                later[1] += d
                                later[2] += d
                                self._start_arr[int(later[0])] += d
                                self._fin_arr[int(later[0])] += d
                            prev_end = later[2]
                        self._fb = max(self._fb, prev_end)
                        break
                self._windows.append(w)
                self._fi = s + interval
                self._start_arr[i] = s
                self._fin_arr[i] = s + lat
            else:
                s = max(t, self._fb)
                moved = True
                while moved:    # can't begin inside an interactive hold
                    moved = False
                    for w in self._windows:
                        if w[0] <= s < w[1]:
                            s = w[1]
                            moved = True
                end = s + interval
                changed = True
                while changed:  # known windows opening inside suspend it
                    changed = False
                    for w in self._windows:
                        if not w[2] and s < w[0] < end:
                            w[2] = True
                            end += (w[1] - w[0]) + oh
                            changed = True
                self._start_arr[i] = s
                self._fin_arr[i] = s + lat + (end - s - interval)
                self._pending.append([float(i), s, end])
                self._fb = end

    # -- results ----------------------------------------------------------------
    def served_through(self) -> int:
        return self._i

    def arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(arrival, start, finish) over every request served so far."""
        arr = self.arrivals[:self._i]
        if self.preempt is not None:
            return (arr, self._start_arr[:self._i].copy(),
                    self._fin_arr[:self._i].copy())
        if not self._starts:
            return arr, arr.copy(), arr.copy()
        return (arr, np.concatenate(self._starts),
                np.concatenate(self._finishes))

    def last_finite_finish(self) -> float:
        if self.preempt is not None:
            fin = self._fin_arr[:self._i]
            fin = fin[np.isfinite(fin)]
            return float(fin.max()) if len(fin) else 0.0
        out = 0.0
        for f in self._finishes:
            fin = f[np.isfinite(f)]
            if len(fin):
                out = max(out, float(fin[-1]))
        return out


def describe_event(ev: DynamicsEvent) -> str:
    """A human label for a bare event — fault kinds get descriptive
    labels (unannounced faults are the interesting rows in a chaos
    trace); announced-only events keep the historical ``event@t`` form."""
    parts = []
    if ev.crash:
        parts.append("crash: device " + ",".join(map(str, ev.crash)))
    if ev.link_down:
        parts.append("link down: " + ",".join(ev.link_down))
    if ev.link_up:
        parts.append("link up: " + ",".join(ev.link_up))
    if ev.straggler:
        parts.append("straggler: " + ",".join(
            f"{d}->x{format(f, '.3g')}" for d, f in sorted(ev.straggler.items())))
    if not parts:
        return f"event@t={ev.t:g}s"
    return "; ".join(parts)


def normalize_timeline(source) -> List[Tuple[str, DynamicsEvent]]:
    """``DynamicsEvent``s and/or (label, event) pairs → labeled pairs
    sorted by time (the shape both simulate modes replay)."""
    timeline: List[Tuple[str, DynamicsEvent]] = []
    for item in source or ():
        if isinstance(item, DynamicsEvent):
            timeline.append((describe_event(item), item))
        else:
            label, ev = item
            timeline.append((label, ev))
    return sorted(timeline, key=lambda kv: kv[1].t)


def replay(timeline: Sequence[Tuple[str, DynamicsEvent]],
           streams: Sequence[Stream],
           fire) -> None:
    """Drive every stream through one labeled timeline: serve each
    inter-event segment as array ops, then fall back to discrete
    stepping for the adapter (``fire(label, event)`` mutates stream
    plans/aliveness and books stalls via the Stream API), and drain the
    tails once the timeline is exhausted."""
    for label, ev in timeline:
        for s in streams:
            s.serve_to(ev.t)
        fire(label, ev)
    for s in streams:
        s.drain()


# -- presence & ownership (energy attribution) ---------------------------------
def overlap_seconds(intervals: Sequence[Tuple[float, float]],
                    lo: float, hi: float) -> float:
    """Total length of ``intervals`` ∩ ``[lo, hi]``."""
    return sum(max(0.0, min(e, hi) - max(s, lo)) for s, e in intervals)


class PresenceTracker:
    """Per-device presence intervals driven by ``leave``/``join`` churn.

    Idle draw is billed only while a device is *present*: a device that
    leaves at ``t`` stops drawing idle power at ``t`` (the historical
    whole-horizon billing was a documented conservative upper bound).
    """

    def __init__(self, n_devices: int, t0: float = 0.0):
        self._open: Dict[int, Optional[float]] = {
            d: t0 for d in range(n_devices)}
        self._closed: Dict[int, List[Tuple[float, float]]] = {
            d: [] for d in range(n_devices)}

    def apply(self, event: DynamicsEvent) -> None:
        for d in event.leave:
            since = self._open.get(d)
            if since is not None:
                if event.t > since:
                    self._closed[d].append((since, event.t))
                self._open[d] = None
        for d in event.join:
            if d in self._open and self._open[d] is None:
                self._open[d] = event.t

    def intervals(self, horizon: float
                  ) -> Dict[int, List[Tuple[float, float]]]:
        out: Dict[int, List[Tuple[float, float]]] = {}
        for d, closed in self._closed.items():
            iv = [(s, min(e, horizon)) for s, e in closed if s < horizon]
            since = self._open[d]
            if since is not None and since < horizon:
                iv.append((since, horizon))
            out[d] = iv
        return out

    def seconds(self, horizon: float) -> Dict[int, float]:
        return {d: sum(e - s for s, e in iv)
                for d, iv in self.intervals(horizon).items()}


class OwnershipTracker:
    """Which tenant owned each device, over time, across rebalances.

    Fleet idle draw is prorated across *owning* tenants by ownership
    interval — a device that changed hands mid-run bills each owner for
    its own span (the historical attribution handed the whole horizon
    to the final owner); spans owned by no tenant land in the
    fleet-wide totals only.
    """

    def __init__(self, assignments: Mapping[str, Sequence[int]],
                 t0: float = 0.0):
        self._history: List[Tuple[float, Dict[str, Tuple[int, ...]]]] = [
            (t0, self._snap(assignments))]

    @staticmethod
    def _snap(assignments) -> Dict[str, Tuple[int, ...]]:
        return {name: tuple(devs) for name, devs in assignments.items()}

    def update(self, t: float, assignments) -> None:
        snap = self._snap(assignments)
        if snap != self._history[-1][1]:
            self._history.append((t, snap))

    @property
    def history(self) -> List[Tuple[float, Dict[str, Tuple[int, ...]]]]:
        return list(self._history)

    def spans(self, horizon: float
              ) -> Dict[int, List[Tuple[float, float, str]]]:
        """Per-device ``(from, to, owner)`` spans clipped to the run."""
        out: Dict[int, List[Tuple[float, float, str]]] = {}
        bounds = [t for t, _ in self._history] + [horizon]
        for (t0, snap), t1 in zip(self._history, bounds[1:]):
            hi = min(t1, horizon)
            if hi <= t0:
                continue
            for name, devs in snap.items():
                for d in devs:
                    spans = out.setdefault(d, [])
                    if spans and spans[-1][2] == name \
                            and spans[-1][1] == t0:
                        spans[-1] = (spans[-1][0], hi, name)
                    else:
                        spans.append((t0, hi, name))
        return out


# -- the result container ------------------------------------------------------
@dataclasses.dataclass
class ServingTrace:
    """Everything one request-level simulation produced."""

    scenario: str
    strategy: str
    load: ServingLoad
    slo_s: float
    requests: RequestLog
    actions: List["AdapterAction"]
    per_device_energy: Dict[int, float]
    #: schedule-level compute-busy seconds per device over the run
    #: (from ``ScheduleResult.busy_seconds``) — the utilization input
    per_device_busy: Dict[int, float]
    horizon_s: float
    #: presence seconds actually billed for idle draw per device — the
    #: whole horizon unless the device left the fleet mid-run
    per_device_idle_s: Dict[int, float] = dataclasses.field(
        default_factory=dict)
    #: chaos-engine fault records (one dict per injected fault: kind,
    #: target, onset/detect/restore times, mttr_s, affected) — empty for
    #: fault-free runs
    faults: List[Dict[str, object]] = dataclasses.field(default_factory=list)
    #: mean time-to-recovery over service-affecting faults (onset →
    #: serving restored), ``None`` when no fault touched the service
    mttr_s: Optional[float] = None

    def __post_init__(self) -> None:
        if not isinstance(self.requests, RequestLog):
            self.requests = RequestLog.from_records(self.requests)

    def utilization(self, device: int) -> float:
        """Fraction of the run this device spent computing.

        The *raw* busy/horizon ratio — a value above 1.0 means the
        admission policy oversubscribed the device (more compute-seconds
        queued than wall-clock available).  The old silent clamp to 1.0
        hid exactly that signal from the multi-tenant path; use
        :meth:`oversubscribed` for the boolean verdict.
        """
        if self.horizon_s <= 0.0:
            return 0.0
        return self.per_device_busy.get(device, 0.0) / self.horizon_s

    def oversubscribed(self, device: int, tol: float = 1e-6) -> bool:
        """True when more busy-seconds were booked on ``device`` than the
        run's horizon holds — the plan (or a co-tenant) admitted faster
        than the device can serve."""
        return self.utilization(device) > 1.0 + tol

    @property
    def oversubscribed_devices(self) -> List[int]:
        return sorted(d for d in self.per_device_busy
                      if self.oversubscribed(d))

    # -- latency distribution ---------------------------------------------------
    def latencies(self) -> np.ndarray:
        return self.requests.latencies()

    def percentile(self, q: float) -> float:
        """Latency percentile over ALL requests; ``inf`` (not NaN) when
        the quantile falls among failed/unserved ones."""
        with np.errstate(invalid="ignore"):
            v = float(np.percentile(self.latencies(), q))
        return math.inf if math.isnan(v) else v

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p95(self) -> float:
        return self.percentile(95.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    @property
    def mean_latency(self) -> float:
        lat = self.latencies()
        served = lat[self.requests.served]
        return float(np.mean(served)) if len(served) else math.inf

    @property
    def slo_attainment(self) -> float:
        """Fraction of requests served within their SLO (failed =
        missed; multi-class loads judge each request against its own
        tier's SLO)."""
        n = len(self.requests)
        if not n:
            return 1.0
        lat = self.latencies()
        ok = self.requests.served & (
            lat <= self.requests.slo_values(self.slo_s))
        return float(np.count_nonzero(ok)) / n

    @property
    def n_failed(self) -> int:
        return int(np.count_nonzero(~self.requests.served))

    @property
    def n_retried(self) -> int:
        """Requests that needed more than one attempt (chaos runs)."""
        return self.requests.n_retried

    @property
    def n_hedged(self) -> int:
        """Requests whose retry was hedged (chaos runs)."""
        return self.requests.n_hedged

    @property
    def failed_rate(self) -> float:
        n = len(self.requests)
        return self.n_failed / n if n else 0.0

    @property
    def energy(self) -> float:
        return sum(self.per_device_energy.values())

    @property
    def replans(self) -> int:
        return sum(1 for a in self.actions if a.action == "replan")

    def class_metrics(self) -> Dict[str, Dict[str, float]]:
        """Per-SLO-tier latency/attainment breakdown (empty for
        single-class loads)."""
        log = self.requests
        if log.class_id is None or not log.classes:
            return {}
        lat = self.latencies()
        served = log.served
        slo = log.slo_values(self.slo_s)
        out: Dict[str, Dict[str, float]] = {}
        for ci, cls in enumerate(log.classes):
            m = log.class_id == ci
            n = int(np.count_nonzero(m))
            if not n:
                out[cls.name] = {"n": 0}
                continue
            with np.errstate(invalid="ignore"):
                p50, p95, p99 = (float(np.percentile(lat[m], q))
                                 for q in (50.0, 95.0, 99.0))
            ok = served[m] & (lat[m] <= slo[m])
            out[cls.name] = {
                "n": n,
                "slo_s": float(slo[m][0]),
                "p50": math.inf if math.isnan(p50) else p50,
                "p95": math.inf if math.isnan(p95) else p95,
                "p99": math.inf if math.isnan(p99) else p99,
                "slo_attainment": float(np.count_nonzero(ok)) / n,
            }
        return out

    def to_dict(self) -> Dict[str, object]:
        out = {
            "scenario": self.scenario,
            "strategy": self.strategy,
            "rate_rps": _json_num(self.load.rate),
            "n_requests": len(self.requests),
            "slo_s": _json_num(self.slo_s),
            "latency_s": {"p50": _json_num(self.p50),
                          "p95": _json_num(self.p95),
                          "p99": _json_num(self.p99),
                          "mean": _json_num(self.mean_latency)},
            "slo_attainment": self.slo_attainment,
            "failed_requests": self.n_failed,
            "energy_j": _json_num(self.energy),
            "per_device_energy_j": {str(d): _json_num(e)
                                    for d, e in
                                    sorted(self.per_device_energy.items())},
            "per_device_utilization": {str(d): self.utilization(d)
                                       for d in
                                       sorted(self.per_device_energy)},
            "oversubscribed_devices": self.oversubscribed_devices,
            "horizon_s": _json_num(self.horizon_s),
            "actions": [{
                "t": a.t, "label": a.label, "action": a.action,
                "react_s": _json_num(a.react_s),
                "stall_s": _json_num(a.stall_s),
                "latency_after_s": _json_num(a.latency_after),
            } for a in self.actions],
        }
        classes = self.class_metrics()
        if classes:
            out["classes"] = {
                name: {k: (_json_num(v) if isinstance(v, float) else v)
                       for k, v in row.items()}
                for name, row in classes.items()}
        if self.per_device_idle_s:
            out["per_device_idle_s"] = {
                str(d): _json_num(s)
                for d, s in sorted(self.per_device_idle_s.items())}
        if self.faults or self.mttr_s is not None:
            out["retried_requests"] = self.n_retried
            out["hedged_requests"] = self.n_hedged
            out["mttr_s"] = _json_num(self.mttr_s) \
                if self.mttr_s is not None else None
            out["faults"] = [
                {k: (_json_num(v) if isinstance(v, float) else v)
                 for k, v in f.items()} for f in self.faults]
        return out

    def summary(self) -> str:
        def fmt(x: float) -> str:
            return f"{x * 1e3:.0f} ms" if math.isfinite(x) else "unserved"
        lines = [
            f"serving {self.scenario} [{self.strategy}]: "
            f"{len(self.requests)} requests @ {self.load.rate:g}/s "
            f"over {self.horizon_s:.1f}s",
            f"latency p50/p95/p99: {fmt(self.p50)} / {fmt(self.p95)} / "
            f"{fmt(self.p99)}  (SLO {self.slo_s:g}s)",
            f"SLO attainment {self.slo_attainment:.1%}"
            + (f"  ({self.n_failed} failed)" if self.n_failed else ""),
            f"energy {self.energy:.1f} J across "
            f"{len(self.per_device_energy)} devices (idle draw included)",
        ]
        for name, row in self.class_metrics().items():
            if row.get("n"):
                lines.append(
                    f"  class {name:12s} n={row['n']:<6d} "
                    f"p99 {fmt(row['p99'])}  "
                    f"SLO {row['slo_attainment']:.1%} "
                    f"(<= {row['slo_s']:g}s)")
        for a in self.actions:
            stall = f" stall {a.stall_s:.2f}s" if a.stall_s > 0 else ""
            lines.append(f"  t={a.t:6.1f}s  {a.label:48s} -> "
                         f"{a.action:10s}{stall} latency "
                         f"{fmt(a.latency_after)}")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class AdapterAction:
    """What the runtime layer did about one timeline event."""

    t: float
    label: str
    #: "reschedule" | "replan" | "repriced" | "degraded" — plus the
    #: chaos-engine verdicts: "fallback" (instant precomputed-ladder
    #: switch), "brownout" (no QoE-feasible plan: batch admissions
    #: shed), "unobserved" (a pure fault the announced-event path
    #: cannot see)
    action: str
    react_s: float
    stall_s: float
    latency_after: float   # per-request service latency after the event


__all__ = [
    "DEFAULT_N_REQUESTS",
    "ArrivalProcess", "PoissonArrivals", "TraceArrivals",
    "DiurnalArrivals", "MMPPArrivals", "FlashCrowdArrivals",
    "poisson_arrivals",
    "RequestClass", "interactive_batch", "assign_classes",
    "PreemptionSpec", "preemption_spec",
    "ServingLoad", "RequestRecord", "RequestLog",
    "ActivePlan", "freeze_plan", "service_interval",
    "Stream", "replay", "normalize_timeline", "describe_event",
    "PresenceTracker", "OwnershipTracker", "overlap_seconds",
    "ServingTrace", "AdapterAction",
]
