"""Phase 2 — contention-aware network scheduler (§4.2).

For each Phase-1 candidate plan, builds the CEP graph and solves the
scheduling problem of Eq. (6): minimize makespan subject to dependency
and per-resource bandwidth-feasibility constraints.

Deployment-faithful solver: critical-path-priority list scheduling over
*chunked* transfers (each chunk holds its resources exclusively —
spatial→temporal bandwidth sharing, exactly the mechanism §4.2/§5
deploy, since edge devices cannot program WiFi APs). An LP/analytic
lower bound certifies the optimality gap; ``fair`` mode reproduces what
the same plan suffers when transfers contend without scheduling
(baseline behavior, Fig. 2).
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

from .cep import CEPCache, cep_resource_caps
from .device import Topology
from .engine import ScheduleResult
from .plans import ParallelismPlan
from .qoe import QoESpec

#: Max plans whose CEP expansion a scheduler keeps alive (LRU).
_CEP_CACHE_SIZE = 128


@dataclasses.dataclass
class SchedulerConfig:
    chunks: int = 4                  # w sub-transfers per communication task
    modes: Sequence[int] = (1, 2, 4, 8)   # chunk counts searched (Fig. 13 knob)
    time_budget_s: float = 1.0       # responsiveness knob (Fig. 13)


class NetworkScheduler:
    def __init__(self, topo: Topology, qoe: QoESpec,
                 config: Optional[SchedulerConfig] = None):
        self.topo = topo
        self.qoe = qoe
        self.config = config or SchedulerConfig()
        # plan-keyed CEP cache: (stages identity, microbatch count,
        # training) -> (stages ref, CEPCache). Phase-2 refinements of one
        # plan — and of its `dataclasses.replace` descendants, which
        # share the stages list — reuse one CEP expansion. The stages
        # reference pins the id() key and guards against reuse.
        self._cep: "OrderedDict[tuple, tuple]" = OrderedDict()
        # CEP dependency structures shared across like-shaped plans
        self._cep_structs: Dict[tuple, tuple] = {}

    def _cep_for(self, plan: ParallelismPlan) -> CEPCache:
        key = (id(plan.stages), plan.n_microbatches, plan.training)
        hit = self._cep.get(key)
        if hit is not None and hit[0] is plan.stages:
            self._cep.move_to_end(key)
            return hit[1]
        cep = CEPCache(plan, self.topo, self._cep_structs)
        self._cep[key] = (plan.stages, cep)
        while len(self._cep) > _CEP_CACHE_SIZE:
            self._cep.popitem(last=False)
        return cep

    @staticmethod
    def _exec_speeds(plan: ParallelismPlan,
                     device_speed: Optional[Dict[int, float]]) -> Dict[str, float]:
        """Convert device-level speed factors into per-stage executor
        factors (stage rate = Σ share_d × f_d under proportional split)."""
        if not device_speed:
            return {}
        out: Dict[str, float] = {}
        for s, st in enumerate(plan.stages):
            f = sum(st.microbatch_split[d] * device_speed.get(d, 1.0)
                    for d in st.devices)
            out[f"exec{s}"] = max(f, 1e-6)
        return out

    # -- single-plan refinement ---------------------------------------------------
    def refine(self, plan: ParallelismPlan,
               compute_speed: Optional[Dict[int, float]] = None,
               bandwidth_scale: Optional[Dict[str, float]] = None,
               modes: Optional[Sequence[int]] = None) -> ParallelismPlan:
        """Re-evaluates ``plan`` under real contention with Dora's chunked
        temporal scheduling; picks the best chunk count within budget.

        ``modes`` overrides the configured chunk counts for this call —
        warm-start replanning passes the plan's previously winning count
        so a steady-state re-refine runs one schedule, not five."""
        cep = self._cep_for(plan)
        caps = self._caps(bandwidth_scale)
        compute_speed = self._exec_speeds(plan, compute_speed)
        best: Tuple[float, Optional[ScheduleResult], int] = (math.inf, None, 1)
        t0 = time.perf_counter()
        # w=0 — the null schedule (fluid sharing, no intervention). Dora's
        # temporal scheduling must never lose to just sending the bytes.
        res = cep.run(0, caps, comm_mode="fair", compute_speed=compute_speed)
        best = (res.makespan, res, 0)
        for w in (self.config.modes if modes is None else modes):
            res = cep.run(w, caps, comm_mode="scheduled",
                          compute_speed=compute_speed)
            if res.makespan < best[0]:
                best = (res.makespan, res, w)
            if time.perf_counter() - t0 > self.config.time_budget_s:
                break
        lat, sched, w = best
        refined = dataclasses.replace(plan)
        refined.latency = lat
        refined.schedule = sched
        refined.meta = dict(plan.meta, chunks=w,
                            lp_bound=self.lower_bound(plan, caps, cep=cep))
        self._reprice(refined)
        return refined

    def evaluate_fair(self, plan: ParallelismPlan,
                      compute_speed: Optional[Dict[int, float]] = None,
                      bandwidth_scale: Optional[Dict[str, float]] = None) -> ParallelismPlan:
        """Contention WITHOUT scheduling: transfers fluid-share the medium
        (how contention-oblivious planners actually execute)."""
        res = self._cep_for(plan).run(
            0, self._caps(bandwidth_scale), comm_mode="fair",
            compute_speed=self._exec_speeds(plan, compute_speed))
        out = dataclasses.replace(plan)
        out.latency = res.makespan
        out.schedule = res
        self._reprice(out)
        return out

    # -- Alg. 1 line 4: refine candidates, return ranked --------------------------
    def refine_candidates(self, plans: Sequence[ParallelismPlan],
                          keep: Optional[int] = None) -> List[ParallelismPlan]:
        """Two-pass refinement: (1) re-rank the whole candidate pool with
        one cheap contention-aware evaluation each — the fix for Phase-1
        rank inversions under contention; (2) run the full chunk-count
        search on the ``keep`` best (Fig. 13's accuracy/responsiveness
        knob). Returns every plan, accurately priced, best first."""
        keep = keep if keep is not None else max(len(plans) // 4, 4)
        fair = [self.evaluate_fair(p) for p in plans]
        fair.sort(key=lambda p: p.objective)
        head = [self.refine(p) for p in fair[:keep]]
        out = head + fair[keep:]
        out.sort(key=lambda p: p.objective)
        return out

    # -- Eq. (6) lower bound ------------------------------------------------------
    def lower_bound(self, plan: ParallelismPlan, caps: Dict[str, float],
                    cep: Optional[CEPCache] = None) -> float:
        """max(zero-contention critical path, per-resource volume bound,
        per-executor work bound) — certifies list-schedule quality.

        Reuses the plan's cached CEP tasks and critical-path priorities
        (``refine`` passes its own ``cep``) instead of rebuilding the
        graph and re-running ``assign_priorities``."""
        if cep is None:
            cep = self._cep_for(plan)
        dist = cep.priorities(1, caps)      # == downstream critical path
        cp = max(dist.values(), default=0.0)
        vol: Dict[str, float] = {}
        work: Dict[str, float] = {}
        for t in cep.tasks(1):
            if t.kind == "comm":
                for r in t.resources:
                    vol[r] = vol.get(r, 0.0) + t.nbytes / caps[r]
            elif t.executor:
                work[t.executor] = work.get(t.executor, 0.0) + t.duration
        return max([cp] + list(vol.values()) + list(work.values()))

    # -- helpers -------------------------------------------------------------------
    def _caps(self, scale: Optional[Dict[str, float]]) -> Dict[str, float]:
        caps = cep_resource_caps(self.topo)
        for k, s in (scale or {}).items():
            # unknown resources are tolerated: a fleet tenant's
            # cumulative state may carry shifts for links outside its
            # current sub-topology
            if k in caps:
                caps[k] = caps[k] * s
        return caps

    def _reprice(self, plan: ParallelismPlan) -> None:
        """Recompute energy/objective for the refined latency (idle power
        integrates over the true makespan)."""
        from .cost_model import plan_device_energy
        plan.per_device_energy = plan_device_energy(
            plan.stages, self.topo, plan.n_microbatches, plan.training, plan.latency)
        plan.energy = sum(plan.per_device_energy.values())
        plan.objective = self.qoe.objective(plan.energy, plan.latency)
