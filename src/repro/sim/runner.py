"""Execute plans on the real (contended) topology; planner comparisons.

Dora plans run through the Phase-2 network scheduler (chunked temporal
sharing); contention-oblivious baselines execute with fluid-shared
("fair") contention — what a real shared medium does to them (Fig. 2).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

from ..core.cost_model import Workload
from ..core.device import Topology
from ..core.graph_builders import paper_model
from ..core.planner import PlanningResult
from ..core.planning_graph import ModelGraph
from ..core.plans import ParallelismPlan
from ..core.qoe import QoESpec
from ..core.scheduler import NetworkScheduler, SchedulerConfig
from ..scenarios import PAPER_SETTINGS, get_scenario
from ..strategies import StrategyError, get_strategy

SETTINGS = PAPER_SETTINGS
PAPER_MODELS = ("bert", "qwen3-0.6b", "qwen3-1.7b", "qwen-omni")

#: Fig. 8/9 comparison set — resolved through the strategy registry.
COMPARISON_PLANNERS = ("edgeshard", "alpa", "metis", "asteroid", "dora")


@dataclasses.dataclass
class ExecResult:
    planner: str
    latency: float = float("inf")       # seconds (iteration or batch-forward)
    energy: float = float("inf")        # joules over the run unit
    plan: Optional[ParallelismPlan] = None
    plan_seconds: float = 0.0           # planning wall time
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None

    @property
    def failure_label(self) -> str:
        """Short table cell for a failed run: the paper's OOM finding vs
        an unexpected strategy bug."""
        if self.error is None:
            return ""
        return "OOM" if "OOM" in self.error else "ERR"


def workload_for(mode: str, global_batch: int = 32,
                 microbatch: int = 4) -> Workload:
    """Paper-style workloads: training iterations vs inference forwards.

    Derived from the canonical ``core.cost_model.PAPER_*_WORKLOAD``
    constants (also used by the scenario catalogue), so scenario-default
    and mode-override sweeps stay comparable. Edge tuning state is bf16
    params + grads + momentum (3× param bytes): a 6B Qwen-Omni cannot
    hold fp32 Adam m/v on phones/laptops, and §5's prototype fine-tunes
    with DDP/PiPPy-style bf16 state.
    """
    from ..core.cost_model import PAPER_SERVE_WORKLOAD, PAPER_TRAIN_WORKLOAD
    if mode == "train":
        return dataclasses.replace(PAPER_TRAIN_WORKLOAD,
                                   global_batch=global_batch,
                                   microbatch_size=microbatch)
    return dataclasses.replace(PAPER_SERVE_WORKLOAD,
                               global_batch=max(global_batch // 4, 4))


def execute_plan(plan: ParallelismPlan, topo: Topology, qoe: QoESpec,
                 scheduled: bool,
                 compute_speed: Optional[Dict[int, float]] = None,
                 bandwidth_scale: Optional[Dict[str, float]] = None
                 ) -> ParallelismPlan:
    """Run one plan on the real topology. ``scheduled=True`` applies
    Dora's Phase-2 chunked schedule; ``False`` is fluid-share contention."""
    sched = NetworkScheduler(topo, qoe)
    if scheduled:
        return sched.refine(plan, compute_speed=compute_speed,
                            bandwidth_scale=bandwidth_scale)
    return sched.evaluate_fair(plan, compute_speed=compute_speed,
                               bandwidth_scale=bandwidth_scale)


def dora_plan(graph: ModelGraph, topo: Topology, qoe: QoESpec, wl: Workload,
              top_k: int = 10,
              scheduler_config: Optional[SchedulerConfig] = None
              ) -> PlanningResult:
    strat = get_strategy("dora", top_k=top_k, sweep_microbatch=True,
                         scheduler_config=scheduler_config)
    return strat.plan(graph, topo, qoe, wl)


def run_strategy(name: str, graph: ModelGraph, topo: Topology, wl: Workload,
                 qoe: QoESpec, **params) -> ExecResult:
    """Resolve one registered strategy and wrap its outcome (errors are a
    result, not an exception — a failing baseline is the finding)."""
    strat = get_strategy(name, **params)
    t0 = time.perf_counter()
    try:
        res = strat.plan(graph, topo, qoe, wl)
    except StrategyError as e:         # expected planner failure (e.g. OOM)
        return ExecResult(planner=name, error=str(e),
                          plan_seconds=time.perf_counter() - t0)
    except Exception as e:  # noqa: BLE001 — keep comparing, but mark as a bug
        return ExecResult(planner=name, error=f"{type(e).__name__}: {e}",
                          plan_seconds=time.perf_counter() - t0)
    return ExecResult(planner=name, latency=res.best.latency,
                      energy=res.best.energy, plan=res.best,
                      plan_seconds=res.total_s)


def compare_planners(graph: ModelGraph, topo: Topology, wl: Workload,
                     qoe: Optional[QoESpec] = None, top_k: int = 10,
                     planners: Sequence[str] = COMPARISON_PLANNERS
                     ) -> Dict[str, ExecResult]:
    """Fig. 8/9 harness: every planner on one (model, setting, workload).

    All planners resolve through the strategy registry; ``dora`` gets the
    richer ``top_k``/microbatch-sweep search the benchmarks use."""
    qoe = qoe or QoESpec(t_qoe=0.0, lam=1e15)   # latency-optimized comparison
    out: Dict[str, ExecResult] = {}
    for name in planners:
        params = (dict(top_k=top_k, sweep_microbatch=True)
                  if name == "dora" else {})
        out[name] = run_strategy(name, graph, topo, wl, qoe, **params)
    return out


def best_baseline(results: Dict[str, ExecResult]) -> Tuple[str, ExecResult]:
    ok = {k: v for k, v in results.items() if k != "dora" and v.ok}
    if not ok:
        raise RuntimeError("no baseline produced a valid plan")
    name = min(ok, key=lambda k: ok[k].latency)
    return name, ok[name]


def _norm_mode(mode: str) -> str:
    """Benchmarks say "infer"; Scenario.mode says "serve" — same thing."""
    if mode in ("infer", "serve"):
        return "serve"
    if mode == "train":
        return "train"
    raise ValueError(f"unknown mode {mode!r}: expected 'train', 'serve' "
                     f"or 'infer'")


def scenario_case(setting: str, model: Optional[str] = None,
                  mode: Optional[str] = None, seq_len: Optional[int] = None
                  ) -> Tuple[Topology, ModelGraph, Workload]:
    """(topology, graph, workload) for one registered scenario.

    The scenario supplies all three by default; ``model``/``mode``/
    ``seq_len`` override its model, train-vs-serve direction or
    sequence length for paper-style sweeps (the workload geometry
    then comes from ``workload_for``).
    """
    sc = get_scenario(setting)
    mode = _norm_mode(mode) if mode is not None else sc.mode
    topo, graph = setting_and_graph(setting, model, mode, seq_len)
    wl = sc.workload if mode == sc.mode else (
        workload_for("train" if mode == "train" else "infer"))
    return topo, graph, wl


def setting_and_graph(setting: str, model: Optional[str] = None,
                      mode: str = "train", seq_len: Optional[int] = None
                      ) -> Tuple[Topology, ModelGraph]:
    """Resolve a scenario name to (topology, planning graph).

    ``setting`` is any name in the ``repro.scenarios`` registry (the
    paper's Table-3 settings included). ``model`` overrides the
    scenario's own model with a paper-model name, which is how the
    Fig. 8/9 harnesses sweep models × settings over one fleet.
    ``seq_len`` defaults to the scenario's own sequence length
    (paper-model overrides keep the historical 512).
    """
    sc = get_scenario(setting)
    mode = _norm_mode(mode)
    topo = sc.build_topology()
    if seq_len is not None:
        eff_seq = seq_len                            # explicit always wins
    elif mode != "train":
        eff_seq = 1                                  # per-token serving
    else:
        eff_seq = sc.seq_len if model is None else 512
    if model is None:
        graph = sc.build_graph(seq_len=eff_seq)
    else:
        graph = paper_model(model, seq_len=eff_seq)
    return topo, graph
