"""Request-level serving simulator (§4.3 under open-loop load).

The plan-level stack answers "how fast is one iteration/token?";
a serving deployment is judged on what *requests* experience: tail
latency and SLO attainment under queueing, runtime dynamics and fleet
churn.  This module layers an open-loop request queue on top of the
planning stack:

* **Arrivals** — a Poisson process at the scenario's registered
  ``request_rate`` (deterministic per seed) or an explicit arrival
  trace.
* **Service** — a fluid pipeline model of the active plan: a request
  admitted at ``s`` finishes at ``s + plan.latency``; the pipeline
  admits the next request after the bottleneck interval (the busiest
  stage executor / network resource per request from the Phase-2
  schedule — stages overlap across requests, so throughput is bounded
  by the slowest stage, not the average; full ``latency`` for
  training, where the flush + gradient sync serialize iterations).
  Service time is sampled at admission.
* **Dynamics** — the scenario's timeline plays out mid-run.  With the
  ``dora`` strategy, events flow through the armed
  :class:`~repro.dora.ServeSession` (cumulative conditions, §4.3
  reschedule/replan, migration stalls pause admissions); device
  ``leave``/``join`` churn shrinks/grows the fleet and forces a replan
  on the surviving topology.  Non-adaptive baseline strategies keep
  their static plan: it is repriced under the merged conditions with
  fluid-fair contention, and churn that removes a device the plan
  placed layers on makes every subsequent request fail until the
  device rejoins.
* **Energy** — idle draw is a baseline: every device is billed
  ``p_idle`` over the whole run exactly once, and each request adds
  only the active plan's *non-idle* per-device energy (compute + DVFS
  + network bytes — the plan's energy minus the idle draw its window
  already prices).  Overlapping pipeline windows therefore never bill
  the same idle second twice.  Departed devices are still billed idle
  for simplicity — a conservative upper bound.

Entry points: :func:`simulate_requests` (also reachable as
``dora.simulate(scenario, mode="requests")``) returning a
:class:`ServingTrace` with p50/p95/p99 latency, SLO attainment %,
per-device energy and every adapter action.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.adapter import DynamicsEvent, RuntimeState
from ..core.plans import ParallelismPlan
from ..core.scheduler import NetworkScheduler
from ..dora import _json_num

#: Default number of requests when a load doesn't specify one.
DEFAULT_N_REQUESTS = 200


@dataclasses.dataclass(frozen=True)
class ServingLoad:
    """Open-loop request load for one serving simulation.

    ``rate`` — mean arrivals per second (Poisson process);
    ``n_requests`` — how many requests to generate;
    ``slo_s`` — per-request latency SLO (defaults to the scenario's
    ``t_qoe``); ``seed`` — arrival-process seed (same seed + same rate
    → identical arrivals; the exponential gaps scale with ``1/rate``,
    so traces at different rates are coupled and queueing is monotone
    in rate).
    """

    rate: float
    n_requests: int = DEFAULT_N_REQUESTS
    slo_s: Optional[float] = None
    seed: int = 0


def poisson_arrivals(rate: float, n_requests: int, seed: int = 0) -> np.ndarray:
    """Arrival times of an open-loop Poisson process (deterministic per
    seed; gaps are standard exponentials scaled by ``1/rate``, so the
    same seed at a higher rate yields a pointwise-compressed trace)."""
    if rate <= 0.0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    if n_requests <= 0:
        raise ValueError(f"n_requests must be positive, got {n_requests}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=int(n_requests)))


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    """One request's life: arrival → service start → finish.
    ``finish`` is ``inf`` when the request could not be served (the
    static plan lost a device to churn)."""

    arrival: float
    start: float
    finish: float

    @property
    def latency(self) -> float:
        return self.finish - self.arrival

    @property
    def waiting(self) -> float:
        return self.start - self.arrival

    @property
    def served(self) -> bool:
        return math.isfinite(self.finish)


@dataclasses.dataclass(frozen=True)
class AdapterAction:
    """What the runtime layer did about one timeline event."""

    t: float
    label: str
    action: str            # "reschedule" | "replan" | "repriced" | "degraded"
    react_s: float
    stall_s: float
    latency_after: float   # per-request service latency after the event


@dataclasses.dataclass
class _ActivePlan:
    """The serving loop's view of whichever plan is currently live,
    with device keys mapped back to *original* topology indices."""

    latency: float
    interval: float
    per_device_energy: Dict[int, float]
    compute_busy: Dict[int, float]  # schedule compute-busy secs per request
    devices: Tuple[int, ...]


def _service_interval(plan: ParallelismPlan) -> float:
    """Steady-state admission interval of the pipeline (fluid model):
    inference requests overlap across stages; training iterations
    serialize on the pipeline flush + gradient sync.

    A pipeline's steady-state throughput is bounded by its *bottleneck*
    — the busiest stage executor (or network resource) per request —
    not by the average stage span.  Refined plans carry a Phase-2
    schedule whose per-executor busy seconds give that bound exactly;
    admitting any faster would oversubscribe the bottleneck device.
    Unrefined plans (no schedule) fall back to the balanced-pipeline
    approximation ``latency / n_stages``.
    """
    if plan.training:
        return max(plan.latency, 1e-9)
    sched = plan.schedule
    if sched is not None and hasattr(sched, "busy_seconds"):
        spans = [sched.busy_seconds(f"exec{i}")
                 for i in range(plan.n_stages)]
        spans += list(getattr(sched, "resource_busy", {}).values())
        bottleneck = max((s for s in spans if s), default=0.0)
        if bottleneck > 0.0:
            # the bottleneck span never exceeds the makespan, but guard
            # against hand-built schedules that claim otherwise
            return max(min(bottleneck, plan.latency), 1e-9)
    return max(plan.latency / max(plan.n_stages, 1), 1e-9)


def _freeze(plan: ParallelismPlan, active: Sequence[int]) -> _ActivePlan:
    """Snapshot a (possibly re-indexed) plan into original device space.

    ``compute_busy`` comes from the Phase-2 schedule
    (``ScheduleResult.busy_seconds`` of each stage's executor) when the
    plan carries one — a device whose stage computes for 80 ms of a
    300 ms request is *computing* 80 ms — falling back to the full plan
    latency for unrefined plans.  It feeds the trace's utilization
    report only; energy bookkeeping bills idle draw once over the whole
    run and adds each request's non-idle energy on top.
    """
    idx = list(active)
    sched = plan.schedule
    compute: Dict[int, float] = {}
    for i, s in enumerate(plan.stages):
        t = None
        if sched is not None and hasattr(sched, "busy_seconds"):
            t = sched.busy_seconds(f"exec{i}") or None
        if t is None:
            t = plan.latency
        for d in s.devices:
            compute[idx[d]] = max(compute.get(idx[d], 0.0), t)
    return _ActivePlan(
        latency=plan.latency,
        interval=_service_interval(plan),
        per_device_energy={idx[d]: e
                           for d, e in plan.per_device_energy.items()},
        compute_busy=compute,
        devices=tuple(sorted({idx[d] for d in plan.devices})))


@dataclasses.dataclass
class ServingTrace:
    """Everything one request-level simulation produced."""

    scenario: str
    strategy: str
    load: ServingLoad
    slo_s: float
    requests: List[RequestRecord]
    actions: List[AdapterAction]
    per_device_energy: Dict[int, float]
    #: schedule-level compute-busy seconds per device over the run
    #: (from ``ScheduleResult.busy_seconds``) — the utilization input
    per_device_busy: Dict[int, float]
    horizon_s: float

    def utilization(self, device: int) -> float:
        """Fraction of the run this device spent computing.

        The *raw* busy/horizon ratio — a value above 1.0 means the
        admission policy oversubscribed the device (more compute-seconds
        queued than wall-clock available).  The old silent clamp to 1.0
        hid exactly that signal from the multi-tenant path; use
        :meth:`oversubscribed` for the boolean verdict.
        """
        if self.horizon_s <= 0.0:
            return 0.0
        return self.per_device_busy.get(device, 0.0) / self.horizon_s

    def oversubscribed(self, device: int, tol: float = 1e-6) -> bool:
        """True when more busy-seconds were booked on ``device`` than the
        run's horizon holds — the plan (or a co-tenant) admitted faster
        than the device can serve."""
        return self.utilization(device) > 1.0 + tol

    @property
    def oversubscribed_devices(self) -> List[int]:
        return sorted(d for d in self.per_device_busy
                      if self.oversubscribed(d))

    # -- latency distribution ---------------------------------------------------
    def latencies(self) -> np.ndarray:
        return np.array([r.latency for r in self.requests])

    def percentile(self, q: float) -> float:
        """Latency percentile over ALL requests; ``inf`` (not NaN) when
        the quantile falls among failed/unserved ones."""
        with np.errstate(invalid="ignore"):
            v = float(np.percentile(self.latencies(), q))
        return math.inf if math.isnan(v) else v

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p95(self) -> float:
        return self.percentile(95.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    @property
    def mean_latency(self) -> float:
        served = [r.latency for r in self.requests if r.served]
        return float(np.mean(served)) if served else math.inf

    @property
    def slo_attainment(self) -> float:
        """Fraction of requests served within the SLO (failed = missed)."""
        if not self.requests:
            return 1.0
        ok = sum(1 for r in self.requests
                 if r.served and r.latency <= self.slo_s)
        return ok / len(self.requests)

    @property
    def n_failed(self) -> int:
        return sum(1 for r in self.requests if not r.served)

    @property
    def energy(self) -> float:
        return sum(self.per_device_energy.values())

    @property
    def replans(self) -> int:
        return sum(1 for a in self.actions if a.action == "replan")

    def to_dict(self) -> Dict[str, object]:
        return {
            "scenario": self.scenario,
            "strategy": self.strategy,
            "rate_rps": _json_num(self.load.rate),
            "n_requests": len(self.requests),
            "slo_s": _json_num(self.slo_s),
            "latency_s": {"p50": _json_num(self.p50),
                          "p95": _json_num(self.p95),
                          "p99": _json_num(self.p99),
                          "mean": _json_num(self.mean_latency)},
            "slo_attainment": self.slo_attainment,
            "failed_requests": self.n_failed,
            "energy_j": _json_num(self.energy),
            "per_device_energy_j": {str(d): _json_num(e)
                                    for d, e in
                                    sorted(self.per_device_energy.items())},
            "per_device_utilization": {str(d): self.utilization(d)
                                       for d in
                                       sorted(self.per_device_energy)},
            "oversubscribed_devices": self.oversubscribed_devices,
            "horizon_s": _json_num(self.horizon_s),
            "actions": [{
                "t": a.t, "label": a.label, "action": a.action,
                "react_s": _json_num(a.react_s),
                "stall_s": _json_num(a.stall_s),
                "latency_after_s": _json_num(a.latency_after),
            } for a in self.actions],
        }

    def summary(self) -> str:
        def fmt(x: float) -> str:
            return f"{x * 1e3:.0f} ms" if math.isfinite(x) else "unserved"
        lines = [
            f"serving {self.scenario} [{self.strategy}]: "
            f"{len(self.requests)} requests @ {self.load.rate:g}/s "
            f"over {self.horizon_s:.1f}s",
            f"latency p50/p95/p99: {fmt(self.p50)} / {fmt(self.p95)} / "
            f"{fmt(self.p99)}  (SLO {self.slo_s:g}s)",
            f"SLO attainment {self.slo_attainment:.1%}"
            + (f"  ({self.n_failed} failed)" if self.n_failed else ""),
            f"energy {self.energy:.1f} J across "
            f"{len(self.per_device_energy)} devices (idle draw included)",
        ]
        for a in self.actions:
            stall = f" stall {a.stall_s:.2f}s" if a.stall_s > 0 else ""
            lines.append(f"  t={a.t:6.1f}s  {a.label:48s} -> "
                         f"{a.action:10s}{stall} latency "
                         f"{fmt(a.latency_after)}")
        return "\n".join(lines)


def normalize_timeline(source) -> List[Tuple[str, DynamicsEvent]]:
    """``DynamicsEvent``s and/or (label, event) pairs → labeled pairs
    sorted by time (the shape both simulate modes replay)."""
    timeline: List[Tuple[str, DynamicsEvent]] = []
    for item in source or ():
        if isinstance(item, DynamicsEvent):
            timeline.append((f"event@t={item.t:g}s", item))
        else:
            label, ev = item
            timeline.append((label, ev))
    return sorted(timeline, key=lambda kv: kv[1].t)


def default_load(scenario, plan_latency: float) -> ServingLoad:
    """The scenario's registered request rate, or a half-capacity
    fallback for ad-hoc scenarios that don't declare one."""
    rate = getattr(scenario, "request_rate", None)
    if rate is None:
        rate = 0.5 / max(plan_latency, 1e-9)
    return ServingLoad(rate=rate)


def simulate_requests(scenario,
                      *,
                      strategy: str = "dora",
                      load: Optional[ServingLoad] = None,
                      events=None,
                      session=None,
                      report=None,
                      arrivals: Optional[Sequence[float]] = None,
                      **overrides) -> ServingTrace:
    """Run one request-level serving simulation.

    ``strategy="dora"`` arms (or reuses, via ``session=``) a
    :class:`~repro.dora.ServeSession` and lets the runtime adapter react
    to every timeline event; any other registered strategy plans once
    (or reuses an existing ``report=`` from ``dora.plan`` of the same
    scenario and strategy) and stays static — its plan is repriced
    under the merged conditions (fluid-fair contention) and breaks
    outright when churn removes a device it placed layers on.
    ``events`` defaults to the scenario's registered timeline;
    ``arrivals`` (explicit trace, seconds) overrides the Poisson
    process.  Keyword ``overrides`` flow to ``dora.serve``/``dora.plan``.
    """
    from .. import dora  # local import: dora lazily imports this module

    sc = dora.get_scenario(scenario)
    if session is not None and strategy != "dora":
        raise ValueError("session= implies the adaptive dora strategy; "
                         f"got strategy={strategy!r}")
    scheduler: Optional[NetworkScheduler] = None
    if strategy == "dora":
        if report is not None:
            raise ValueError("the dora strategy reuses a session=, "
                             "not a report=")
        if session is None:
            session = dora.serve(sc, **overrides)
        else:
            have = session.report.scenario.name
            if have != sc.name:
                raise ValueError(f"session was served for scenario {have!r},"
                                 f" not {sc.name!r}")
            if overrides:
                raise ValueError("overrides are ignored when reusing a "
                                 "session; pass them to dora.serve instead")
        report = session.report
        active = _freeze(session.current, session.active)
    else:
        if report is None:
            report = dora.plan(sc, strategy=strategy, **overrides)
        elif report.scenario.name != sc.name or report.strategy != strategy:
            raise ValueError(
                f"report= was planned for ({report.scenario.name!r}, "
                f"{report.strategy!r}), not ({sc.name!r}, {strategy!r})")
        scheduler = NetworkScheduler(report.topology, report.qoe)
        active = _freeze(report.best, range(report.topology.n))
    topo = report.topology
    qoe = report.qoe

    if load is None:
        load = default_load(sc, active.latency)
    slo = load.slo_s if load.slo_s is not None else qoe.t_qoe

    if arrivals is not None:
        arr = np.asarray(sorted(float(a) for a in arrivals))
        if len(arr) and arr[0] < 0.0:
            raise ValueError("arrival times must be non-negative")
    else:
        arr = poisson_arrivals(load.rate, load.n_requests, load.seed)

    timeline = normalize_timeline(
        events if events is not None else sc.timeline)

    # static-strategy runtime view (the dora path keeps its own inside
    # the ServeSession)
    static_state = RuntimeState()
    static_fleet = set(range(topo.n))
    static_devices = set(active.devices)
    static_alive = True

    records: List[RequestRecord] = []
    actions: List[AdapterAction] = []
    service_energy: Dict[int, float] = {}       # non-idle joules per device
    compute_busy: Dict[int, float] = {}
    next_free = 0.0
    ev_i = 0

    def fire(label: str, ev: DynamicsEvent) -> None:
        nonlocal active, next_free, static_state, static_alive
        if strategy == "dora":
            new, act, react = session.on_dynamics(ev)
            stall = (float(new.meta.get("switch_stall_s", 0.0))
                     if act == "replan" else 0.0)
            if stall > 0.0:
                next_free = max(next_free, ev.t) + stall
            active = _freeze(new, session.active)
            actions.append(AdapterAction(t=ev.t, label=label, action=act,
                                         react_s=react, stall_s=stall,
                                         latency_after=active.latency))
            return
        # static baseline: merge conditions, apply churn, reprice
        t0 = time.perf_counter()
        static_state = static_state.apply(ev)
        static_fleet.difference_update(ev.leave)
        static_fleet.update(ev.join)
        static_alive = static_devices <= static_fleet
        if not static_alive:
            act, lat = "degraded", math.inf
        else:
            repriced = scheduler.evaluate_fair(
                report.best,
                compute_speed=dict(static_state.compute_speed),
                bandwidth_scale=dict(static_state.bandwidth_scale))
            active = _freeze(repriced, range(topo.n))
            act, lat = "repriced", active.latency
        actions.append(AdapterAction(t=ev.t, label=label, action=act,
                                     react_s=time.perf_counter() - t0,
                                     stall_s=0.0, latency_after=lat))

    for a in arr:
        while ev_i < len(timeline) and timeline[ev_i][1].t <= a:
            fire(*timeline[ev_i])
            ev_i += 1
        if strategy != "dora" and not static_alive:
            records.append(RequestRecord(arrival=float(a), start=float(a),
                                         finish=math.inf))
            continue
        start = max(float(a), next_free)
        finish = start + active.latency
        next_free = start + active.interval
        records.append(RequestRecord(arrival=float(a), start=start,
                                     finish=finish))
        for d, e in active.per_device_energy.items():
            # strip the idle draw the plan priced into its own window —
            # the baseline p_idle·horizon below bills it exactly once,
            # even when pipelined windows overlap
            non_idle = e - topo.devices[d].p_idle * active.latency
            service_energy[d] = service_energy.get(d, 0.0) \
                + max(non_idle, 0.0)
        for d, b in active.compute_busy.items():
            compute_busy[d] = compute_busy.get(d, 0.0) + b
    # consume the rest of the timeline so the trace covers every event
    while ev_i < len(timeline):
        fire(*timeline[ev_i])
        ev_i += 1

    horizon = max([0.0, float(arr[-1]) if len(arr) else 0.0,
                   *(r.finish for r in records if r.served),
                   *(ev.t for _, ev in timeline)])
    per_device_energy: Dict[int, float] = {}
    for d, dev in enumerate(topo.devices):
        per_device_energy[d] = service_energy.get(d, 0.0) \
            + dev.p_idle * horizon

    return ServingTrace(scenario=sc.name, strategy=strategy, load=load,
                        slo_s=slo, requests=records, actions=actions,
                        per_device_energy=per_device_energy,
                        per_device_busy=dict(compute_busy),
                        horizon_s=float(horizon))


__all__ = [
    "ServingLoad", "RequestRecord", "AdapterAction", "ServingTrace",
    "poisson_arrivals", "default_load", "normalize_timeline",
    "simulate_requests", "DEFAULT_N_REQUESTS",
]
