"""Request-level serving simulator (§4.3 under open-loop load).

The plan-level stack answers "how fast is one iteration/token?";
a serving deployment is judged on what *requests* experience: tail
latency and SLO attainment under queueing, runtime dynamics and fleet
churn.  This module is a thin adapter over the shared serving kernel
(:mod:`repro.core.events`), which owns arrival generation, the
vectorized admission/queueing recurrence, dynamics segmentation and
energy attribution; what remains here is the strategy wiring:

* **Arrivals** — the load's arrival process (Poisson at the scenario's
  registered ``request_rate`` by default; diurnal/MMPP/flash-crowd
  curves and multi-class SLO tiers via :class:`ServingLoad`) or an
  explicit arrival trace.
* **Service** — a fluid pipeline model of the active plan: a request
  admitted at ``s`` finishes at ``s + plan.latency``; the pipeline
  admits the next request after the bottleneck interval
  (:meth:`~repro.core.engine.ScheduleResult.admission_interval` — the
  busiest stage executor / network resource per request from the
  Phase-2 schedule; full ``latency`` for training, where the flush +
  gradient sync serialize iterations).  Between dynamics events the
  kernel serves whole arrival segments as array ops, so 10^6-request
  traces run in seconds.
* **Dynamics** — the scenario's timeline plays out mid-run.  With the
  ``dora`` strategy, events flow through the armed
  :class:`~repro.dora.ServeSession` (cumulative conditions, §4.3
  reschedule/replan, migration stalls pause admissions); device
  ``leave``/``join`` churn shrinks/grows the fleet and forces a replan
  on the surviving topology.  Non-adaptive baseline strategies keep
  their static plan: it is repriced under the merged conditions with
  fluid-fair contention, and churn that removes a device the plan
  placed layers on makes every subsequent request fail until the
  device rejoins.
* **Energy** — idle draw is billed once per device over its *presence
  interval* (a device that leaves at ``t`` stops drawing idle power at
  ``t``; see ``ServingTrace.per_device_idle_s``), and each request adds
  only the active plan's non-idle per-device energy (compute + DVFS +
  network bytes — the plan's energy minus the idle draw its window
  already prices).  Overlapping pipeline windows therefore never bill
  the same idle second twice.

The public API is unchanged: :func:`simulate_requests` (also reachable
as ``dora.simulate(scenario, mode="requests")``) returns a
:class:`ServingTrace` with p50/p95/p99 latency, SLO attainment %,
per-device energy and every adapter action.  Moved internals
(``poisson_arrivals``, ``normalize_timeline``, ``_ActivePlan``, …) stay
importable from here behind a :class:`DeprecationWarning` shim.
"""
from __future__ import annotations

import math
import time
import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..control.battery import SOC_CHECK_LABEL, BatteryTracker
from ..control.plane import ControlConfig, StaticPlane
from ..core.adapter import DynamicsEvent
from ..core.scheduler import NetworkScheduler
from ..core import events as kernel
from ..core.events import (DEFAULT_N_REQUESTS, AdapterAction, RequestLog,
                           RequestRecord, ServingLoad, ServingTrace)


def default_load(scenario, plan_latency: float) -> ServingLoad:
    """The scenario's registered request rate (plus any registered
    arrival process / request classes), or a half-capacity fallback for
    ad-hoc scenarios that don't declare one."""
    rate = getattr(scenario, "request_rate", None)
    if rate is None:
        rate = 0.5 / max(plan_latency, 1e-9)
    return ServingLoad(
        rate=rate,
        arrival=getattr(scenario, "arrival", None),
        classes=tuple(getattr(scenario, "request_classes", ()) or ()))


def simulate_requests(scenario,
                      *,
                      strategy: str = "dora",
                      load: Optional[ServingLoad] = None,
                      events=None,
                      session=None,
                      report=None,
                      arrivals: Optional[Sequence[float]] = None,
                      chunk: Optional[int] = None,
                      faults=None,
                      resilience=None,
                      recovery: str = "ladder",
                      control: Optional[ControlConfig] = None,
                      **overrides) -> ServingTrace:
    """Run one request-level serving simulation.

    ``strategy="dora"`` arms (or reuses, via ``session=``) a
    :class:`~repro.dora.ServeSession` and lets the runtime adapter react
    to every timeline event; any other registered strategy plans once
    (or reuses an existing ``report=`` from ``dora.plan`` of the same
    scenario and strategy) and stays static — its plan is repriced
    under the merged conditions (fluid-fair contention) and breaks
    outright when churn removes a device it placed layers on.
    ``events`` defaults to the scenario's registered timeline;
    ``arrivals`` (explicit trace, seconds) overrides the load's arrival
    process.  ``chunk`` bounds the kernel's vectorization width (a
    validation knob — results are invariant to it).  Keyword
    ``overrides`` flow to ``dora.serve``/``dora.plan``.

    **Chaos.** ``faults=`` injects *unannounced* failures — a
    :class:`~repro.resilience.FaultScript`, or any event sequence
    carrying ``crash``/``link_down``/``link_up``/``straggler`` fields
    (the ``faulty_sites`` scenario family registers such timelines).
    Whenever fault content is present (or ``resilience=`` is passed),
    the run is delegated to the chaos engine
    (:mod:`repro.resilience.engine`): failures take effect silently at
    onset and are only *acted on* one heartbeat detection window later
    (``miss_limit * beat_interval``, pumped through a real
    ``runtime.heartbeat.Coordinator``); blind-window requests fail or
    time out and are retried per the :class:`RetryPolicy`;
    ``recovery=`` picks the dora reaction — ``"ladder"`` (precomputed
    fallback plan, background warm replan) or ``"replan"`` (naive
    replan-on-detect).  With no fault content this function is
    bit-identical to the plain Lindley kernel path.

    **Control plane.** ``control=`` (a
    :class:`~repro.control.plane.ControlConfig`, defaulting to the
    session's own) arms the within-plan mechanisms: ``preemption``
    lets ``priority > 0`` request classes jump queued batch admissions
    at the bottleneck stage; ``battery`` integrates per-device SoC
    (``DeviceProfile.battery_j``) against the kernel's energy
    attribution at ``soc_check_interval_s`` checkpoints, kills emptied
    devices mid-run, and — with ``battery_aware`` — evacuates them
    *before* the projected death.  With every mechanism off this is
    bit-identical to the historical path.  Chaos runs ignore the
    sim-side mechanisms (streamed migration, which lives in the
    adapter, still applies).
    """
    from .. import dora  # local import: dora lazily imports this module

    sc = dora.get_scenario(scenario)
    if session is not None and strategy != "dora":
        raise ValueError("session= implies the adaptive dora strategy; "
                         f"got strategy={strategy!r}")
    scheduler: Optional[NetworkScheduler] = None
    if strategy == "dora":
        if report is not None:
            raise ValueError("the dora strategy reuses a session=, "
                             "not a report=")
        if session is None:
            session = dora.serve(sc, **overrides)
        else:
            have = session.report.scenario.name
            if have != sc.name:
                raise ValueError(f"session was served for scenario {have!r},"
                                 f" not {sc.name!r}")
            if overrides:
                raise ValueError("overrides are ignored when reusing a "
                                 "session; pass them to dora.serve instead")
        report = session.report
        topo = report.topology
        active = kernel.freeze_plan(session.current, session.active, topo)
    else:
        if report is None:
            report = dora.plan(sc, strategy=strategy, **overrides)
        elif report.scenario.name != sc.name or report.strategy != strategy:
            raise ValueError(
                f"report= was planned for ({report.scenario.name!r}, "
                f"{report.strategy!r}), not ({sc.name!r}, {strategy!r})")
        topo = report.topology
        scheduler = NetworkScheduler(topo, report.qoe)
        active = kernel.freeze_plan(report.best, range(topo.n), topo)
    qoe = report.qoe

    if load is None:
        load = default_load(sc, active.latency)
    slo = load.slo_s if load.slo_s is not None else qoe.t_qoe

    if arrivals is not None:
        arr = np.asarray(sorted(float(a) for a in arrivals))
        if len(arr) and arr[0] < 0.0:
            raise ValueError("arrival times must be non-negative")
    else:
        arr = load.sample_arrivals()

    timeline = kernel.normalize_timeline(
        events if events is not None else sc.timeline)

    if faults is not None and hasattr(faults, "events"):
        faults = faults.events()
    if faults:
        timeline = sorted(timeline + kernel.normalize_timeline(faults),
                          key=lambda item: item[1].t)
    if resilience is not None or any(ev.is_fault for _, ev in timeline):
        from ..resilience import ResilienceConfig
        from ..resilience.engine import run_chaos
        return run_chaos(sc=sc, strategy=strategy, session=session,
                         report=report, scheduler=scheduler, load=load,
                         slo=slo, arr=arr, timeline=timeline,
                         config=resilience or ResilienceConfig(),
                         recovery=recovery)

    # static-strategy runtime view (the dora path keeps its own inside
    # the ServeSession's ControlPlane)
    static = StaticPlane(topo.n, active.devices)

    if control is None and session is not None:
        control = session.control

    class_id = load.sample_class_ids(len(arr))
    preempt = None
    if control is not None and control.preemption:
        preempt = kernel.preemption_spec(load.classes, class_id,
                                         control.preempt_overhead_s)

    battery: Optional[BatteryTracker] = None
    present = set(range(topo.n))
    if control is not None and control.battery:
        if strategy != "dora":
            raise ValueError("battery tracking needs the adaptive dora "
                             "strategy (the control plane reacts to SoC)")
        battery = BatteryTracker(topo.devices)
        if not battery.capacity:
            battery = None          # no battery-backed device to track
    if battery is not None:
        # inject SoC checkpoints; fire() intercepts them by label
        # *before* they could reach the session's reaction path (an
        # empty event would otherwise trigger a refine)
        t_hi = max([float(arr[-1]) if len(arr) else 0.0,
                    *(ev.t for _, ev in timeline)])
        step = control.soc_check_interval_s
        checks = [(SOC_CHECK_LABEL, DynamicsEvent(t=k * step))
                  for k in range(1, int(t_hi / step) + 1)]
        timeline = sorted(timeline + checks, key=lambda kv: kv[1].t)

    stream = kernel.Stream(arr, plan=active, chunk=chunk, preempt=preempt)
    presence = kernel.PresenceTracker(topo.n)
    actions: List[AdapterAction] = []

    def fire(label: str, ev: DynamicsEvent) -> None:
        if battery is not None and label == SOC_CHECK_LABEL:
            newly = battery.advance(ev.t, stream.service_energy, present)
            for lbl, bev, act, react, stall in session.plane.on_soc(
                    ev.t, battery, newly_dead=newly, config=control):
                presence.apply(bev)
                present.difference_update(bev.leave)
                present.update(bev.join)
                stream.stall(bev.t, stall)
                if act == "degraded" or session.degraded:
                    stream.alive = False
                    lat = math.inf
                else:
                    stream.alive = True
                    stream.plan = kernel.freeze_plan(
                        session.current, session.plan_fleet, topo)
                    lat = stream.plan.latency
                actions.append(AdapterAction(
                    t=ev.t, label=lbl, action=act, react_s=react,
                    stall_s=stall, latency_after=lat))
            return
        presence.apply(ev)
        present.difference_update(ev.leave)
        present.update(ev.join)
        if strategy == "dora":
            new, act, react = session.on_dynamics(ev)
            stall = (float(new.meta.get("switch_stall_s", 0.0))
                     if act == "replan" else 0.0)
            stream.stall(ev.t, stall)
            if act == "degraded":
                # no servable plan on the survivors: requests fail
                # until a rejoin replans successfully
                stream.alive = False
                lat = math.inf
            else:
                stream.alive = True
                stream.plan = kernel.freeze_plan(new, session.plan_fleet,
                                                 topo)
                lat = stream.plan.latency
            actions.append(AdapterAction(t=ev.t, label=label, action=act,
                                         react_s=react, stall_s=stall,
                                         latency_after=lat))
            return
        # static baseline: merge conditions, apply churn, reprice
        t0 = time.perf_counter()
        stream.alive = static.apply(ev)
        if not stream.alive:
            act, lat = "degraded", math.inf
        else:
            repriced = scheduler.evaluate_fair(
                report.best,
                compute_speed=dict(static.state.compute_speed),
                bandwidth_scale=dict(static.state.bandwidth_scale))
            stream.plan = kernel.freeze_plan(repriced, range(topo.n), topo)
            act, lat = "repriced", stream.plan.latency
        actions.append(AdapterAction(t=ev.t, label=label, action=act,
                                     react_s=time.perf_counter() - t0,
                                     stall_s=0.0, latency_after=lat))

    kernel.replay(timeline, [stream], fire)

    arr_out, starts, finishes = stream.arrays()
    horizon = max([0.0, float(arr[-1]) if len(arr) else 0.0,
                   stream.last_finite_finish(),
                   *(ev.t for _, ev in timeline)])
    idle_s = presence.seconds(horizon)
    per_device_energy: Dict[int, float] = {}
    for d, dev in enumerate(topo.devices):
        per_device_energy[d] = stream.service_energy.get(d, 0.0) \
            + dev.p_idle * idle_s.get(d, 0.0)

    log = RequestLog(arr_out, starts, finishes,
                     class_id=(class_id[:len(arr_out)]
                               if class_id is not None else None),
                     classes=load.classes)
    return ServingTrace(scenario=sc.name, strategy=strategy, load=load,
                        slo_s=slo, requests=log, actions=actions,
                        per_device_energy=per_device_energy,
                        per_device_busy=dict(stream.busy),
                        horizon_s=float(horizon),
                        per_device_idle_s=idle_s)


#: moved internals kept importable with a DeprecationWarning (the
#: public serving API above is unchanged)
_MOVED = {
    "poisson_arrivals": "poisson_arrivals",
    "normalize_timeline": "normalize_timeline",
    "_ActivePlan": "ActivePlan",
    "_freeze": "freeze_plan",
    "_service_interval": "service_interval",
}


def __getattr__(name: str):
    target = _MOVED.get(name)
    if target is not None:
        warnings.warn(
            f"repro.sim.serving.{name} moved to "
            f"repro.core.events.{target}; import it from there",
            DeprecationWarning, stacklevel=2)
        return getattr(kernel, target)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "ServingLoad", "RequestRecord", "RequestLog", "AdapterAction",
    "ServingTrace", "default_load", "simulate_requests",
    "DEFAULT_N_REQUESTS",
]
