"""Alias for the shared serving kernel.

The kernel lives in :mod:`repro.core.events` so the scenario registry
and the plan-level engine can use it without importing the simulator
package; ``repro.sim.kernel`` re-exports it under the name the
simulators advertise.
"""
from ..core.events import *  # noqa: F401,F403
from ..core.events import __all__  # noqa: F401
