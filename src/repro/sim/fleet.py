"""Multi-tenant request-level serving: N concurrent streams, one fleet.

Extends :mod:`repro.sim.serving` to a co-planned fleet: every tenant
gets its own open-loop arrival stream (at its registered
``request_rate``, through its registered arrival process / request
classes) served by its own pipeline on its *exclusive* device
allotment, while the fleet timeline (bandwidth/compute shifts and
device churn) plays out through the :class:`~repro.fleet.FleetSession`
— rebalances move devices between tenants mid-run and bill each moved
tenant's migration stall against its own admissions.

All bookkeeping delegates to the shared serving kernel
(:mod:`repro.core.events`): one :class:`~repro.core.events.Stream` per
tenant replays the fleet timeline, vectorizing each inter-event
segment with the same Lindley recurrence as the single-tenant path.
Fleet-level attribution:

* **Idle draw** is billed once per fleet device over its *presence
  interval* and prorated across the tenants that owned the device, by
  ownership interval (:class:`~repro.core.events.OwnershipTracker`) —
  a device that changed hands mid-run bills each owner for its own
  span; spans owned by no tenant land in the fleet-wide totals only.
* **Oversubscription** is checked, not clamped: summing every tenant's
  compute-busy seconds per device must stay within the horizon, since
  allotments are exclusive — :meth:`FleetTrace.oversubscribed_devices`
  must come back empty, and the fleet tests assert it.

Entry points: :func:`simulate_fleet`, also reachable as
``dora.simulate(fleet, mode="fleet")``.
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

from ..core.adapter import DynamicsEvent
from ..core import events as kernel
from ..core.events import (DEFAULT_N_REQUESTS, AdapterAction, RequestLog,
                           ServingLoad, ServingTrace, _json_num)

#: Seed stride between tenants so their arrival processes are
#: independent but each stays deterministic per (fleet seed, tenant).
_TENANT_SEED_STRIDE = 9973


@dataclasses.dataclass(frozen=True)
class FleetAction:
    """One tenant-visible runtime reaction during a fleet run."""

    t: float
    label: str
    tenant: str
    action: str             # "reschedule" | "replan" | "rebalance"
    react_s: float
    stall_s: float
    latency_after: float
    allotment: Tuple[int, ...]


@dataclasses.dataclass
class FleetTrace:
    """Everything one multi-tenant serving simulation produced."""

    fleet: str
    tenants: "OrderedDict[str, ServingTrace]"
    actions: List[FleetAction]
    assignments: Dict[str, Tuple[int, ...]]   # final allotments
    per_device_energy: Dict[int, float]       # fleet-wide, idle billed once
    per_device_busy: Dict[int, float]         # summed across tenants
    horizon_s: float
    rebalances: int
    #: (t, {tenant: allotment}) snapshots — the ownership history the
    #: idle-draw proration was computed from
    ownership: List[Tuple[float, Dict[str, Tuple[int, ...]]]] = \
        dataclasses.field(default_factory=list)
    #: chaos-engine fault records (kind, target, onset/detect/restore
    #: times, mttr_s) — empty for fault-free runs
    faults: List[Dict[str, object]] = dataclasses.field(default_factory=list)
    #: mean time-to-recovery over service-affecting faults, ``None``
    #: when no fault touched any tenant
    mttr_s: Optional[float] = None

    @property
    def energy(self) -> float:
        return sum(self.per_device_energy.values())

    @property
    def slo_attainment(self) -> float:
        """Worst tenant's SLO attainment (the fleet is only as good as
        its unhappiest tenant)."""
        return min((t.slo_attainment for t in self.tenants.values()),
                   default=1.0)

    @property
    def n_failed(self) -> int:
        return sum(t.n_failed for t in self.tenants.values())

    @property
    def n_retried(self) -> int:
        return sum(t.n_retried for t in self.tenants.values())

    @property
    def failed_rate(self) -> float:
        n = sum(len(t.requests) for t in self.tenants.values())
        return self.n_failed / n if n else 0.0

    def utilization(self, device: int) -> float:
        if self.horizon_s <= 0.0:
            return 0.0
        return self.per_device_busy.get(device, 0.0) / self.horizon_s

    @property
    def oversubscribed_devices(self) -> List[int]:
        """Devices booked for more compute-seconds than the run holds —
        always empty under exclusive allotments (asserted by tests)."""
        return sorted(d for d in self.per_device_busy
                      if self.utilization(d) > 1.0 + 1e-6)

    def to_dict(self) -> Dict[str, object]:
        return {
            "fleet": self.fleet,
            "horizon_s": _json_num(self.horizon_s),
            "energy_j": _json_num(self.energy),
            "slo_attainment_worst": self.slo_attainment,
            "failed_requests": self.n_failed,
            "rebalances": self.rebalances,
            "assignments": {k: list(v)
                            for k, v in self.assignments.items()},
            "ownership": [{"t": _json_num(t),
                           "assignments": {k: list(v)
                                           for k, v in snap.items()}}
                          for t, snap in self.ownership],
            "per_device_energy_j": {str(d): _json_num(e) for d, e in
                                    sorted(self.per_device_energy.items())},
            "per_device_utilization": {str(d): self.utilization(d) for d in
                                       sorted(self.per_device_energy)},
            "oversubscribed_devices": self.oversubscribed_devices,
            **({"retried_requests": self.n_retried,
                "mttr_s": _json_num(self.mttr_s),
                "faults": [{k: _json_num(v) if isinstance(v, float) else v
                            for k, v in rec.items()}
                           for rec in self.faults]}
               if self.faults or self.mttr_s is not None else {}),
            "tenants": {name: t.to_dict()
                        for name, t in self.tenants.items()},
            "actions": [{
                "t": a.t, "label": a.label, "tenant": a.tenant,
                "action": a.action, "react_s": _json_num(a.react_s),
                "stall_s": _json_num(a.stall_s),
                "latency_after_s": _json_num(a.latency_after),
                "allotment": list(a.allotment),
            } for a in self.actions],
        }

    def summary(self) -> str:
        lines = [f"fleet {self.fleet}: {len(self.tenants)} tenants over "
                 f"{self.horizon_s:.1f}s, total energy {self.energy:.1f} J"
                 f", {self.rebalances} rebalances"]
        for name, t in self.tenants.items():
            def fmt(x: float) -> str:
                return (f"{x * 1e3:.0f} ms" if math.isfinite(x)
                        else "unserved")
            lines.append(
                f"  {name:24s} devs={list(self.assignments[name])!s:12s} "
                f"{len(t.requests)} reqs @ {t.load.rate:g}/s  "
                f"p50/p99 {fmt(t.p50)}/{fmt(t.p99)}  "
                f"SLO {t.slo_attainment:.1%}")
        for a in self.actions:
            stall = f" stall {a.stall_s:.2f}s" if a.stall_s > 0 else ""
            lines.append(f"  t={a.t:6.1f}s  [{a.tenant}] {a.label:40s} -> "
                         f"{a.action}{stall}")
        return "\n".join(lines)


def _default_span(timeline) -> float:
    last = max((ev.t for _, ev in timeline), default=0.0)
    return max(60.0, last * 1.25)


def simulate_fleet(fleet, *,
                   loads: Optional[Dict[str, ServingLoad]] = None,
                   events=None,
                   session=None,
                   span_s: Optional[float] = None,
                   seed: int = 0,
                   chunk: Optional[int] = None,
                   faults=None,
                   resilience=None,
                   recovery: str = "ladder",
                   control=None,
                   **overrides) -> FleetTrace:
    """Run one multi-tenant request-level serving simulation.

    ``fleet`` — a registered fleet-scenario name, a
    :class:`~repro.fleet.FleetScenario`, or a list of tenant scenario
    refs.  ``loads`` overrides per-tenant :class:`ServingLoad`\\ s; by
    default each tenant arrives at its registered ``request_rate`` for
    ``span_s`` seconds (default: 60 s or 1.25x the last timeline
    event).  ``events`` overrides the fleet timeline.  Pass an armed
    ``session=`` (from ``dora.serve_fleet``) to reuse its plans;
    ``chunk`` bounds the kernel's vectorization width (a validation
    knob — results are invariant to it); keyword ``overrides``
    otherwise flow to ``dora.serve_fleet``.

    ``faults=`` / ``resilience=`` / ``recovery=`` mirror
    :func:`repro.sim.serving.simulate_requests`: any fault content
    (a :class:`~repro.resilience.FaultScript` or fault-carrying
    timeline events) delegates the run to the multi-tenant chaos
    engine with detection-latency-aware recovery.

    ``control=`` (a :class:`~repro.control.plane.ControlConfig`) arms
    kernel-side priority preemption per tenant — ``priority > 0``
    request classes jump queued batch admissions on their tenant's
    pipeline.  Battery SoC is single-tenant only (use
    :func:`simulate_requests`).
    """
    from .. import dora            # local import: dora lazily imports sims
    from ..fleet import resolve_fleet

    topology = overrides.pop("topology", None)
    fs = resolve_fleet(fleet, topology=topology)
    if session is None:
        session = dora.serve_fleet(fs, **overrides)
    else:
        have = session.scenario.name if session.scenario is not None \
            else session.plan.name
        if have != fs.name:
            raise ValueError(f"session was armed for fleet {have!r}, "
                             f"not {fs.name!r}")
        if overrides or topology is not None:
            raise ValueError("overrides are ignored when reusing a "
                             "session; pass them to dora.serve_fleet")
    topo = session.planner.topo
    timeline = kernel.normalize_timeline(
        events if events is not None else fs.timeline)
    span = span_s if span_s is not None else _default_span(timeline)

    names = [t.name for t in fs.tenants]
    tenant_loads: Dict[str, ServingLoad] = {}
    for i, tn in enumerate(fs.tenants):
        load = (loads or {}).get(tn.name)
        if load is None:
            active0 = session.sessions[tn.name].current
            rate = tn.request_rate or 0.5 / max(active0.latency, 1e-9)
            n = max(8, min(int(math.ceil(rate * span)),
                           2 * DEFAULT_N_REQUESTS))
            load = ServingLoad(
                rate=rate, n_requests=n,
                seed=seed + i * _TENANT_SEED_STRIDE,
                arrival=getattr(tn, "arrival", None),
                classes=tuple(getattr(tn, "request_classes", ()) or ()))
        tenant_loads[tn.name] = load

    if faults is not None and hasattr(faults, "events"):
        faults = faults.events()
    if faults:
        timeline = sorted(timeline + kernel.normalize_timeline(faults),
                          key=lambda item: item[1].t)
    if resilience is not None or any(ev.is_fault for _, ev in timeline):
        from ..resilience import ResilienceConfig
        from ..resilience.engine import run_chaos_fleet
        return run_chaos_fleet(fs=fs, session=session, loads=tenant_loads,
                               timeline=timeline,
                               config=resilience or ResilienceConfig(),
                               recovery=recovery)

    def freeze(name: str) -> kernel.ActivePlan:
        tp = session.plan.tenants[name]
        return kernel.freeze_plan(session.sessions[name].current,
                                  tp.allotment, topo)

    streams: Dict[str, kernel.Stream] = {}
    for n in names:
        t_load = tenant_loads[n]
        t_arr = t_load.sample_arrivals()
        preempt = None
        if control is not None and control.preemption:
            preempt = kernel.preemption_spec(
                t_load.classes, t_load.sample_class_ids(len(t_arr)),
                control.preempt_overhead_s)
        streams[n] = kernel.Stream(t_arr, plan=freeze(n), chunk=chunk,
                                   preempt=preempt)
    actions: List[FleetAction] = []
    presence = kernel.PresenceTracker(topo.n)
    ownership = kernel.OwnershipTracker(session.plan.assignments)

    def fire(label: str, ev: DynamicsEvent) -> None:
        presence.apply(ev)
        reacted = session.on_dynamics(ev)
        for act in reacted:
            if act.tenant not in streams:    # whole-fleet marker row
                actions.append(FleetAction(
                    t=ev.t, label=label, tenant=act.tenant,
                    action=act.action, react_s=act.react_s,
                    stall_s=act.stall_s, latency_after=act.latency_after,
                    allotment=act.allotment))
                continue
            streams[act.tenant].stall(ev.t, act.stall_s)
            actions.append(FleetAction(
                t=ev.t, label=label, tenant=act.tenant, action=act.action,
                react_s=act.react_s, stall_s=act.stall_s,
                latency_after=act.latency_after, allotment=act.allotment))
        if reacted:
            for n in names:                  # allotments may have moved
                streams[n].plan = freeze(n)
            ownership.update(ev.t, session.plan.assignments)

    kernel.replay(timeline, [streams[n] for n in names], fire)

    horizon = max([0.0,
                   *(float(s.arrivals[-1]) for s in streams.values()
                     if len(s.arrivals)),
                   *(s.last_finite_finish() for s in streams.values()),
                   *(ev.t for _, ev in timeline)])

    # -- energy attribution: idle draw once per device over its presence
    # interval, prorated across owning tenants by ownership interval;
    # service energy to the tenant that admitted the request
    presence_iv = presence.intervals(horizon)
    fleet_idle = presence.seconds(horizon)
    fleet_energy: Dict[int, float] = {
        d: dev.p_idle * fleet_idle.get(d, 0.0)
        for d, dev in enumerate(topo.devices)}
    tenant_idle: Dict[str, Dict[int, float]] = {n: {} for n in names}
    for d, spans in ownership.spans(horizon).items():
        for lo, hi, owner in spans:
            if owner not in tenant_idle:
                continue
            secs = kernel.overlap_seconds(presence_iv.get(d, ()), lo, hi)
            if secs > 0.0:
                tenant_idle[owner][d] = \
                    tenant_idle[owner].get(d, 0.0) + secs

    final = session.plan.assignments
    traces: "OrderedDict[str, ServingTrace]" = OrderedDict()
    fleet_busy: Dict[int, float] = {}
    for tn in fs.tenants:
        name = tn.name
        load = tenant_loads[name]
        stream = streams[name]
        for d, e in stream.service_energy.items():
            fleet_energy[d] = fleet_energy.get(d, 0.0) + e
        for d, b in stream.busy.items():
            fleet_busy[d] = fleet_busy.get(d, 0.0) + b
        tenant_energy = dict(stream.service_energy)
        idle_s = tenant_idle[name]
        for d, secs in idle_s.items():
            tenant_energy[d] = tenant_energy.get(d, 0.0) \
                + topo.devices[d].p_idle * secs
        slo = load.slo_s if load.slo_s is not None else tn.qoe.t_qoe
        arr, starts, finishes = stream.arrays()
        log = RequestLog(arr, starts, finishes,
                         class_id=load.sample_class_ids(len(arr)),
                         classes=load.classes)
        traces[name] = ServingTrace(
            scenario=f"{fs.name}/{name}", strategy="fleet", load=load,
            slo_s=slo, requests=log,
            actions=[AdapterAction(t=a.t, label=a.label, action=a.action,
                                   react_s=a.react_s, stall_s=a.stall_s,
                                   latency_after=a.latency_after)
                     for a in actions if a.tenant == name],
            per_device_energy=tenant_energy,
            per_device_busy=dict(stream.busy),
            horizon_s=float(horizon),
            per_device_idle_s=idle_s)

    return FleetTrace(fleet=fs.name, tenants=traces, actions=actions,
                      assignments={k: tuple(v) for k, v in final.items()},
                      per_device_energy=fleet_energy,
                      per_device_busy=fleet_busy,
                      horizon_s=float(horizon),
                      rebalances=session.rebalances,
                      ownership=ownership.history)


__all__ = ["FleetAction", "FleetTrace", "simulate_fleet"]
