"""Multi-tenant request-level serving: N concurrent streams, one fleet.

Extends :mod:`repro.sim.serving` to a co-planned fleet: every tenant
gets its own open-loop Poisson arrival stream (at its registered
``request_rate``) served by its own pipeline on its *exclusive* device
allotment, while the fleet timeline (bandwidth/compute shifts and
device churn) plays out through the :class:`~repro.fleet.FleetSession`
— rebalances move devices between tenants mid-run and bill each moved
tenant's migration stall against its own admissions.

Bookkeeping follows the single-tenant fluid model per tenant:
admissions at the plan's bottleneck interval, per-request non-idle
energy on the tenant's devices.  Fleet-level attribution:

* **Idle draw** is billed once per fleet device over the whole horizon
  and attributed to the tenant owning the device at the end of the run
  (devices that changed hands mid-run stay whole — conservative and
  simple); devices owned by no tenant land in the fleet-wide totals
  only.
* **Oversubscription** is checked, not clamped: summing every tenant's
  compute-busy seconds per device must stay within the horizon, since
  allotments are exclusive — :meth:`FleetTrace.oversubscribed_devices`
  must come back empty, and the fleet tests assert it.

Entry points: :func:`simulate_fleet`, also reachable as
``dora.simulate(fleet, mode="fleet")``.
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

from ..core.adapter import DynamicsEvent
from ..dora import _json_num
from .serving import (DEFAULT_N_REQUESTS, AdapterAction, RequestRecord,
                      ServingLoad, ServingTrace, _ActivePlan, _freeze,
                      normalize_timeline, poisson_arrivals)

#: Seed stride between tenants so their arrival processes are
#: independent but each stays deterministic per (fleet seed, tenant).
_TENANT_SEED_STRIDE = 9973


@dataclasses.dataclass(frozen=True)
class FleetAction:
    """One tenant-visible runtime reaction during a fleet run."""

    t: float
    label: str
    tenant: str
    action: str             # "reschedule" | "replan" | "rebalance"
    react_s: float
    stall_s: float
    latency_after: float
    allotment: Tuple[int, ...]


@dataclasses.dataclass
class FleetTrace:
    """Everything one multi-tenant serving simulation produced."""

    fleet: str
    tenants: "OrderedDict[str, ServingTrace]"
    actions: List[FleetAction]
    assignments: Dict[str, Tuple[int, ...]]   # final allotments
    per_device_energy: Dict[int, float]       # fleet-wide, idle billed once
    per_device_busy: Dict[int, float]         # summed across tenants
    horizon_s: float
    rebalances: int

    @property
    def energy(self) -> float:
        return sum(self.per_device_energy.values())

    @property
    def slo_attainment(self) -> float:
        """Worst tenant's SLO attainment (the fleet is only as good as
        its unhappiest tenant)."""
        return min((t.slo_attainment for t in self.tenants.values()),
                   default=1.0)

    @property
    def n_failed(self) -> int:
        return sum(t.n_failed for t in self.tenants.values())

    def utilization(self, device: int) -> float:
        if self.horizon_s <= 0.0:
            return 0.0
        return self.per_device_busy.get(device, 0.0) / self.horizon_s

    @property
    def oversubscribed_devices(self) -> List[int]:
        """Devices booked for more compute-seconds than the run holds —
        always empty under exclusive allotments (asserted by tests)."""
        return sorted(d for d in self.per_device_busy
                      if self.utilization(d) > 1.0 + 1e-6)

    def to_dict(self) -> Dict[str, object]:
        return {
            "fleet": self.fleet,
            "horizon_s": _json_num(self.horizon_s),
            "energy_j": _json_num(self.energy),
            "slo_attainment_worst": self.slo_attainment,
            "failed_requests": self.n_failed,
            "rebalances": self.rebalances,
            "assignments": {k: list(v)
                            for k, v in self.assignments.items()},
            "per_device_energy_j": {str(d): _json_num(e) for d, e in
                                    sorted(self.per_device_energy.items())},
            "per_device_utilization": {str(d): self.utilization(d) for d in
                                       sorted(self.per_device_energy)},
            "oversubscribed_devices": self.oversubscribed_devices,
            "tenants": {name: t.to_dict()
                        for name, t in self.tenants.items()},
            "actions": [{
                "t": a.t, "label": a.label, "tenant": a.tenant,
                "action": a.action, "react_s": _json_num(a.react_s),
                "stall_s": _json_num(a.stall_s),
                "latency_after_s": _json_num(a.latency_after),
                "allotment": list(a.allotment),
            } for a in self.actions],
        }

    def summary(self) -> str:
        lines = [f"fleet {self.fleet}: {len(self.tenants)} tenants over "
                 f"{self.horizon_s:.1f}s, total energy {self.energy:.1f} J"
                 f", {self.rebalances} rebalances"]
        for name, t in self.tenants.items():
            def fmt(x: float) -> str:
                return (f"{x * 1e3:.0f} ms" if math.isfinite(x)
                        else "unserved")
            lines.append(
                f"  {name:24s} devs={list(self.assignments[name])!s:12s} "
                f"{len(t.requests)} reqs @ {t.load.rate:g}/s  "
                f"p50/p99 {fmt(t.p50)}/{fmt(t.p99)}  "
                f"SLO {t.slo_attainment:.1%}")
        for a in self.actions:
            stall = f" stall {a.stall_s:.2f}s" if a.stall_s > 0 else ""
            lines.append(f"  t={a.t:6.1f}s  [{a.tenant}] {a.label:40s} -> "
                         f"{a.action}{stall}")
        return "\n".join(lines)


def _default_span(timeline) -> float:
    last = max((ev.t for _, ev in timeline), default=0.0)
    return max(60.0, last * 1.25)


def simulate_fleet(fleet, *,
                   loads: Optional[Dict[str, ServingLoad]] = None,
                   events=None,
                   session=None,
                   span_s: Optional[float] = None,
                   seed: int = 0,
                   **overrides) -> FleetTrace:
    """Run one multi-tenant request-level serving simulation.

    ``fleet`` — a registered fleet-scenario name, a
    :class:`~repro.fleet.FleetScenario`, or a list of tenant scenario
    refs.  ``loads`` overrides per-tenant :class:`ServingLoad`\\ s; by
    default each tenant arrives at its registered ``request_rate`` for
    ``span_s`` seconds (default: 60 s or 1.25x the last timeline
    event).  ``events`` overrides the fleet timeline.  Pass an armed
    ``session=`` (from ``dora.serve_fleet``) to reuse its plans;
    keyword ``overrides`` otherwise flow to ``dora.serve_fleet``.
    """
    from .. import dora            # local import: dora lazily imports sims
    from ..fleet import resolve_fleet

    topology = overrides.pop("topology", None)
    fs = resolve_fleet(fleet, topology=topology)
    if session is None:
        session = dora.serve_fleet(fs, **overrides)
    else:
        have = session.scenario.name if session.scenario is not None \
            else session.plan.name
        if have != fs.name:
            raise ValueError(f"session was armed for fleet {have!r}, "
                             f"not {fs.name!r}")
        if overrides or topology is not None:
            raise ValueError("overrides are ignored when reusing a "
                             "session; pass them to dora.serve_fleet")
    topo = session.planner.topo
    timeline = normalize_timeline(
        events if events is not None else fs.timeline)
    span = span_s if span_s is not None else _default_span(timeline)

    names = [t.name for t in fs.tenants]
    tenant_loads: Dict[str, ServingLoad] = {}
    arrivals: List[Tuple[float, str]] = []
    for i, tn in enumerate(fs.tenants):
        load = (loads or {}).get(tn.name)
        if load is None:
            active0 = session.sessions[tn.name].current
            rate = tn.request_rate or 0.5 / max(active0.latency, 1e-9)
            n = max(8, min(int(math.ceil(rate * span)),
                           2 * DEFAULT_N_REQUESTS))
            load = ServingLoad(rate=rate, n_requests=n,
                               seed=seed + i * _TENANT_SEED_STRIDE)
        tenant_loads[tn.name] = load
        for a in poisson_arrivals(load.rate, load.n_requests, load.seed):
            arrivals.append((float(a), tn.name))
    arrivals.sort()

    def freeze(name: str) -> _ActivePlan:
        tp = session.plan.tenants[name]
        return _freeze(session.sessions[name].current, tp.allotment)

    active: Dict[str, _ActivePlan] = {n: freeze(n) for n in names}
    next_free: Dict[str, float] = {n: 0.0 for n in names}
    records: Dict[str, List[RequestRecord]] = {n: [] for n in names}
    actions: List[FleetAction] = []
    service_energy: Dict[str, Dict[int, float]] = {n: {} for n in names}
    busy: Dict[str, Dict[int, float]] = {n: {} for n in names}

    def fire(label: str, ev: DynamicsEvent) -> None:
        reacted = session.on_dynamics(ev)
        for act in reacted:
            if act.tenant not in active:     # whole-fleet marker row
                actions.append(FleetAction(
                    t=ev.t, label=label, tenant=act.tenant,
                    action=act.action, react_s=act.react_s,
                    stall_s=act.stall_s, latency_after=act.latency_after,
                    allotment=act.allotment))
                continue
            if act.stall_s > 0.0:
                next_free[act.tenant] = (max(next_free[act.tenant], ev.t)
                                         + act.stall_s)
            actions.append(FleetAction(
                t=ev.t, label=label, tenant=act.tenant, action=act.action,
                react_s=act.react_s, stall_s=act.stall_s,
                latency_after=act.latency_after, allotment=act.allotment))
        if reacted:
            for n in names:                  # allotments may have moved
                active[n] = freeze(n)

    ev_i = 0
    for a, name in arrivals:
        while ev_i < len(timeline) and timeline[ev_i][1].t <= a:
            fire(*timeline[ev_i])
            ev_i += 1
        plan = active[name]
        start = max(a, next_free[name])
        finish = start + plan.latency
        next_free[name] = start + plan.interval
        records[name].append(RequestRecord(arrival=a, start=start,
                                           finish=finish))
        acc = service_energy[name]
        for d, e in plan.per_device_energy.items():
            non_idle = e - topo.devices[d].p_idle * plan.latency
            acc[d] = acc.get(d, 0.0) + max(non_idle, 0.0)
        for d, b in plan.compute_busy.items():
            busy[name][d] = busy[name].get(d, 0.0) + b
    while ev_i < len(timeline):
        fire(*timeline[ev_i])
        ev_i += 1

    horizon = max([0.0,
                   *(a for a, _ in arrivals),
                   *(r.finish for rs in records.values() for r in rs
                     if r.served),
                   *(ev.t for _, ev in timeline)])

    # -- energy attribution: idle once per device, service to its tenant
    final = session.plan.assignments
    fleet_energy: Dict[int, float] = {
        d: dev.p_idle * horizon for d, dev in enumerate(topo.devices)}
    traces: "OrderedDict[str, ServingTrace]" = OrderedDict()
    fleet_busy: Dict[int, float] = {}
    for tn in fs.tenants:
        name = tn.name
        load = tenant_loads[name]
        for d, e in service_energy[name].items():
            fleet_energy[d] = fleet_energy.get(d, 0.0) + e
        for d, b in busy[name].items():
            fleet_busy[d] = fleet_busy.get(d, 0.0) + b
        tenant_energy = dict(service_energy[name])
        for d in final.get(name, ()):
            tenant_energy[d] = tenant_energy.get(d, 0.0) \
                + topo.devices[d].p_idle * horizon
        slo = load.slo_s if load.slo_s is not None else tn.qoe.t_qoe
        traces[name] = ServingTrace(
            scenario=f"{fs.name}/{name}", strategy="fleet", load=load,
            slo_s=slo, requests=records[name],
            actions=[AdapterAction(t=a.t, label=a.label, action=a.action,
                                   react_s=a.react_s, stall_s=a.stall_s,
                                   latency_after=a.latency_after)
                     for a in actions if a.tenant == name],
            per_device_energy=tenant_energy,
            per_device_busy=dict(busy[name]),
            horizon_s=float(horizon))

    return FleetTrace(fleet=fs.name, tenants=traces, actions=actions,
                      assignments={k: tuple(v) for k, v in final.items()},
                      per_device_energy=fleet_energy,
                      per_device_busy=fleet_busy,
                      horizon_s=float(horizon),
                      rebalances=session.rebalances)


__all__ = ["FleetAction", "FleetTrace", "simulate_fleet"]
