"""Back-compat shim — the baseline planners moved to
:mod:`repro.strategies.baselines`, where they are registered in the
planner-strategy registry (``repro.strategies.get_strategy``).

The plain ``*_plan`` functions stay importable from here (and from
``repro.sim``) for existing callers; new code should resolve planners
through the registry instead.
"""
from __future__ import annotations

from ..strategies.baselines import (  # noqa: F401
    LATENCY_ONLY, BaselineError, alpa_plan, asteroid_plan,
    brute_force_optimal, edgeshard_plan, metis_plan, plan_memory_ok,
    reprice_stage)

__all__ = [
    "LATENCY_ONLY", "BaselineError", "alpa_plan", "asteroid_plan",
    "brute_force_optimal", "edgeshard_plan", "metis_plan",
    "plan_memory_ok", "reprice_stage",
]
