"""DEPRECATED back-compat shim — the baseline planners live in
:mod:`repro.strategies.baselines`, registered in the planner-strategy
registry (``repro.strategies.get_strategy``).

Importing names from this module works but raises a
``DeprecationWarning``; new code should either resolve planners through
the registry or import the ``*_plan`` functions from
``repro.strategies.baselines`` directly.  The re-exports on
``repro.sim`` itself (``from repro.sim import alpa_plan``) remain
warning-free for now.
"""
from __future__ import annotations

import warnings

__all__ = [
    "LATENCY_ONLY", "BaselineError", "alpa_plan", "asteroid_plan",
    "brute_force_optimal", "edgeshard_plan", "metis_plan",
    "plan_memory_ok", "reprice_stage",
]


def __getattr__(name: str):
    if name in __all__:
        warnings.warn(
            "repro.sim.baselines is deprecated; import "
            f"{name!r} from repro.strategies.baselines (or resolve the "
            "planner via repro.strategies.get_strategy)",
            DeprecationWarning, stacklevel=2)
        from ..strategies import baselines as _baselines
        return getattr(_baselines, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
