"""Edge-deployment simulator + baseline planners.

Validates the paper's claims without edge hardware: the four Table-3
settings (``core.device.make_setting``), the discrete-event engine
(``core.engine``), Asteroid-/EdgeShard-/Alpa-/Metis-like baselines, and
a brute-force optimal searcher for small device counts.
"""
from .baselines import (BaselineError, alpa_plan, asteroid_plan,
                        brute_force_optimal, edgeshard_plan, metis_plan)
from .runner import (ExecResult, compare_planners, dora_plan, execute_plan,
                     workload_for)

__all__ = [
    "BaselineError", "alpa_plan", "asteroid_plan", "brute_force_optimal",
    "edgeshard_plan", "metis_plan", "ExecResult", "compare_planners",
    "dora_plan", "execute_plan", "workload_for",
]
