"""Edge-deployment simulator + baseline planners.

Validates the paper's claims without edge hardware: the registered
deployment scenarios (``repro.scenarios`` — Table-3 settings and
beyond), the discrete-event engine (``core.engine``),
Asteroid-/EdgeShard-/Alpa-/Metis-like baselines, and a brute-force
optimal searcher for small device counts.
"""
from .baselines import (BaselineError, alpa_plan, asteroid_plan,
                        brute_force_optimal, edgeshard_plan, metis_plan)
from .runner import (ExecResult, compare_planners, dora_plan, execute_plan,
                     scenario_case, setting_and_graph, workload_for)

__all__ = [
    "BaselineError", "alpa_plan", "asteroid_plan", "brute_force_optimal",
    "edgeshard_plan", "metis_plan", "ExecResult", "compare_planners",
    "dora_plan", "execute_plan", "scenario_case", "setting_and_graph",
    "workload_for",
]
