"""Edge-deployment simulator.

Validates the paper's claims without edge hardware: the registered
deployment scenarios (``repro.scenarios`` — Table-3 settings and
beyond), the discrete-event engine (``core.engine``), and the
contended-execution runner.  The baseline planners moved to the
strategy registry (``repro.strategies``); their ``*_plan`` functions
stay re-exported here for back compatibility (the deeper
``repro.sim.baselines`` shim is deprecated and warns).
"""
from ..strategies.baselines import (BaselineError, alpa_plan, asteroid_plan,
                                    brute_force_optimal, edgeshard_plan,
                                    metis_plan)
from .runner import (COMPARISON_PLANNERS, ExecResult, compare_planners,
                     dora_plan, execute_plan, run_strategy, scenario_case,
                     setting_and_graph, workload_for)
from ..core.events import poisson_arrivals
from .fleet import FleetAction, FleetTrace, simulate_fleet
from .serving import (AdapterAction, RequestLog, RequestRecord, ServingLoad,
                      ServingTrace, simulate_requests)

__all__ = [
    "BaselineError", "alpa_plan", "asteroid_plan", "brute_force_optimal",
    "edgeshard_plan", "metis_plan", "COMPARISON_PLANNERS", "ExecResult",
    "compare_planners", "dora_plan", "execute_plan", "run_strategy",
    "scenario_case", "setting_and_graph", "workload_for",
    "AdapterAction", "RequestLog", "RequestRecord", "ServingLoad",
    "ServingTrace", "poisson_arrivals", "simulate_requests",
    "FleetAction", "FleetTrace", "simulate_fleet",
]
