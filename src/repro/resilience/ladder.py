"""Degraded-plan fallback ladder: precomputed recovery per loss scope.

Naive replan-on-detect pays the full planner latency *plus* a cold
weight load on the critical path — the crashed pipeline cannot serve
while the replacement is prepared.  The ladder instead precomputes, at
arm time (and again in the background after every adoption), one
QoE-ranked fallback plan per likely failure scope — each surviving
subset from a single-device loss — so detection switches instantly:
the fallback's weights are prestaged on the survivors, and the only
stall is the pipeline drain.

``FallbackLadder`` serves a single :class:`~repro.dora.ServeSession`;
``FleetLadder`` precomputes whole fleet assignments for a
:class:`~repro.fleet.session.FleetSession`.  A scope with no
QoE-feasible fallback is recorded as infeasible — the engine then
degrades gracefully (brownout: shed batch admissions, keep
interactive) instead of raising.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Tuple

from ..core.adapter import RuntimeState
from ..core.planner import DoraPlanner


@dataclasses.dataclass
class LadderEntry:
    """One precomputed fallback: the best plan on ``keep`` after losing
    ``lost``. ``result is None`` marks an infeasible scope (survivors
    disconnect, or nothing plannable); ``qoe_ok`` is the QoE verdict of
    the fallback (False → adopt it but report brownout pressure)."""

    lost: FrozenSet[int]
    keep: Tuple[int, ...]
    mapping: Dict[int, int] = dataclasses.field(default_factory=dict)
    planner: Optional[DoraPlanner] = None
    result: Optional[object] = None
    qoe_ok: bool = False

    @property
    def feasible(self) -> bool:
        return self.result is not None


class FallbackLadder:
    """Per-scope fallback plans for one ``ServeSession``."""

    def __init__(self, session):
        self.session = session
        self.entries: Dict[FrozenSet[int], LadderEntry] = {}
        self.build()

    def build(self) -> None:
        """(Re)compute one fallback per single-device loss from the
        session's current fleet — the warm background replan that runs
        after every adoption."""
        self.entries = {}
        session = self.session
        if session.degraded or len(session.active) <= 1:
            return
        for d in session.active:
            lost = frozenset({d})
            keep = tuple(x for x in session.active if x != d)
            self.entries[lost] = self._build_entry(lost, keep)

    def _build_entry(self, lost: FrozenSet[int],
                     keep: Tuple[int, ...]) -> LadderEntry:
        session = self.session
        report = session.report
        try:
            sub, mapping = report.topology.subset(keep)
            planner = DoraPlanner(
                report.graph, sub, report.qoe,
                partitioner_config=session.partitioner_config,
                scheduler_config=session.scheduler_config,
                adapter_config=session.adapter.config)
            trans = {pos: mapping[orig]
                     for pos, orig in enumerate(session.plan_fleet)
                     if orig in mapping}
            result = planner.replan(report.workload, session.plans,
                                    mapping=trans)
        except (ValueError, RuntimeError):
            # survivors disconnect the routed topology or admit no plan:
            # the scope is infeasible — detection will brown out instead
            return LadderEntry(lost=lost, keep=keep)
        return LadderEntry(lost=lost, keep=keep, mapping=mapping,
                           planner=planner, result=result,
                           qoe_ok=report.qoe.satisfied(result.best))

    def lookup(self, lost) -> Optional[LadderEntry]:
        return self.entries.get(frozenset(lost))

    def apply(self, lost) -> Optional[float]:
        """Switch the session to the precomputed fallback for ``lost``.

        Returns the stall (drain only — fallback weights are
        prestaged), or ``None`` when no feasible entry exists for this
        exact scope (caller falls back to naive replan / brownout).
        Mirrors ``ServeSession._on_churn``'s bookkeeping.
        """
        entry = self.lookup(lost)
        if entry is None or entry.result is None:
            return None
        session = self.session
        adapter = entry.planner.make_adapter(entry.result)
        new = entry.result.best
        merged = session.state
        cond = RuntimeState(
            compute_speed={entry.mapping[d]: v
                           for d, v in merged.compute_speed.items()
                           if d in entry.mapping},
            bandwidth_scale={k: v for k, v in merged.bandwidth_scale.items()
                             if k in entry.planner.topo.resources})
        if cond.compute_speed or cond.bandwidth_scale:
            new = adapter.scheduler.refine(
                new, compute_speed=dict(cond.compute_speed),
                bandwidth_scale=dict(cond.bandwidth_scale))
        stall = adapter.config.switch_drain_s
        new.meta["switch_stall_s"] = stall
        new.meta["fleet"] = list(entry.keep)
        new.meta["fallback"] = True
        session.adapter = adapter
        session.active = entry.keep
        session.plan_fleet = entry.keep
        session.degraded = False
        session.plans = list(entry.result.candidates)
        session.current = new
        return stall


class FleetLadder:
    """Per-scope fallback fleet assignments for one ``FleetSession``."""

    def __init__(self, session):
        self.session = session
        self.entries: Dict[FrozenSet[int], object] = {}
        self.build()

    def build(self) -> None:
        self.entries = {}
        session = self.session
        n_tenants = len(session.planner.tenants)
        for d in session.active:
            fleet = sorted(set(session.active) - {d})
            if len(fleet) < n_tenants:
                continue        # infeasible scope: not enough devices
            warm = {name: (list(sess.plans),
                           session.plan.tenants[name].allotment)
                    for name, sess in session.sessions.items()}
            merged = session.state
            conditions = merged if (merged.compute_speed
                                    or merged.bandwidth_scale) else None
            try:
                self.entries[frozenset({d})] = session.planner.plan(
                    devices=fleet, warm=warm, conditions=conditions)
            except (ValueError, RuntimeError):
                continue        # no feasible assignment without d

    def lookup(self, lost):
        return self.entries.get(frozenset(lost))

    def apply(self, lost) -> Optional[list]:
        """Adopt the precomputed fleet plan for ``lost``: mirrors
        ``FleetSession._rebalance`` adoption, but every moved tenant
        pays only the drain (fallback weights are prestaged).  Returns
        the tenant actions, or ``None`` when no entry covers the scope.
        """
        from ..fleet.session import TenantAction, _orig_placement

        new_plan = self.lookup(lost)
        if new_plan is None:
            return None
        session = self.session
        old_plan = session.plan
        shares_of = session.planner.link_shares
        old_shares = shares_of(list(old_plan.assignments.values()))
        new_shares = shares_of(list(new_plan.assignments.values()))
        actions: List[TenantAction] = []
        new_sessions = {}
        for name, tp in new_plan.tenants.items():
            old_tp = old_plan.tenants.get(name)
            if (old_tp is not None and old_tp.allotment == tp.allotment
                    and session.planner._factors_key(tp.allotment, old_shares)
                    == session.planner._factors_key(tp.allotment,
                                                    new_shares)):
                new_sessions[name] = session.sessions[name]
                continue
            sess = session._arm_tenant(
                tp, state=session._local_state(tp, session.state))
            stall = 0.0
            if old_tp is not None:
                old_current = session.sessions[name].current
                if (_orig_placement(old_current, old_tp)
                        != _orig_placement(sess.current, tp)):
                    # prestaged: drain only, no weight load
                    stall = sess.adapter.config.switch_drain_s
            sess.current.meta["switch_stall_s"] = stall
            sess.current.meta["fleet"] = list(tp.allotment)
            sess.current.meta["fallback"] = True
            new_sessions[name] = sess
            actions.append(TenantAction(
                tenant=name, action="fallback", react_s=0.0, stall_s=stall,
                latency_after=sess.current.latency, allotment=tp.allotment))
        session.plan = new_plan
        session.sessions = new_sessions
        session.active = tuple(sorted(
            set(session.active) - frozenset(lost)))
        session.rebalances += 1
        return actions
