"""Degraded-plan fallback ladder: precomputed recovery per loss scope.

Naive replan-on-detect pays the full planner latency *plus* a cold
weight load on the critical path — the crashed pipeline cannot serve
while the replacement is prepared.  The ladder instead precomputes, at
arm time (and again in the background after every adoption), one
QoE-ranked fallback plan per likely failure scope — each surviving
subset from a single-device loss — so detection switches instantly:
the fallback's weights are prestaged on the survivors, and the only
stall is the pipeline drain.

``FallbackLadder`` serves a single :class:`~repro.dora.ServeSession`;
``FleetLadder`` precomputes whole fleet assignments for a
:class:`~repro.fleet.session.FleetSession`.  A scope with no
QoE-feasible fallback is recorded as infeasible — the engine then
degrades gracefully (brownout: shed batch admissions, keep
interactive) instead of raising.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Optional, Tuple

from ..core.planner import DoraPlanner


@dataclasses.dataclass
class LadderEntry:
    """One precomputed fallback: the best plan on ``keep`` after losing
    ``lost``. ``result is None`` marks an infeasible scope (survivors
    disconnect, or nothing plannable); ``qoe_ok`` is the QoE verdict of
    the fallback (False → adopt it but report brownout pressure)."""

    lost: FrozenSet[int]
    keep: Tuple[int, ...]
    mapping: Dict[int, int] = dataclasses.field(default_factory=dict)
    planner: Optional[DoraPlanner] = None
    result: Optional[object] = None
    qoe_ok: bool = False

    @property
    def feasible(self) -> bool:
        return self.result is not None


class FallbackLadder:
    """Per-scope fallback plans for one ``ServeSession``."""

    def __init__(self, session):
        self.session = session
        self.entries: Dict[FrozenSet[int], LadderEntry] = {}
        self.build()

    def build(self) -> None:
        """(Re)compute one fallback per single-device loss from the
        session's current fleet — the warm background replan that runs
        after every adoption."""
        self.entries = {}
        session = self.session
        if session.degraded or len(session.active) <= 1:
            return
        for d in session.active:
            lost = frozenset({d})
            keep = tuple(x for x in session.active if x != d)
            self.entries[lost] = self._build_entry(lost, keep)

    def _build_entry(self, lost: FrozenSet[int],
                     keep: Tuple[int, ...]) -> LadderEntry:
        session = self.session
        report = session.report
        try:
            sub, mapping = report.topology.subset(keep)
            planner = DoraPlanner(
                report.graph, sub, report.qoe,
                partitioner_config=session.partitioner_config,
                scheduler_config=session.scheduler_config,
                adapter_config=session.adapter.config)
            trans = {pos: mapping[orig]
                     for pos, orig in enumerate(session.plan_fleet)
                     if orig in mapping}
            result = planner.replan(report.workload, session.plans,
                                    mapping=trans)
        except (ValueError, RuntimeError):
            # survivors disconnect the routed topology or admit no plan:
            # the scope is infeasible — detection will brown out instead
            return LadderEntry(lost=lost, keep=keep)
        return LadderEntry(lost=lost, keep=keep, mapping=mapping,
                           planner=planner, result=result,
                           qoe_ok=report.qoe.satisfied(result.best))

    def lookup(self, lost) -> Optional[LadderEntry]:
        return self.entries.get(frozenset(lost))

    def apply(self, lost) -> Optional[float]:
        """Switch the session to the precomputed fallback for ``lost``.

        Returns the stall (drain only — fallback weights are
        prestaged), or ``None`` when no feasible entry exists for this
        exact scope (caller falls back to naive replan / brownout).
        Adoption itself lives on the control plane
        (:meth:`ControlPlane.adopt_fallback`).
        """
        entry = self.lookup(lost)
        if entry is None or entry.result is None:
            return None
        return self.session.plane.adopt_fallback(entry)


class FleetLadder:
    """Per-scope fallback fleet assignments for one ``FleetSession``."""

    def __init__(self, session):
        self.session = session
        self.entries: Dict[FrozenSet[int], object] = {}
        self.build()

    def build(self) -> None:
        self.entries = {}
        session = self.session
        n_tenants = len(session.planner.tenants)
        for d in session.active:
            fleet = sorted(set(session.active) - {d})
            if len(fleet) < n_tenants:
                continue        # infeasible scope: not enough devices
            warm = {name: (list(sess.plans),
                           session.plan.tenants[name].allotment)
                    for name, sess in session.sessions.items()}
            merged = session.state
            conditions = merged if (merged.compute_speed
                                    or merged.bandwidth_scale) else None
            try:
                self.entries[frozenset({d})] = session.planner.plan(
                    devices=fleet, warm=warm, conditions=conditions)
            except (ValueError, RuntimeError):
                continue        # no feasible assignment without d

    def lookup(self, lost):
        return self.entries.get(frozenset(lost))

    def apply(self, lost) -> Optional[list]:
        """Adopt the precomputed fleet plan for ``lost``: every moved
        tenant pays only the drain (fallback weights are prestaged).
        Returns the tenant actions, or ``None`` when no entry covers
        the scope.  Adoption itself lives on the control plane
        (:meth:`FleetControlPlane.adopt_fallback`)."""
        new_plan = self.lookup(lost)
        if new_plan is None:
            return None
        return self.session.plane.adopt_fallback(lost, new_plan)
