"""Chaos serving engine: detection-latency-aware serving under faults.

The plain serving kernel treats every dynamics event as *announced*:
the session reacts at the event's timestamp and no request ever fails.
This engine runs the same open-loop admission model under
**unannounced** faults with honest failure semantics:

* **Ground truth vs. belief.** Fault onsets mutate ground truth only
  (a crashed device, a dead link, a silently-slowed straggler); the
  session's *believed* state is untouched until detection. Requests
  served during a silent slowdown pay the true (slower) latency; a
  plan whose route crosses a dead link or crashed device is broken.
* **Detection latency.** Crashes are detected by pumping a real
  :class:`~repro.runtime.heartbeat.Coordinator` over the beat grid —
  only crashed devices stop beating, so a crash at ``t`` is acted on
  at the first tick past ``t + miss_limit * beat_interval``. Link and
  straggler onsets are debounced by the same window.
* **Failure modes.** ``blind`` (broken, not yet detected): admitted
  requests wait out the per-request timeout, then fail and retry.
  ``down`` (detected, but no servable plan): requests fail fast and
  retry with capped exponential backoff. ``brownout`` (plan exists but
  QoE-infeasible): batch-class admissions are shed, interactive ones
  keep serving. Fault onset also *retro-fails* every booked-but-
  unfinished request — the pipeline's in-flight state is lost, and the
  energy already booked for them stays booked (work the fault wasted).
* **Recovery.** ``recovery="ladder"`` switches instantly to the
  precomputed :class:`~repro.resilience.ladder.FallbackLadder` entry
  (stall = pipeline drain only; weights are prestaged) and rebuilds
  the ladder in the background; ``recovery="replan"`` is naive
  replan-on-detect — planning time lands on the critical path and the
  switch pays the synchronous (no async prefetch overlap: the old
  pipeline is dead) load stall. Static strategies never recover;
  their requests stay blind until the fault's announced repair.
* **MTTR.** Each service-affecting fault records onset, detection and
  restore times; ``ServingTrace.mttr_s`` is the mean onset→restored
  gap over restored faults.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import time
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from ..core.adapter import DynamicsEvent
from ..core import events as kernel
from ..core.events import AdapterAction, RequestLog, ServingTrace
from ..runtime.heartbeat import Coordinator
from .faults import ResilienceConfig
from .ladder import FallbackLadder, FleetLadder

__all__ = ["ResilientStream", "plan_link_resources", "run_chaos",
           "run_chaos_fleet"]


def plan_link_resources(plan, fleet, topo) -> frozenset:
    """Link resource names a plan's traffic traverses, in original
    topology space: consecutive-stage pairs (activations) plus
    intra-stage pairs (TP/DP sync). A fault on any of these breaks the
    pipeline outright — it is not a repricing."""
    idx = list(fleet)
    used: Set[str] = set()
    stages = plan.stages
    for i, s in enumerate(stages):
        devs = [idx[d] for d in s.devices]
        for a_pos, a in enumerate(devs):
            for b in devs[a_pos + 1:]:
                used.update(r.name for r in topo.resources_between(a, b))
        if i + 1 < len(stages):
            for a in devs:
                for b in (idx[d] for d in stages[i + 1].devices):
                    if a != b:
                        used.update(
                            r.name for r in topo.resources_between(a, b))
    return frozenset(used)


class ResilientStream:
    """Per-request admission queue with failure modes and retries.

    The heap holds ``(issue_t, seq, request, attempt)`` — arrivals and
    re-queued retries interleave in time order. Unlike the vectorized
    ``Stream`` this steps per request (chaos runs are event-dense; the
    no-fault path never comes through here, so kernel parity is
    untouched)."""

    def __init__(self, arrivals, plan, *, policy, slo_s: float,
                 classes=(), class_id=None):
        self.arrival = np.ascontiguousarray(arrivals, dtype=np.float64)
        n = len(self.arrival)
        self.start = self.arrival.copy()
        self.finish = np.full(n, math.inf)
        self.attempts = np.zeros(n, dtype=np.int64)
        self.hedged = np.zeros(n, dtype=bool)
        self.classes = tuple(classes)
        self.class_id = class_id
        self.policy = policy
        self.timeout = policy.resolve_timeout(
            slo_s, plan.latency if plan is not None else slo_s)
        self.plan = plan
        self.mode = "ok"                 # ok | blind | down | brownout
        self.next_free = 0.0
        self.service_energy: Dict[int, float] = {}
        self.busy: Dict[int, float] = {}
        self._open: List[Tuple[int, float, float]] = []  # (idx, issued, fin)
        self._seq = n
        self._heap = [(float(a), i, i, 1) for i, a in enumerate(self.arrival)]
        heapq.heapify(self._heap)

    def _class_name(self, idx: int) -> str:
        if self.class_id is None or not self.classes:
            return ""
        return self.classes[int(self.class_id[idx])].name

    def serve_to(self, t: float) -> None:
        while self._heap and self._heap[0][0] < t:
            at, _, idx, attempt = heapq.heappop(self._heap)
            self._issue(at, idx, attempt)

    def drain(self) -> None:
        while self._heap:
            at, _, idx, attempt = heapq.heappop(self._heap)
            self._issue(at, idx, attempt)

    def _issue(self, at: float, idx: int, attempt: int) -> None:
        self.attempts[idx] = attempt
        if self.mode == "down" or self.plan is None:
            # detected outage with nothing servable: fail fast, back off
            self._requeue(idx, attempt, at)
            return
        if self.mode == "blind":
            # broken but undetected: the client waits out its timeout
            self._requeue(idx, attempt, at + self.timeout)
            return
        if self.mode == "brownout" and self._class_name(idx) == "batch":
            self.finish[idx] = math.inf      # shed, not retried
            return
        p = self.plan
        start = max(at, self.next_free)
        self.start[idx] = start
        self.finish[idx] = start + p.latency
        self.next_free = start + p.interval
        for d, e in p.non_idle_energy.items():
            self.service_energy[d] = self.service_energy.get(d, 0.0) + e
        for d, b in p.compute_busy.items():
            self.busy[d] = self.busy.get(d, 0.0) + b
        self._open.append((idx, at, self.finish[idx]))

    def _requeue(self, idx: int, attempt: int, fail_t: float) -> None:
        """Attempt failed, noticed at ``fail_t``; re-queue per policy."""
        self.finish[idx] = math.inf
        if attempt > self.policy.max_retries:
            return
        hedge = (self.policy.hedge
                 and self._class_name(idx) == "interactive")
        delay = 0.0 if hedge else self.policy.backoff(attempt + 1)
        if hedge:
            self.hedged[idx] = True
        self._seq += 1
        heapq.heappush(self._heap,
                       (fail_t + delay, self._seq, idx, attempt + 1))

    def break_pipeline(self, t: float) -> None:
        """Fault onset: in-flight state is lost, so every booked-but-
        unfinished request fails. The client notices at its timeout
        (or at ``t`` if that already passed); energy booked for the
        lost work stays booked."""
        pending, self._open = self._open, []
        for idx, issued, fin in pending:
            if fin <= t:
                continue
            self._requeue(idx, int(self.attempts[idx]),
                          max(t, issued + self.timeout))

    def stall(self, t: float, stall_s: float) -> None:
        if stall_s > 0.0:
            self.next_free = max(self.next_free, t) + stall_s

    def last_finite_finish(self) -> float:
        fin = self.finish[np.isfinite(self.finish)]
        return float(fin.max()) if len(fin) else 0.0


# -- fault occurrence bookkeeping ----------------------------------------------
def _new_record(kind: str, target, t: float, factor=None) -> Dict[str, object]:
    rec: Dict[str, object] = {
        "kind": kind, "target": target, "t": float(t),
        "detect_t": None, "restore_t": None, "mttr_s": None,
        "affected": False, "restored": False}
    if factor is not None:
        rec["factor"] = float(factor)
    return rec


def _crash_spans(occurrences, announced) -> Dict[int, List[Tuple[float, float]]]:
    """Per-device crash intervals ``[onset, repair)`` — a crash is
    repaired by an *announced* join (the rebooted device says hello)."""
    spans: Dict[int, List[Tuple[float, float]]] = {}
    open_: Dict[int, float] = {}
    items = sorted(
        [(rec["t"], 0, rec["target"]) for rec in occurrences
         if rec["kind"] == "crash"]
        + [(ev.t, 1, d) for _, ev in announced for d in ev.join],
        key=lambda x: (x[0], x[1]))
    for t, phase, d in items:
        if phase == 0:
            open_.setdefault(d, t)
        elif d in open_:
            spans.setdefault(d, []).append((open_.pop(d), t))
    for d, t in open_.items():
        spans.setdefault(d, []).append((t, math.inf))
    return spans


def _detect_crashes(n_devices: int, spans, t_end: float,
                    config: ResilienceConfig) -> Dict[Tuple[int, float], float]:
    """Pump a real Coordinator over the beat grid: only crashed devices
    stop beating, so detection lands at the first tick past
    ``onset + miss_limit * beat_interval``. Returns
    ``{(device, onset): detect_t}``."""
    coord = Coordinator(list(range(n_devices)),
                        beat_interval=config.beat_interval,
                        miss_limit=config.miss_limit)

    def down_at(d: int, t: float) -> bool:
        return any(o <= t < r for o, r in spans.get(d, ()))

    detects: Dict[Tuple[int, float], float] = {}
    last = t_end + config.detection_window_s + 2.0 * config.beat_interval
    k = 1
    t = config.beat_interval
    while t <= last:
        for d in range(n_devices):
            if not down_at(d, t):
                coord.beat(d, t)
        for d in coord.tick(t):
            onsets = [o for o, r in spans.get(d, ()) if o <= t < r]
            if onsets:
                detects[(d, max(onsets))] = t
        k += 1
        t = k * config.beat_interval
    return detects


def _expand_faults(timeline, config: ResilienceConfig):
    """Split a labeled timeline into announced events and individual
    fault occurrences, then schedule each occurrence's detection.

    Returns ``(announced, entries)`` where ``entries`` is the merged,
    time-ordered list of ``(t, prio, seq, kind, payload)`` the engine
    replays: fault onsets (prio 0), announced events (prio 1) and
    detections (prio 2)."""
    announced: List[Tuple[str, DynamicsEvent]] = []
    occurrences: List[Dict[str, object]] = []
    recoveries: List[Dict[str, object]] = []
    for label, ev in timeline:
        if ev.is_fault:
            for d in ev.crash:
                occurrences.append(_new_record("crash", int(d), ev.t))
            for r in ev.link_down:
                occurrences.append(_new_record("link_down", r, ev.t))
            for r in ev.link_up:
                recoveries.append(_new_record("link_up", r, ev.t))
            for d, f in sorted(ev.straggler.items()):
                if f == 1.0:
                    recoveries.append(
                        _new_record("straggler_recover", int(d), ev.t,
                                    factor=1.0))
                else:
                    occurrences.append(
                        _new_record("straggler", int(d), ev.t, factor=f))
        if ev.is_announced:
            announced.append((label if not ev.is_fault
                              else f"event@t={ev.t:g}s",
                              dataclasses.replace(ev, crash=(),
                                                  link_down=(), link_up=(),
                                                  straggler={})))
    return announced, occurrences, recoveries


def _build_entries(announced, occurrences, recoveries, detects,
                   config: ResilienceConfig):
    entries = []
    seq = 0
    for rec in occurrences + recoveries:
        entries.append((rec["t"], 0, seq, "onset", rec))
        seq += 1
        if rec["kind"] == "crash":
            dt = detects.get((rec["target"], rec["t"]))
        else:
            dt = rec["t"] + config.detection_window_s
        if dt is not None:
            entries.append((dt, 2, seq, "detect", rec))
            seq += 1
    for label, ev in announced:
        entries.append((ev.t, 1, seq, "announced", (label, ev)))
        seq += 1
    entries.sort(key=lambda e: (e[0], e[1], e[2]))
    return entries


def _describe(prefix: str, rec: Dict[str, object]) -> str:
    k, tgt = rec["kind"], rec["target"]
    if k == "crash":
        body = f"crash: device {tgt}"
    elif k == "link_down":
        body = f"link down: {tgt}"
    elif k == "link_up":
        body = f"link up: {tgt}"
    elif k == "straggler_recover":
        body = f"straggler recovered: {tgt}"
    else:
        body = f"straggler: {tgt}->x{format(rec.get('factor', 0.5), '.3g')}"
    return f"{prefix}{body}"


def _mean_mttr(fault_log) -> Optional[float]:
    vals = [rec["mttr_s"] for rec in fault_log
            if rec.get("mttr_s") is not None]
    return float(np.mean(vals)) if vals else None


# -- single-tenant engine ------------------------------------------------------
def run_chaos(*, sc, strategy: str, session, report, scheduler, load,
              slo: float, arr, timeline, config: ResilienceConfig,
              recovery: str = "ladder") -> ServingTrace:
    """Delegate target of ``simulate_requests`` whenever fault content
    is present. ``session``/``report``/``scheduler`` arrive pre-armed
    exactly as the plain path builds them."""
    if recovery not in ("ladder", "replan", "none"):
        raise ValueError(f"unknown recovery mode {recovery!r}")
    topo = report.topology
    announced, occurrences, recoveries = _expand_faults(timeline, config)
    spans = _crash_spans(occurrences, announced)
    t_end = max([0.0, float(arr[-1]) if len(arr) else 0.0,
                 *(ev.t for _, ev in timeline)])
    detects = _detect_crashes(topo.n, spans, t_end, config)
    entries = _build_entries(announced, occurrences, recoveries, detects,
                             config)

    dora_mode = strategy == "dora"
    ladder = (FallbackLadder(session)
              if dora_mode and recovery == "ladder" else None)

    if dora_mode:
        plan0 = kernel.freeze_plan(session.current, session.plan_fleet, topo)
    else:
        plan0 = kernel.freeze_plan(report.best, range(topo.n), topo)
    class_id = load.sample_class_ids(len(arr))
    stream = ResilientStream(arr, plan0, policy=config.retry, slo_s=slo,
                             classes=load.classes, class_id=class_id)
    presence = kernel.PresenceTracker(topo.n)
    actions: List[AdapterAction] = []
    fault_log: List[Dict[str, object]] = []

    # ground truth (what actually happened) vs. belief (what the
    # session/static state knows)
    crashed: Set[int] = set()
    dead_links: Set[str] = set()
    true_speed: Dict[int, float] = {}
    detected_crashed: Set[int] = set()
    detected_links: Set[str] = set()
    fault_touched = False
    from ..control.plane import StaticPlane
    static = StaticPlane(topo.n, plan0.devices)

    def current_frozen():
        """The *true* active plan: the believed plan re-priced under
        silent straggler truth (bit-identical freeze when no silent
        divergence — parity with the plain path)."""
        if dora_mode:
            if session.degraded:
                return None
            plan = session.current
            overlay = {d: f for d, f in true_speed.items()
                       if d in session.plan_fleet
                       and f != session.state.compute_speed.get(d, 1.0)}
            if overlay:
                mapping = {orig: pos
                           for pos, orig in enumerate(session.plan_fleet)}
                cond = session._translate(session.state)
                speed = dict(cond.compute_speed)
                speed.update({mapping[d]: f for d, f in overlay.items()})
                plan = session.adapter.scheduler.refine(
                    plan, compute_speed=speed,
                    bandwidth_scale=dict(cond.bandwidth_scale))
            return kernel.freeze_plan(plan, session.plan_fleet, topo)
        if not static.alive:
            return None
        speed = dict(static.state.compute_speed)
        speed.update({d: f for d, f in true_speed.items()
                      if speed.get(d, 1.0) != f})
        if speed or static.state.bandwidth_scale:
            plan = scheduler.evaluate_fair(
                report.best, compute_speed=speed,
                bandwidth_scale=dict(static.state.bandwidth_scale))
        else:
            plan = report.best
        return kernel.freeze_plan(plan, range(topo.n), topo)

    def route_links() -> frozenset:
        if dora_mode:
            if session.degraded:
                return frozenset()
            return plan_link_resources(session.current, session.plan_fleet,
                                       topo)
        return plan_link_resources(report.best, range(topo.n), topo)

    def refresh() -> None:
        frozen = current_frozen()
        if frozen is None:
            stream.plan = None
            stream.mode = "down" if dora_mode else "blind"
            return
        stream.plan = frozen
        broken_devs = set(frozen.devices) & crashed
        broken_links = route_links() & dead_links
        if broken_devs or broken_links:
            if not dora_mode:
                stream.mode = "blind"    # static never reroutes
            elif (broken_devs - detected_crashed) \
                    or (broken_links - detected_links):
                stream.mode = "blind"
            else:
                stream.mode = "down"
        elif dora_mode and fault_touched and not session.meets_qoe:
            stream.mode = "brownout"
        else:
            stream.mode = "ok"

    def close_restored(t: float, extra: float) -> None:
        if stream.mode not in ("ok", "brownout"):
            return
        for rec in fault_log:
            if (rec["affected"] and not rec["restored"]
                    and rec["kind"] in ("crash", "link_down")
                    and rec["t"] <= t):
                rec["restored"] = True
                rec["restore_t"] = t + extra
                rec["mttr_s"] = t + extra - rec["t"]

    def lat_now() -> float:
        return (stream.plan.latency
                if stream.plan is not None
                and stream.mode in ("ok", "brownout") else math.inf)

    for t, prio, _seq, kind, payload in entries:
        stream.serve_to(t)
        if kind == "onset":
            rec = payload
            k, tgt = rec["kind"], rec["target"]
            fault_touched = fault_touched or k in ("crash", "link_down",
                                                   "straggler")
            frozen = current_frozen()
            devs = set(frozen.devices) if frozen is not None else set()
            links = route_links()
            if k == "crash":
                crashed.add(tgt)
                presence.apply(DynamicsEvent(t=t, leave=(tgt,)))
                rec["affected"] = tgt in devs
            elif k == "link_down":
                dead_links.add(tgt)
                rec["affected"] = tgt in links
            elif k == "link_up":
                dead_links.discard(tgt)
            elif k == "straggler":
                true_speed[tgt] = rec["factor"]
                rec["affected"] = tgt in devs
            else:                        # straggler_recover
                true_speed[tgt] = 1.0
            if k in ("crash", "link_down", "straggler"):
                fault_log.append(rec)
            if rec["affected"] and k in ("crash", "link_down"):
                stream.break_pipeline(t)
            refresh()
            actions.append(AdapterAction(
                t=t, label=_describe("", rec), action="unobserved",
                react_s=0.0, stall_s=0.0, latency_after=lat_now()))
            close_restored(t, 0.0)       # a link_up can restore silently
            continue
        if kind == "announced":
            label, ev = payload
            presence.apply(ev)
            for d in ev.join:            # a rejoin repairs a crash
                if d in crashed:
                    crashed.discard(d)
                    detected_crashed.discard(d)
            react = stall = 0.0
            if dora_mode:
                new, act, react = session.on_dynamics(ev)
                stall = (float(new.meta.get("switch_stall_s", 0.0))
                         if act == "replan" else 0.0)
                stream.stall(t, stall)
                if ladder is not None and act == "replan":
                    ladder.build()       # fleet changed: refresh scopes
            else:
                t0 = time.perf_counter()
                act = "repriced" if static.apply(ev) else "degraded"
                react = time.perf_counter() - t0
            refresh()
            actions.append(AdapterAction(
                t=t, label=label, action=act, react_s=react, stall_s=stall,
                latency_after=lat_now()))
            close_restored(t, stall)
            continue
        # detection
        rec = payload
        k, tgt = rec["kind"], rec["target"]
        if k == "crash" and tgt not in crashed:
            continue                     # repaired before detection
        rec["detect_t"] = t
        if k == "crash":
            detected_crashed.add(tgt)
        elif k == "link_down":
            detected_links.add(tgt)
        elif k == "link_up":
            detected_links.discard(tgt)
        was_broken = stream.mode in ("blind", "down")
        if dora_mode and recovery != "none":
            # detection-time recovery is the control plane's job
            act, react, stall = session.plane.on_detection(
                rec, config=config, ladder=ladder)
            if act not in ("degraded", "unobserved") \
                    and not session.meets_qoe:
                act = "brownout"         # adopted, but QoE-infeasible
            # recovery planning lands on the critical path only when
            # the pipeline was actually out
            stream.stall(t, react + stall if was_broken else stall)
        else:
            act, react, stall = ("degraded" if was_broken
                                 else "unobserved"), 0.0, 0.0
        if k in ("straggler", "straggler_recover") and rec.get("affected"):
            rec["restored"] = True
            rec["restore_t"] = t + react
            rec["mttr_s"] = t + react - rec["t"]
        refresh()
        actions.append(AdapterAction(
            t=t, label=_describe("detected ", rec), action=act,
            react_s=react, stall_s=stall, latency_after=lat_now()))
        close_restored(t, react + stall)

    stream.drain()

    horizon = max([0.0, float(arr[-1]) if len(arr) else 0.0,
                   stream.last_finite_finish(),
                   *(e[0] for e in entries)])
    idle_s = presence.seconds(horizon)
    per_device_energy: Dict[int, float] = {}
    for d, dev in enumerate(topo.devices):
        per_device_energy[d] = stream.service_energy.get(d, 0.0) \
            + dev.p_idle * idle_s.get(d, 0.0)

    log = RequestLog(stream.arrival, stream.start, stream.finish,
                     class_id=class_id, classes=load.classes,
                     attempts=stream.attempts, hedged=stream.hedged)
    return ServingTrace(scenario=sc.name, strategy=strategy, load=load,
                        slo_s=slo, requests=log, actions=actions,
                        per_device_energy=per_device_energy,
                        per_device_busy=dict(stream.busy),
                        horizon_s=float(horizon),
                        per_device_idle_s=idle_s,
                        faults=fault_log, mttr_s=_mean_mttr(fault_log))


# -- fleet engine --------------------------------------------------------------
def run_chaos_fleet(*, fs, session, loads, timeline,
                    config: ResilienceConfig, recovery: str = "ladder"):
    """Multi-tenant chaos run: delegate target of ``simulate_fleet``
    when fault content is present. Mirrors its energy/ownership
    attribution with per-tenant :class:`ResilientStream`\\ s."""
    from ..sim.fleet import FleetAction

    if recovery not in ("ladder", "replan", "none"):
        raise ValueError(f"unknown recovery mode {recovery!r}")
    topo = session.planner.topo
    announced, occurrences, recoveries = _expand_faults(timeline, config)
    spans = _crash_spans(occurrences, announced)
    names = [t.name for t in fs.tenants]
    arrivals = {n: loads[n].sample_arrivals() for n in names}
    class_ids = {n: loads[n].sample_class_ids(len(arrivals[n]))
                 for n in names}
    t_end = max([0.0, *(float(a[-1]) for a in arrivals.values() if len(a)),
                 *(ev.t for _, ev in timeline)])
    detects = _detect_crashes(topo.n, spans, t_end, config)
    entries = _build_entries(announced, occurrences, recoveries, detects,
                             config)
    ladder = FleetLadder(session) if recovery == "ladder" else None

    # ground truth vs believed state (see run_chaos)
    crashed: Set[int] = set()
    dead_links: Set[str] = set()
    true_speed: Dict[int, float] = {}
    detected_crashed: Set[int] = set()
    detected_links: Set[str] = set()
    fault_touched = False
    rebalance_stuck = False              # naive replan hit a dead end

    def freeze(name: str):
        tp = session.plan.tenants.get(name)
        sess = session.sessions.get(name)
        if tp is None or sess is None:
            return None
        plan = sess.current
        overlay = {tp.mapping[d]: f for d, f in true_speed.items()
                   if d in tp.mapping
                   and sess.state.compute_speed.get(tp.mapping[d], 1.0) != f}
        if overlay:
            speed = dict(sess.state.compute_speed)
            speed.update(overlay)
            plan = sess.adapter.scheduler.refine(
                plan, compute_speed=speed,
                bandwidth_scale=dict(sess.state.bandwidth_scale))
        return kernel.freeze_plan(plan, tp.allotment, topo)

    slos = {}
    for tn in fs.tenants:
        load = loads[tn.name]
        slos[tn.name] = (load.slo_s if load.slo_s is not None
                         else tn.qoe.t_qoe)
    streams: Dict[str, ResilientStream] = {}
    for tn in fs.tenants:
        streams[tn.name] = ResilientStream(
            arrivals[tn.name], freeze(tn.name), policy=config.retry,
            slo_s=slos[tn.name], classes=loads[tn.name].classes,
            class_id=class_ids[tn.name])
    presence = kernel.PresenceTracker(topo.n)
    ownership = kernel.OwnershipTracker(session.plan.assignments)
    rows: List[FleetAction] = []
    fault_log: List[Dict[str, object]] = []

    def tenant_route(name: str) -> frozenset:
        tp = session.plan.tenants.get(name)
        sess = session.sessions.get(name)
        if tp is None or sess is None:
            return frozenset()
        return plan_link_resources(sess.current, tp.allotment, topo)

    def refresh() -> None:
        for name, stream in streams.items():
            frozen = freeze(name)
            tp = session.plan.tenants.get(name)
            if frozen is None or tp is None:
                stream.plan = None
                stream.mode = "down"
                continue
            stream.plan = frozen
            broken_devs = set(tp.allotment) & crashed
            broken_links = tenant_route(name) & dead_links
            if broken_devs or broken_links:
                if (broken_devs - detected_crashed) \
                        or (broken_links - detected_links):
                    stream.mode = "blind"
                else:
                    stream.mode = "down"
            elif fault_touched and not session.sessions[name].meets_qoe:
                stream.mode = "brownout"
            else:
                stream.mode = "ok"

    def all_serving() -> bool:
        return all(s.mode in ("ok", "brownout") for s in streams.values())

    def close_restored(t: float, extra: float) -> None:
        if not all_serving():
            return
        for rec in fault_log:
            if (rec["affected"] and not rec["restored"]
                    and rec["kind"] in ("crash", "link_down")
                    and rec["t"] <= t):
                rec["restored"] = True
                rec["restore_t"] = t + extra
                rec["mttr_s"] = t + extra - rec["t"]

    def dispatch(t: float, label: str, ev: DynamicsEvent,
                 *, critical: bool, extra_stall: float = 0.0) -> float:
        """Feed one believed event through the FleetSession; book the
        tenant stalls (+planning time when ``critical``). Returns the
        worst stall booked."""
        nonlocal rebalance_stuck
        t0 = time.perf_counter()
        try:
            reacted = session.on_dynamics(ev)
            rebalance_stuck = False
        except (ValueError, RuntimeError):
            # not enough devices / disconnected: the affected tenants
            # stay down until an announced rejoin
            rebalance_stuck = True
            reacted = []
        react = time.perf_counter() - t0
        worst = 0.0
        for a in reacted:
            stall = a.stall_s + extra_stall
            if a.tenant in streams:
                streams[a.tenant].stall(
                    t, (react + stall) if critical else stall)
            worst = max(worst, stall)
            rows.append(FleetAction(
                t=t, label=label, tenant=a.tenant, action=a.action,
                react_s=react, stall_s=stall,
                latency_after=a.latency_after, allotment=a.allotment))
        ownership.update(t, session.plan.assignments)
        return react + worst

    for t, prio, _seq, kind, payload in entries:
        for s in streams.values():
            s.serve_to(t)
        if kind == "onset":
            rec = payload
            k, tgt = rec["kind"], rec["target"]
            fault_touched = fault_touched or k in ("crash", "link_down",
                                                   "straggler")
            if k == "crash":
                crashed.add(tgt)
                presence.apply(DynamicsEvent(t=t, leave=(tgt,)))
                rec["affected"] = any(
                    tgt in tp.allotment
                    for tp in session.plan.tenants.values())
            elif k == "link_down":
                dead_links.add(tgt)
                rec["affected"] = any(tgt in tenant_route(n) for n in names)
            elif k == "link_up":
                dead_links.discard(tgt)
            elif k == "straggler":
                true_speed[tgt] = rec["factor"]
                rec["affected"] = any(
                    tgt in tp.allotment
                    for tp in session.plan.tenants.values())
            else:
                true_speed[tgt] = 1.0
            if k in ("crash", "link_down", "straggler"):
                fault_log.append(rec)
            if rec["affected"] and k in ("crash", "link_down"):
                for name, stream in streams.items():
                    tp = session.plan.tenants.get(name)
                    if tp is None:
                        continue
                    if (k == "crash" and tgt in tp.allotment) or \
                            (k == "link_down" and tgt in tenant_route(name)):
                        stream.break_pipeline(t)
            refresh()
            rows.append(FleetAction(
                t=t, label=_describe("", rec), tenant="*",
                action="unobserved", react_s=0.0, stall_s=0.0,
                latency_after=math.nan, allotment=tuple(session.active)))
            close_restored(t, 0.0)
            continue
        if kind == "announced":
            label, ev = payload
            presence.apply(ev)
            for d in ev.join:
                if d in crashed:
                    crashed.discard(d)
                    detected_crashed.discard(d)
            extra = dispatch(t, label, ev, critical=False)
            refresh()
            close_restored(t, extra)
            continue
        rec = payload
        k, tgt = rec["kind"], rec["target"]
        if k == "crash" and tgt not in crashed:
            continue
        rec["detect_t"] = t
        if k == "crash":
            detected_crashed.add(tgt)
        elif k == "link_down":
            detected_links.add(tgt)
        elif k == "link_up":
            detected_links.discard(tgt)
        extra = 0.0
        if recovery != "none":
            if k == "crash" and tgt in session.active:
                handled = False
                if ladder is not None:
                    t0 = time.perf_counter()
                    acts = ladder.apply({tgt})
                    if acts is not None:
                        react = time.perf_counter() - t0
                        worst = 0.0
                        for a in acts:
                            if a.tenant in streams:
                                streams[a.tenant].stall(t, react + a.stall_s)
                            worst = max(worst, a.stall_s)
                            rows.append(FleetAction(
                                t=t, label=_describe("detected ", rec),
                                tenant=a.tenant, action=a.action,
                                react_s=react, stall_s=a.stall_s,
                                latency_after=a.latency_after,
                                allotment=a.allotment))
                        ownership.update(t, session.plan.assignments)
                        ladder.build()
                        extra = react + worst
                        handled = True
                if not handled:
                    # naive replan-on-detect: tenants on the dead device
                    # can't overlap the weight prefetch with serving,
                    # nor stream ahead of the switch
                    from ..core.adapter import AdapterConfig
                    prev_cfg = session.planner.adapter_config
                    cfg = dataclasses.replace(prev_cfg or AdapterConfig(),
                                              async_switching=False,
                                              streamed_migration=False)
                    session.planner.adapter_config = cfg
                    try:
                        extra = dispatch(
                            t, _describe("detected ", rec),
                            DynamicsEvent(t=t, leave=(tgt,)), critical=True)
                    finally:
                        session.planner.adapter_config = prev_cfg
                    if ladder is not None:
                        ladder.build()
            elif k in ("link_down", "link_up"):
                scale = (config.link_down_scale if k == "link_down" else 1.0)
                extra = dispatch(t, _describe("detected ", rec),
                                 DynamicsEvent(t=t,
                                               bandwidth_scale={tgt: scale}),
                                 critical=False)
            elif k in ("straggler", "straggler_recover"):
                extra = dispatch(
                    t, _describe("detected ", rec),
                    DynamicsEvent(t=t,
                                  compute_speed={tgt: rec.get("factor",
                                                              1.0)}),
                    critical=False)
        if k in ("straggler", "straggler_recover") and rec.get("affected"):
            rec["restored"] = True
            rec["restore_t"] = t
            rec["mttr_s"] = t - rec["t"]
        refresh()
        close_restored(t, extra)

    for s in streams.values():
        s.drain()

    # -- trace assembly: mirrors ``simulate_fleet``'s energy/ownership
    # attribution (idle draw once per device over its presence interval,
    # prorated across owning tenants; service energy to the admitter)
    from collections import OrderedDict
    from ..sim.fleet import FleetTrace

    horizon = max([0.0,
                   *(float(a[-1]) for a in arrivals.values() if len(a)),
                   *(s.last_finite_finish() for s in streams.values()),
                   *(e[0] for e in entries)])
    presence_iv = presence.intervals(horizon)
    fleet_idle = presence.seconds(horizon)
    fleet_energy: Dict[int, float] = {
        d: dev.p_idle * fleet_idle.get(d, 0.0)
        for d, dev in enumerate(topo.devices)}
    tenant_idle: Dict[str, Dict[int, float]] = {n: {} for n in names}
    for d, span_list in ownership.spans(horizon).items():
        for lo, hi, owner in span_list:
            if owner not in tenant_idle:
                continue
            secs = kernel.overlap_seconds(presence_iv.get(d, ()), lo, hi)
            if secs > 0.0:
                tenant_idle[owner][d] = tenant_idle[owner].get(d, 0.0) + secs

    traces: "OrderedDict[str, ServingTrace]" = OrderedDict()
    fleet_busy: Dict[int, float] = {}
    for tn in fs.tenants:
        name = tn.name
        stream = streams[name]
        for d, e in stream.service_energy.items():
            fleet_energy[d] = fleet_energy.get(d, 0.0) + e
        for d, b in stream.busy.items():
            fleet_busy[d] = fleet_busy.get(d, 0.0) + b
        tenant_energy = dict(stream.service_energy)
        idle_secs = tenant_idle[name]
        for d, secs in idle_secs.items():
            tenant_energy[d] = tenant_energy.get(d, 0.0) \
                + topo.devices[d].p_idle * secs
        log = RequestLog(stream.arrival, stream.start, stream.finish,
                         class_id=class_ids[name],
                         classes=loads[name].classes,
                         attempts=stream.attempts, hedged=stream.hedged)
        traces[name] = ServingTrace(
            scenario=f"{fs.name}/{name}", strategy="fleet",
            load=loads[name], slo_s=slos[name], requests=log,
            actions=[AdapterAction(t=a.t, label=a.label, action=a.action,
                                   react_s=a.react_s, stall_s=a.stall_s,
                                   latency_after=a.latency_after)
                     for a in rows if a.tenant == name],
            per_device_energy=tenant_energy,
            per_device_busy=dict(stream.busy),
            horizon_s=float(horizon),
            per_device_idle_s=idle_secs)

    return FleetTrace(
        fleet=fs.name, tenants=traces, actions=rows,
        assignments={k: tuple(v)
                     for k, v in session.plan.assignments.items()},
        per_device_energy=fleet_energy, per_device_busy=fleet_busy,
        horizon_s=float(horizon), rebalances=session.rebalances,
        ownership=ownership.history, faults=fault_log,
        mttr_s=_mean_mttr(fault_log))
