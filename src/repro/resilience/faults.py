"""Fault model: seeded fault scripts, retry policy, resilience knobs.

A :class:`Fault` is one unannounced failure occurrence; a
:class:`FaultScript` is an ordered, seeded collection of them that
compiles to ``DynamicsEvent`` onsets (silent — carrying only the new
``crash`` / ``link_down`` / ``link_up`` / ``straggler`` fields) plus
*announced* repair events (a crashed device that comes back rejoins
through the ordinary churn path, because a rebooted device says hello).

Scripts compose with the PR 6 scenario families: pass
``faults=FaultScript.random(sc, seed=0)`` to ``dora.simulate(...,
mode="requests")``, or use the ``faulty_sites`` generator family whose
timelines already carry fault events.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.adapter import DynamicsEvent

FAULT_KINDS = ("crash", "link_flap", "straggler")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One unannounced failure occurrence.

    kind      -- "crash" (device stops silently), "link_flap" (a link
                 resource goes down for a duration), or "straggler"
                 (silent slowdown; the device keeps heartbeating its
                 *nominal* speed, so the planner's believed state is
                 wrong until the slowdown is detected).
    t         -- onset time (seconds into the run).
    target    -- device id (crash/straggler) or link resource name
                 (link_flap).
    duration  -- seconds until repair; ``None`` means the fault lasts
                 to the end of the run.
    factor    -- straggler speed multiplier (< 1.0 is slower); ignored
                 for the other kinds.
    """

    kind: str
    t: float
    target: object
    duration: Optional[float] = None
    factor: float = 0.5

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == "link_flap" and not isinstance(self.target, str):
            raise TypeError("link_flap target must be a link resource name")
        if self.kind in ("crash", "straggler") and not isinstance(self.target, int):
            raise TypeError(f"{self.kind} target must be a device id")

    @property
    def repair_t(self) -> Optional[float]:
        return None if self.duration is None else self.t + self.duration

    def describe(self) -> str:
        tail = "" if self.duration is None else f" for {self.duration:g}s"
        if self.kind == "straggler":
            return f"straggler: {self.target}->x{self.factor:g}{tail}"
        noun = "crash" if self.kind == "crash" else "link down"
        return f"{noun}: {self.target}{tail}"


@dataclasses.dataclass(frozen=True)
class FaultScript:
    """An ordered, seeded set of faults for one chaos run."""

    faults: Tuple[Fault, ...]
    name: str = ""
    seed: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "faults",
                           tuple(sorted(self.faults, key=lambda f: f.t)))

    def events(self) -> List[DynamicsEvent]:
        """Compile to a timeline of ``DynamicsEvent``s.

        Onsets are silent (fault fields only). Repairs are announced:
        a crashed device rejoins via ``join`` (a rebooted device
        re-registers), a flapped link comes back via ``link_up``, and
        a straggler recovering resets its factor to 1.0.
        """
        out: List[DynamicsEvent] = []
        for f in self.faults:
            if f.kind == "crash":
                out.append(DynamicsEvent(t=f.t, crash=(f.target,)))
                if f.repair_t is not None:
                    out.append(DynamicsEvent(t=f.repair_t, join=(f.target,)))
            elif f.kind == "link_flap":
                out.append(DynamicsEvent(t=f.t, link_down=(f.target,)))
                if f.repair_t is not None:
                    out.append(DynamicsEvent(t=f.repair_t, link_up=(f.target,)))
            else:  # straggler
                out.append(DynamicsEvent(t=f.t, straggler={f.target: f.factor}))
                if f.repair_t is not None:
                    out.append(DynamicsEvent(t=f.repair_t,
                                             straggler={f.target: 1.0}))
        out.sort(key=lambda ev: ev.t)
        return out

    @classmethod
    def random(cls, scenario, seed: int = 0, *,
               n_faults: Optional[int] = None,
               kinds: Sequence[str] = FAULT_KINDS,
               crashable: Optional[Sequence[int]] = None,
               t0: Tuple[float, float] = (4.0, 20.0),
               gap: Tuple[float, float] = (8.0, 30.0),
               duration: Tuple[float, float] = (10.0, 45.0),
               repair_p: float = 0.7) -> "FaultScript":
        """Seeded fault generator for a scenario.

        Deterministic in ``(scenario.name, seed)``; independent of any
        other RNG stream in the repo. Always includes at least one
        crash when a crashable device exists. Device 0 is excluded
        from the default crash pool (it anchors the plan's first
        stage), but callers may pass ``crashable`` explicitly — e.g.
        ``crashable=[0]`` to exercise coordinator failover.
        """
        rng = random.Random(f"dora-chaos:{getattr(scenario, 'name', scenario)}:{seed}")
        topo = scenario.build_topology()
        n = topo.n
        if crashable is None:
            crashable = list(range(1, n))
        crashable = list(crashable)
        links = sorted({r.name for i in range(n) for j in range(i + 1, n)
                        for r in topo.resources_between(i, j)})
        kinds = [k for k in kinds
                 if not (k == "crash" and not crashable)
                 and not (k == "link_flap" and not links)]
        if not kinds:
            raise ValueError("no applicable fault kinds for this scenario")
        if n_faults is None:
            n_faults = rng.randint(1, 3)
        faults: List[Fault] = []
        t = rng.uniform(*t0)
        order = list(kinds)
        if "crash" in order:            # guarantee one crash per script
            order.remove("crash")
            order.insert(0, "crash")
        for i in range(n_faults):
            kind = order[0] if i == 0 else rng.choice(kinds)
            dur = rng.uniform(*duration) if rng.random() < repair_p else None
            if kind == "crash":
                faults.append(Fault("crash", t, rng.choice(crashable), dur))
            elif kind == "link_flap":
                faults.append(Fault("link_flap", t, rng.choice(links), dur))
            else:
                faults.append(Fault("straggler", t, rng.randrange(n), dur,
                                    factor=rng.uniform(0.2, 0.6)))
            t += rng.uniform(*gap)
        return cls(faults=tuple(faults),
                   name=f"{getattr(scenario, 'name', scenario)}/chaos-{seed}",
                   seed=seed)

    @classmethod
    def for_session(cls, session, seed: int = 0, **kwargs) -> "FaultScript":
        """Seeded faults aimed at an armed ``ServeSession``'s *plan
        devices* — the crashes that actually break service (a crash of
        an idle device exercises detection but affects nothing). The
        chaos bench uses this so every script is service-affecting."""
        from ..core.events import freeze_plan
        frozen = freeze_plan(session.current, session.plan_fleet,
                             session.report.topology)
        kwargs.setdefault("crashable", list(frozen.devices))
        return cls.random(session.report.scenario, seed, **kwargs)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Client-side retry semantics for failed requests.

    ``timeout_s`` is how long a client waits on a request issued into
    a *broken* (not-yet-detected) pipeline before giving up; ``None``
    derives it per run as ``max(3 * SLO, 5 * plan latency)``. Healthy
    segments never time out, so the no-fault path stays bit-identical
    to the Lindley kernel. Retries back off exponentially (capped);
    hedged retries — enabled for request classes named "interactive" —
    skip the backoff and re-issue immediately.
    """

    timeout_s: Optional[float] = None
    max_retries: int = 2
    backoff_s: float = 0.5
    backoff_mult: float = 2.0
    backoff_cap_s: float = 8.0
    hedge: bool = True

    def backoff(self, attempt: int) -> float:
        """Delay before retry number ``attempt`` (attempt 2 = first retry)."""
        return min(self.backoff_cap_s,
                   self.backoff_s * self.backoff_mult ** max(0, attempt - 2))

    def resolve_timeout(self, slo_s: float, latency_s: float) -> float:
        if self.timeout_s is not None:
            return self.timeout_s
        return max(3.0 * slo_s, 5.0 * latency_s)


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs for the chaos serving engine.

    The detection window is ``miss_limit * beat_interval`` (paper §5):
    a crash at ``t`` is only acted on at the first heartbeat tick after
    ``t + window``. ``link_down_scale`` is the bandwidth scale the
    session *believes* for a detected-down link (near-zero, so replans
    route around it); ``straggler_window_s`` defaults to the detection
    window.
    """

    beat_interval: float = 1.0
    miss_limit: int = 3
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    link_down_scale: float = 0.05

    @property
    def detection_window_s(self) -> float:
        return self.miss_limit * self.beat_interval


def split_timeline(timeline) -> Tuple[List[DynamicsEvent], List[DynamicsEvent]]:
    """Split a normalized timeline into (announced, fault) event lists.

    An event carrying both announced and fault content is split into
    two events at the same ``t`` so each side sees a pure stream.
    """
    announced: List[DynamicsEvent] = []
    faults: List[DynamicsEvent] = []
    for ev in timeline:
        if ev.is_fault and ev.is_announced:
            announced.append(dataclasses.replace(
                ev, crash=(), link_down=(), link_up=(), straggler={}))
            faults.append(DynamicsEvent(t=ev.t, crash=ev.crash,
                                        link_down=ev.link_down,
                                        link_up=ev.link_up,
                                        straggler=dict(ev.straggler)))
        elif ev.is_fault:
            faults.append(ev)
        else:
            announced.append(ev)
    return announced, faults
