"""Resilience layer: unannounced faults, detection-latency-aware serving,
and a degraded-plan fallback ladder.

The serving stack historically only modeled *announced* churn
(``DynamicsEvent.leave/join`` at a known instant, clean replanning, no
request ever fails). This package makes unannounced failure a
first-class dynamic:

- :mod:`repro.resilience.faults` — the fault model (``Fault``,
  ``FaultScript``), the client-side ``RetryPolicy`` and the
  ``ResilienceConfig`` knobs (heartbeat cadence, detection window).
- :mod:`repro.resilience.ladder` — precomputed QoE-ranked fallback
  plans per single-device-loss scope (``FallbackLadder`` for
  ``ServeSession``, ``FleetLadder`` for ``FleetSession``).
- :mod:`repro.resilience.engine` — the chaos serving engine: pumps a
  real ``runtime.heartbeat.Coordinator`` over the beat grid so a crash
  at ``t`` is only acted on at ``t + miss_limit*beat_interval``, fails
  or times out blind-window requests, re-queues them through the
  recovered plan, and records failed/retried/hedged counts plus MTTR.

Entry point: ``dora.simulate(sc, mode="requests", faults=...)`` (or
``sim.serving.simulate_requests(..., faults=...)`` directly).
"""
from .faults import (Fault, FaultScript, ResilienceConfig, RetryPolicy,
                     split_timeline)
from .ladder import FallbackLadder, FleetLadder, LadderEntry

__all__ = [
    "Fault",
    "FaultScript",
    "RetryPolicy",
    "ResilienceConfig",
    "split_timeline",
    "FallbackLadder",
    "FleetLadder",
    "LadderEntry",
]
