"""Elastic training controller: node loss → shrink mesh → replan →
restore from checkpoint with resharding → resume.

The controller composes the substrate pieces: the Coordinator detects
failures, Dora's planner re-partitions for the surviving fleet, and the
Checkpointer's elastic restore maps saved shards onto the new mesh. On
CPU this is exercised by integration tests with a host mesh that
shrinks (e.g. 8 → 4 virtual devices).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
from jax.sharding import NamedSharding

from ..checkpoint import Checkpointer, latest_step
from .heartbeat import Coordinator


@dataclasses.dataclass
class ElasticState:
    mesh: Any
    step: int
    params: Any
    opt_state: Any
    generation: int = 0          # bumps on every re-mesh


class ElasticController:
    """Owns the train loop's distributed state across mesh generations."""

    def __init__(self, *, make_mesh: Callable[[int], Any],
                 spec_fn: Callable[[Any, Any], Tuple[Any, Any]],
                 ckpt: Checkpointer, n_devices: int):
        """``make_mesh(n)`` builds a mesh over n devices; ``spec_fn(mesh,
        shapes)`` returns (param_specs, opt_specs) for that mesh."""
        self.make_mesh = make_mesh
        self.spec_fn = spec_fn
        self.ckpt = ckpt
        self.n_devices = n_devices
        self.coordinator = Coordinator(list(range(n_devices)),
                                       on_failure=self._on_failure)
        self._pending_failures: List[int] = []

    def _on_failure(self, failed: List[int]) -> None:
        self._pending_failures.extend(failed)

    def needs_remesh(self) -> bool:
        return bool(self._pending_failures)

    def remesh(self, state: ElasticState, train_tree_shapes) -> ElasticState:
        """Shrink to the healthy device count and restore the latest
        committed checkpoint onto the new mesh.

        ``train_tree_shapes`` — ShapeDtypeStructs of the combined
        {params, opt} tree (shapes/dtypes only; shardings recomputed
        for the shrunk mesh by ``spec_fn``)."""
        healthy = len(self.coordinator.healthy)
        if healthy == 0:
            raise RuntimeError("no healthy devices left")
        new_mesh = self.make_mesh(healthy)
        specs = self.spec_fn(new_mesh, train_tree_shapes)
        step = latest_step(self.ckpt.dir)
        if step is None:
            raise RuntimeError("no checkpoint to restore after failure")

        structs = jax.tree.map(
            lambda sh, sp: jax.ShapeDtypeStruct(
                sh.shape, sh.dtype, sharding=NamedSharding(new_mesh, sp)),
            train_tree_shapes, specs)
        tree = self.ckpt.restore(step, structs)
        self._pending_failures.clear()
        return ElasticState(mesh=new_mesh, step=step,
                            params=tree["params"], opt_state=tree["opt"],
                            generation=state.generation + 1)
