from .heartbeat import Coordinator, DeviceStatus
from .elastic import ElasticController
from .pipeline import DoraPipelineExecutor

__all__ = ["Coordinator", "DeviceStatus", "ElasticController",
           "DoraPipelineExecutor"]
