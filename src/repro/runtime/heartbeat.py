"""Coordinator + heartbeat failure detector (paper §5).

The most capable device acts as coordinator; it receives periodic
heartbeats carrying (compute speed factor, available bandwidth). Small
fluctuations (≤ threshold) trigger network-only rescheduling; large ones
trigger full replanning; missed beats mark a device failed and start
consensus-style recovery (deterministic re-election: lowest healthy id).

This module is transport-agnostic (the simulator drives it with a
virtual clock; a real deployment would pump it from RPC callbacks).
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Dict, List, Optional

from ..core.adapter import DynamicsEvent


@dataclasses.dataclass
class DeviceStatus:
    device_id: int
    last_beat: float = 0.0
    speed: float = 1.0          # compute factor (1.0 = nominal)
    bandwidth: float = 1.0      # network factor
    alive: bool = True


class Coordinator:
    def __init__(self, device_ids: List[int], *, beat_interval: float = 1.0,
                 miss_limit: int = 3, fluctuation_threshold: float = 0.10,
                 on_reschedule: Optional[Callable[[DynamicsEvent], None]] = None,
                 on_replan: Optional[Callable[[DynamicsEvent], None]] = None,
                 on_failure: Optional[Callable[[List[int]], None]] = None):
        self.devices = {d: DeviceStatus(d) for d in device_ids}
        self.beat_interval = beat_interval
        self.miss_limit = miss_limit
        self.threshold = fluctuation_threshold
        self.on_reschedule = on_reschedule
        self.on_replan = on_replan
        self.on_failure = on_failure
        self.coordinator_id = min(device_ids)
        self.log: List[str] = []

    # -- election -----------------------------------------------------------------
    def _elect(self, t: float) -> None:
        """Maintain the docstring's invariant: the coordinator is always
        the lowest *healthy* id (a revived lower id reclaims the role; a
        dead coordinator is replaced even when every device failed in
        the same tick and one later returns)."""
        healthy = [d for d, s in self.devices.items() if s.alive]
        if healthy and self.coordinator_id != min(healthy):
            self.coordinator_id = min(healthy)
            self.log.append(f"t={t:.1f} coordinator -> {self.coordinator_id}")

    def _notify_failure(self, failed: List[int]) -> None:
        """Call ``on_failure`` with the new coordinator exposed: two-arg
        callbacks receive ``(failed, coordinator_id)``; legacy one-arg
        callbacks (e.g. ``ElasticController._on_failure``) keep working.
        """
        if self.on_failure is None:
            return
        try:
            n_params = len(inspect.signature(self.on_failure).parameters)
        except (TypeError, ValueError):
            n_params = 1
        if n_params >= 2:
            self.on_failure(failed, self.coordinator_id)
        else:
            self.on_failure(failed)

    # -- heartbeat ingestion ------------------------------------------------------
    def beat(self, device_id: int, t: float, *, speed: float = 1.0,
             bandwidth: float = 1.0) -> None:
        st = self.devices[device_id]
        prev_speed, prev_bw = st.speed, st.bandwidth
        revived = not st.alive
        st.last_beat, st.speed, st.bandwidth, st.alive = t, speed, bandwidth, True
        if revived:
            self._elect(t)
        mag = max(abs(speed - prev_speed), abs(bandwidth - prev_bw))
        if mag == 0.0:
            return
        ev = DynamicsEvent(t=t, compute_speed={device_id: speed},
                           bandwidth_scale={"*": bandwidth})
        if mag <= self.threshold:
            self.log.append(f"t={t:.1f} dev{device_id} fluctuation {mag:.2f} -> reschedule")
            if self.on_reschedule:
                self.on_reschedule(ev)
        else:
            self.log.append(f"t={t:.1f} dev{device_id} shift {mag:.2f} -> replan")
            if self.on_replan:
                self.on_replan(ev)

    # -- failure detection ----------------------------------------------------------
    def tick(self, t: float) -> List[int]:
        """Advance the detector; returns newly-failed device ids."""
        failed = []
        for st in self.devices.values():
            if st.alive and t - st.last_beat > self.miss_limit * self.beat_interval:
                st.alive = False
                failed.append(st.device_id)
        if failed:
            self.log.append(f"t={t:.1f} failed={failed}")
            self._elect(t)                        # re-election before notify
            self._notify_failure(failed)
        return failed

    @property
    def healthy(self) -> List[int]:
        return sorted(d for d, s in self.devices.items() if s.alive)
