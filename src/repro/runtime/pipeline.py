"""Dora-plan-driven pipeline-parallel executor (shard_map).

Bridges the planner and the JAX runtime: a ``ParallelismPlan`` with S
pipeline stages maps onto a mesh axis ``"stage"``; activations move
between stages with ``jax.lax.ppermute`` (the jax-native analogue of the
paper's PiPPy send/recv), microbatches stream GPipe-style via
``lax.scan``. Gradients flow back through the transposed ppermute, so
``jax.grad`` of the pipelined forward gives pipeline-parallel training
without bespoke backward scheduling; per-stage remat keeps memory flat.

Stage imbalance follows the plan: each stage executes ``layers_per_stage``
layers of the stacked parameter tree (padded to the max so the shard_map
body is uniform — idle layers are zero-cost identity slots).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map                    # jax >= 0.8
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

from ..core.plans import ParallelismPlan


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    """Executable stage layout derived from a Dora plan."""

    n_stages: int
    layers_per_stage: Tuple[int, ...]     # true layer counts (≤ pad)
    pad: int                              # max layers on any stage
    n_microbatches: int

    @classmethod
    def from_plan(cls, plan: ParallelismPlan, n_layers: int) -> "PipelineSpec":
        total_nodes = sum(len(s.node_ids) for s in plan.stages)
        counts = []
        acc = 0
        for s in plan.stages:
            share = round(n_layers * len(s.node_ids) / total_nodes)
            counts.append(max(1, share))
            acc += counts[-1]
        counts[-1] += n_layers - sum(counts)        # fix rounding drift
        counts[-1] = max(1, counts[-1])
        return cls(n_stages=len(plan.stages), layers_per_stage=tuple(counts),
                   pad=max(counts), n_microbatches=plan.n_microbatches)


def _pad_stage_params(stacked: Any, spec: PipelineSpec) -> Any:
    """(L, ...) stacked layer params → (S, pad, ...), zero-padded."""
    bounds = np.cumsum((0,) + spec.layers_per_stage)

    def fn(x):
        out = np.zeros((spec.n_stages, spec.pad) + x.shape[1:], dtype=x.dtype)
        for s in range(spec.n_stages):
            lo, hi = bounds[s], bounds[s + 1]
            out[s, : hi - lo] = np.asarray(x[lo:hi])
        return jnp.asarray(out)
    return jax.tree.map(fn, stacked)


class DoraPipelineExecutor:
    """GPipe-over-shard_map executor for one decoder-style layer stack.

    ``layer_fn(layer_params, x) -> x`` is a single layer's forward.
    Parameters arrive stacked (L, ...); they are re-packed per stage.
    """

    def __init__(self, plan: ParallelismPlan, n_layers: int, mesh,
                 layer_fn: Callable[[Any, jnp.ndarray], jnp.ndarray]):
        if "stage" not in mesh.axis_names:
            raise ValueError("pipeline mesh needs a 'stage' axis")
        self.spec = PipelineSpec.from_plan(plan, n_layers)
        self.mesh = mesh
        self.layer_fn = layer_fn
        n_stage_devices = dict(zip(mesh.axis_names, mesh.devices.shape))["stage"]
        if n_stage_devices != self.spec.n_stages:
            raise ValueError(f"plan has {self.spec.n_stages} stages but mesh "
                             f"'stage' axis is {n_stage_devices}")

    # -- parameter packing ------------------------------------------------------
    def pack_params(self, stacked_params: Any) -> Any:
        return _pad_stage_params(stacked_params, self.spec)

    # -- forward -------------------------------------------------------------------
    def forward(self, stage_params: Any, x: jnp.ndarray) -> jnp.ndarray:
        """x: (M, mb, ...) microbatched input (already embedded). Returns
        the pipeline output in the same layout (valid on the last stage,
        broadcast back to all)."""
        spec = self.spec
        S, M = spec.n_stages, spec.n_microbatches
        n_valid = jnp.asarray(spec.layers_per_stage)

        # jax ≥0.7 calls the replication check ``check_vma``; older jax
        # calls it ``check_rep`` — disable whichever this jax has.
        import inspect
        check_kw = ("check_vma" if "check_vma"
                    in inspect.signature(shard_map).parameters
                    else "check_rep")

        @functools.partial(
            shard_map, mesh=self.mesh,
            in_specs=(P("stage"), P(None)),
            out_specs=P(None),
            **{check_kw: False})
        def run(params, xs):
            params = jax.tree.map(lambda a: a[0], params)   # local stage block
            stage_id = jax.lax.axis_index("stage")

            def stage_fn(x):
                def body(carry, lp_idx):
                    lp, idx = lp_idx
                    y = self.layer_fn(lp, carry)
                    keep = idx < n_valid[stage_id]          # padded slots = identity
                    return jnp.where(keep, y, carry), None
                idxs = jnp.arange(spec.pad)
                out, _ = jax.lax.scan(body, x, (params, idxs))
                return out

            stage_fn = jax.remat(stage_fn)
            buf = jnp.zeros_like(xs[0])
            outs = jnp.zeros_like(xs)
            perm = [(i, i + 1) for i in range(S - 1)]

            def tick(carry, t):
                buf, outs = carry
                # stage 0 injects microbatch t; others take the permuted input
                inject = jnp.where(t < M, t, M - 1)
                x_in = jnp.where(stage_id == 0, xs[inject], buf)
                y = stage_fn(x_in)
                # collect finished microbatches from the last stage
                done_idx = t - (S - 1)
                take = jnp.logical_and(stage_id == S - 1,
                                       jnp.logical_and(done_idx >= 0, done_idx < M))
                outs = jax.lax.cond(
                    take,
                    lambda o: jax.lax.dynamic_update_index_in_dim(
                        o, y, jnp.maximum(done_idx, 0), 0),
                    lambda o: o, outs)
                buf = jax.lax.ppermute(y, "stage", perm)
                return (buf, outs), None

            (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(M + S - 1))
            # broadcast final outputs from the last stage to every stage
            outs = jnp.where(stage_id == S - 1, outs, jnp.zeros_like(outs))
            return jax.lax.psum(outs, "stage")

        return run(stage_params, x)

    def loss(self, stage_params: Any, x: jnp.ndarray,
             loss_fn: Callable[[jnp.ndarray], jnp.ndarray]) -> jnp.ndarray:
        out = self.forward(stage_params, x)
        return loss_fn(out)
