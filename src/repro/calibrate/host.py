"""Host-CPU fleet twin: measured DeviceProfiles, Topology and costs.

The fidelity loop (and ``dora.calibrate``) plans over a *host fleet*: N
``host<i>`` devices backed by jax's forced-host-platform devices.  Each
device's "datasheet" claims exactly what a naive single-stream
microbenchmark would claim — sustained matmul FLOP/s, memcpy bytes/s —
priced through the library-default ``compute_efficiency`` MFU guess.
That claim is systematically wrong on a time-shared host: N forced
devices serialize on the physical cores, so a pipeline stage really
runs at the *contended* rate, roughly ``1/N`` of single-stream.
:func:`host_costs` converts that measured gap into a
:class:`~repro.core.profiler.ProfiledCosts` via ``from_measurements``
— the same sim-to-real correction a real edge fleet would derive from
on-device step timings.

Everything imports jax lazily; the module is safe to import from the
jax-free ``repro.dora`` facade.
"""
from __future__ import annotations

import datetime
from typing import Dict, Mapping, Optional, Tuple

from ..core.device import DeviceProfile, LinkResource, Topology
from ..core.profiler import ProfiledCosts
from .microbench import measure_host
from .timing import MeasurementCache, backend_key

#: Default accelerator-visible memory per host device.  Deliberately
#: small enough that realistic proxy models need several pipeline
#: stages; the fidelity loop overrides it per case.
HOST_MEMORY = 4e9

#: Shared-medium resource name of the host fleet (device_put transfers
#: between forced host devices all ride the same memory system).
HOSTMEM = "hostmem"


def host_device(measure: Mapping[str, float], index: int = 0, *,
                memory: float = HOST_MEMORY) -> DeviceProfile:
    """One ``host<i>`` DeviceProfile from host measurements.

    ``flops`` is the measured single-stream matmul peak — the number a
    datasheet (or a naive benchmark) would claim — and
    ``compute_efficiency`` stays the library default, so *uncalibrated*
    planning over a host fleet mispredicts exactly the way datasheet
    planning over a real fleet does.  ``ProfiledCosts`` then closes the
    gap from measurements.
    """
    return DeviceProfile(
        name=f"host{index}",
        flops=float(measure["matmul_peak_flops"]),
        memory=memory,
        mem_bw=float(measure["memory_bw"]),
        e_flop=1e-11, e_byte=1e-9, p_idle=5.0)


def host_topology(measure: Mapping[str, float], n: int, *,
                  memory: float = HOST_MEMORY) -> Topology:
    """``n`` host devices on one shared ``hostmem`` medium.

    The medium's claimed capacity is the measured single-stream memory
    bandwidth (the honest "datasheet" for an in-memory link); its
    per-message latency is derived from the small-vs-large transfer
    goodput gap when both were measured.
    """
    devs = [host_device(measure, i, memory=memory) for i in range(n)]
    latency = 1e-4
    small = measure.get("transfer_small_bps")
    large = measure.get("transfer_large_bps")
    if small and large and small > 0.0 and large > 0.0:
        latency = max((1 << 16) / small - (1 << 16) / large, 1e-5)
    res = LinkResource(HOSTMEM, capacity=float(measure["memory_bw"]),
                       members=frozenset(range(n)), shared=True,
                       latency=latency)
    return Topology(devs, [res])


def host_costs(measure: Mapping[str, float], n: int, *,
               contended: Optional[float] = None,
               name: str = "profiled-host",
               provenance: Optional[Mapping[str, str]] = None
               ) -> ProfiledCosts:
    """Measured :class:`ProfiledCosts` for an ``n``-device host fleet.

    Compute factors come from measured-vs-analytic *step seconds* of the
    contended stage block: the analytic time prices the block at the
    datasheet effective rate (matmul peak × default MFU), the measured
    time is what the block actually took per device under ``n``-way
    concurrent load (``contended`` overrides the cached default
    measurement, e.g. with a geometry-matched
    :func:`~repro.calibrate.microbench.contended_mlp_rate`).  The
    ``hostmem`` bandwidth factor is measured transfer goodput over the
    claimed memory-bandwidth capacity.
    """
    claimed = host_device(measure).effective_flops()
    achieved = contended
    if achieved is None:
        achieved = measure.get("contended_mlp_flops") \
            or measure.get("contended_flops") \
            or float(measure["matmul_peak_flops"])
    # (analytic, measured) seconds per FLOP of the calibration block:
    # from_measurements turns the pair into achieved/claimed per device.
    device_seconds = {f"host{i}": (1.0 / claimed, 1.0 / float(achieved))
                      for i in range(n)}
    links: Dict[str, Tuple[float, float]] = {}
    transfer = measure.get("transfer_large_bps")
    if transfer:
        links[HOSTMEM] = (float(measure["memory_bw"]), float(transfer))
    pc = ProfiledCosts.from_measurements(device_seconds=device_seconds,
                                         link_bytes_per_s=links)
    prov = {
        "backend": backend_key(),
        "date": datetime.date.today().isoformat(),
        "source": "repro.calibrate host microbenchmarks "
                  "(matmul peak, memcpy, contended stage block, "
                  "device_put goodput)",
        "claimed_effective_flops": f"{claimed:.4g}",
        "achieved_contended_flops": f"{float(achieved):.4g}",
        **dict(provenance or {}),
    }
    import dataclasses
    return dataclasses.replace(pc, name=name, provenance=prov)


def calibrate_host(cache: Optional[MeasurementCache] = None, *,
                   quick: bool = False,
                   archs=("qwen3_32b", "mamba2_780m"),
                   path: Optional[str] = None) -> ProfiledCosts:
    """Measure this host and build its ProfiledCosts artifact.

    Runs (or recalls from ``cache``) the microbenchmark suite —
    including the timed zoo train/decode steps, whose measured-vs-
    analytic ratios land in the provenance — and returns the
    :class:`ProfiledCosts` for the current forced-host fleet.  With
    ``path``, the artifact is also written as committable JSON,
    loadable later via ``dora.plan(..., costs="profiled:<path>")``.
    """
    import jax

    from .microbench import step_analytic_seconds

    cache = cache if cache is not None else MeasurementCache()
    measure = measure_host(cache, archs=archs, quick=quick)
    n = jax.device_count()
    prov: Dict[str, str] = {}
    dev = host_device(measure)
    for arch in archs:
        for mode in ("train", "decode"):
            measured = measure.get(f"step/{arch}/{mode}_s")
            if not measured:
                continue
            analytic = step_analytic_seconds(arch, mode, dev)
            prov[f"step_ratio/{arch}/{mode}"] = f"{analytic / measured:.4g}"
    costs = host_costs(measure, n, provenance=prov)
    if path is not None:
        costs.to_json(path)
    return costs
