"""Microbenchmarks of the real compute/transfer paths on the live backend.

Four families, all routed through :class:`repro.calibrate.timing`:

* ``matmul_peak``   — sustained large-matmul FLOP/s of one device (the
  "datasheet" number the uncalibrated host profile claims);
* ``kernel rates``  — the four ``repro.kernels`` entry points (flash /
  decode attention, SSD scan, RG-LRU scan) timed against their analytic
  FLOP counts from ``repro.kernels.flops``;
* ``step rates``    — jitted train / prefill / decode steps from
  ``launch/steps.py`` on REDUCED zoo configs, timed whole;
* ``transfers``     — payload goodput between two local devices
  (``jax.device_put``), large (streaming capacity) and small
  (per-message overhead), plus the *contended* per-device compute rate
  when every local device runs the same block concurrently — on a host
  whose logical devices time-share physical cores this is the number
  that actually governs pipeline execution speed.

Everything returns plain floats so the results drop straight into the
measurement cache and the calibration artifact.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

from .timing import MeasurementCache, time_callable


# -- single-device compute ------------------------------------------------------
def matmul_peak_flops(dim: int = 1024, *, repeats: int = 5) -> float:
    """Achieved FLOP/s of a jitted f32 ``dim×dim`` matmul chain."""
    import jax
    import jax.numpy as jnp

    x = jax.random.normal(jax.random.PRNGKey(0), (dim, dim), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (dim, dim), jnp.float32)
    chain = 4                                   # amortize dispatch

    @jax.jit
    def run(x, w):
        for _ in range(chain):
            x = x @ w
        return x

    sec = time_callable(lambda: run(x, w), repeats=repeats)
    return chain * 2.0 * dim ** 3 / sec


def memory_bandwidth(nbytes: int = 1 << 26, *, repeats: int = 5) -> float:
    """Achieved bytes/s of a jitted device-memory copy (read + write)."""
    import jax
    import jax.numpy as jnp

    n = nbytes // 4
    x = jax.numpy.zeros((n,), jnp.float32)
    run = jax.jit(lambda x: x + 1.0)
    sec = time_callable(lambda: run(x), repeats=repeats)
    return 2.0 * nbytes / sec


# -- kernel rates ---------------------------------------------------------------
def kernel_rates(*, repeats: int = 3) -> Dict[str, float]:
    """Achieved FLOP/s of each ``repro.kernels`` entry point on the live
    backend (CPU runs the same dispatch path production CPU serving
    uses).  Shapes are the mid-size cases of ``tests/test_kernels.py``."""
    import jax
    import jax.numpy as jnp

    from ..kernels import (decode_attention, flash_attention, rglru_scan,
                           ssd_scan)
    from ..kernels import flops as kf

    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    out: Dict[str, float] = {}

    B, S, H, KV, d = 1, 256, 4, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, d), jnp.float32)
    fa = jax.jit(functools.partial(flash_attention, causal=True))
    sec = time_callable(lambda: fa(q, k, v), repeats=repeats)
    out["flash_attention"] = kf.flash_attention_flops(B, S, H, KV, d) / sec

    T = 4096
    qd = jax.random.normal(ks[3], (B, 1, H, d), jnp.float32)
    kc = jax.random.normal(ks[4], (B, T, KV, d), jnp.float32)
    vc = jax.random.normal(ks[5], (B, T, KV, d), jnp.float32)
    clen = jnp.full((B,), T, jnp.int32)
    da = jax.jit(decode_attention)
    sec = time_callable(lambda: da(qd, kc, vc, clen), repeats=repeats)
    out["decode_attention"] = kf.decode_attention_flops(B, T, H, d) / sec

    Bs, Ss, Hs, P, G, N = 1, 256, 4, 64, 1, 64
    xs = jax.random.normal(ks[6], (Bs, Ss, Hs, P), jnp.float32) * 0.1
    a = -jnp.abs(jax.random.normal(ks[7], (Bs, Ss, Hs), jnp.float32)) * 0.1
    b = jax.random.normal(ks[0], (Bs, Ss, G, N), jnp.float32) * 0.1
    c = jax.random.normal(ks[1], (Bs, Ss, G, N), jnp.float32) * 0.1
    ss = jax.jit(functools.partial(ssd_scan, chunk=128))
    sec = time_callable(lambda: ss(xs, a, b, c), repeats=repeats)
    out["ssd_scan"] = kf.ssd_scan_flops(Bs, Ss, Hs, P, G, N) / sec

    W = 512
    al = -jnp.abs(jax.random.normal(ks[2], (Bs, Ss, W), jnp.float32)) * 0.3
    bb = jax.random.normal(ks[3], (Bs, Ss, W), jnp.float32) * 0.1
    rg = jax.jit(rglru_scan)
    sec = time_callable(lambda: rg(al, bb), repeats=repeats)
    out["rglru_scan"] = kf.rglru_scan_flops(Bs, Ss, W) / sec
    return out


# -- whole-step rates -----------------------------------------------------------
def step_seconds(arch: str, mode: str = "train", *, batch: int = 2,
                 seq: int = 32, repeats: int = 3) -> float:
    """Wall seconds of one jitted REDUCED-config step on one device.

    ``mode`` is ``"train"`` (full fwd+bwd+AdamW) or ``"decode"`` (one
    cached serving token).  This times the *production step functions*
    from ``launch/steps.py`` — the same closures the dry-run lowers —
    with real (not ShapeDtypeStruct) inputs.
    """
    import jax
    import jax.numpy as jnp

    from ..configs import reduced_config
    from ..launch.steps import make_serve_step, make_train_step

    cfg = reduced_config(arch)
    rng = jax.random.PRNGKey(0)
    if mode == "train":
        model, train_step = make_train_step(cfg, remat="none")
        params = model.init(rng)
        from ..optim import adamw_init
        opt = adamw_init(params)
        toks = jax.random.randint(rng, (batch, seq + 1), 0, cfg.vocab_size)
        b = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.encdec:
            b["encoder_frames"] = jax.random.normal(
                rng, (batch, cfg.enc_seq, cfg.d_model), jnp.float32) * 0.02
        if cfg.vision_stub:
            b["extra_embeddings"] = jax.random.normal(
                rng, (batch, cfg.n_patches, cfg.d_model), jnp.float32) * 0.02
        step = jax.jit(train_step)
        zero = jnp.zeros((), jnp.int32)
        return time_callable(lambda: step(params, opt, b, zero),
                             repeats=repeats)
    if mode != "decode":
        raise ValueError(f"unknown step mode {mode!r}")
    model, serve_step = make_serve_step(cfg)
    params = model.init(rng)
    cache = model.init_cache(batch, seq)
    tok = jnp.zeros((batch, 1), jnp.int32)
    pos = jnp.zeros((batch,), jnp.int32)
    step = jax.jit(serve_step)
    return time_callable(lambda: step(params, tok, cache, pos),
                         repeats=repeats)


def step_analytic_seconds(arch: str, mode: str, device, *, batch: int = 2,
                          seq: int = 32) -> float:
    """Roofline prediction for the same step on ``device`` (a
    ``DeviceProfile``): planning-graph FLOPs at the step's geometry over
    the device's effective rate — the number the planner would use."""
    from ..configs import reduced_config
    from ..models.registry import planning_graph

    cfg = reduced_config(arch)
    g = planning_graph(cfg, seq if mode == "train" else 1)
    fwd = sum(n.flops_fwd for n in g.nodes) * batch
    flops = 3.0 * fwd if mode == "train" else fwd
    return flops / device.effective_flops()


# -- multi-device: transfers + contended compute --------------------------------
def transfer_goodput(nbytes: int, *, repeats: int = 5) -> float:
    """bytes/s of a ``jax.device_put`` of ``nbytes`` between the first
    two local devices (needs ≥2 devices; ValueError otherwise)."""
    import jax
    import jax.numpy as jnp

    devs = jax.devices()
    if len(devs) < 2:
        raise ValueError("transfer benchmark needs >= 2 devices")
    x = jax.device_put(jnp.zeros((max(nbytes // 4, 1),), jnp.float32),
                       devs[0])
    sec = time_callable(lambda: jax.device_put(x, devs[1]), repeats=repeats)
    return nbytes / sec


def contended_rate(n_devices: Optional[int] = None, *, dim: int = 512,
                   layers: int = 8, repeats: int = 3) -> float:
    """Per-device FLOP/s when ``n_devices`` devices run an identical
    MLP-style block stack *concurrently* (pmap).

    On real edge fleets every device computes its pipeline stage at the
    same time; on the forced-host-platform fleet the logical devices
    time-share the physical cores, so the concurrent rate — not the
    single-stream peak — is what a pipeline stage actually gets.  This
    single measurement is the heart of the sim-to-real compute factor.
    """
    import jax
    import jax.numpy as jnp

    n = n_devices or jax.device_count()
    n = min(n, jax.device_count())
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    w = jax.random.normal(k1, (n, layers, dim, dim), jnp.float32) * 0.1
    x = jax.random.normal(k2, (n, 16, dim), jnp.float32)

    @functools.partial(jax.pmap, axis_name="bench",
                       devices=jax.devices()[:n])
    def run(w, x):
        def body(carry, wi):
            return jnp.tanh(carry @ wi), None
        out, _ = jax.lax.scan(body, x, w)
        return out

    sec = time_callable(lambda: run(w, x), repeats=repeats)
    flops_per_dev = 2.0 * layers * 16 * dim * dim
    return flops_per_dev / sec


def gated_mlp_layer(lp, x):
    """The fidelity proxy layer: a silu-gated MLP block — 3 matmuls,
    ``6 · rows · d_model · d_ff`` FLOPs per call.  This is the exact
    ``layer_fn`` :mod:`repro.calibrate.fidelity` hands the pipeline
    executor, so timing it under contention calibrates precisely the
    compute path the executed plan runs."""
    import jax

    h = jax.nn.silu(x @ lp["wg"]) * (x @ lp["wu"])
    return h @ lp["wd"]


def init_gated_mlp(n_layers: int, d_model: int, d_ff: int, seed: int = 0):
    """Stacked (L, ...) parameters for :func:`gated_mlp_layer`, scaled
    so activations neither explode nor vanish across the stack."""
    import jax
    import jax.numpy as jnp

    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    si, so = d_model ** -0.5, 1.8 * d_ff ** -0.5
    return {
        "wg": jax.random.normal(k[0], (n_layers, d_model, d_ff),
                                jnp.float32) * si,
        "wu": jax.random.normal(k[1], (n_layers, d_model, d_ff),
                                jnp.float32) * si,
        "wd": jax.random.normal(k[2], (n_layers, d_ff, d_model),
                                jnp.float32) * so,
    }


def contended_mlp_rate(n_devices: Optional[int] = None, *, rows: int = 16,
                       d_model: int = 512, d_ff: int = 2048,
                       layers: int = 4, iters: int = 12,
                       training: bool = False,
                       repeats: int = 3) -> float:
    """Per-device FLOP/s of the gated-MLP proxy stage under ``n``-way
    concurrent load (pmap) — :func:`contended_rate` specialized to the
    fidelity loop's actual stage body (same op mix, same scan-over-
    layers structure), so the calibrated factor absorbs both the
    device-concurrency slowdown and the op-mix efficiency gap.

    The stage block repeats ``iters`` times *inside* the jitted call —
    the executor runs its M+S−1 pipeline ticks inside one jitted scan,
    so per-call dispatch overhead must be amortized identically or the
    measured rate underestimates what a pipeline stage actually gets.

    With ``training=True`` the timed block is ``value_and_grad`` of the
    remat'd stage stack — 4× the forward FLOPs (forward + remat
    recompute + grad-x + grad-w), exactly the per-stage work mix of a
    pipelined training step — because backward matmul shapes run at a
    different rate than forward ones and the planner prices both
    through one per-device factor.
    """
    import jax
    import jax.numpy as jnp

    n = min(n_devices or jax.device_count(), jax.device_count())
    lp = init_gated_mlp(layers, d_model, d_ff)
    lp = jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), lp)
    x = jax.random.normal(jax.random.PRNGKey(1), (n, rows, d_model),
                          jnp.float32)

    perm = [(i, (i + 1) % n) for i in range(n)]

    def stack(lp, x):
        def stage(carry, _):
            def block(c):
                def body(c, lpi):
                    return gated_mlp_layer(lpi, c), None
                out, _ = jax.lax.scan(body, c, lp)
                return out
            out = jax.remat(block)(carry)
            # the executor's tick ends with an inter-stage ppermute
            # handoff; include it so its per-tick overhead lands in the
            # measured rate rather than in the fidelity error
            if n > 1:
                out = jax.lax.ppermute(out, "bench", perm)
            return out, None
        out, _ = jax.lax.scan(stage, x, None, length=iters)
        return out

    if training:
        def target(lp, x):
            return jnp.mean(stack(lp, x) ** 2)
        run = jax.pmap(jax.value_and_grad(target), axis_name="bench",
                       devices=jax.devices()[:n])
        work = 4.0                       # fwd + remat recompute + 2x grad
    else:
        run = jax.pmap(stack, axis_name="bench",
                       devices=jax.devices()[:n])
        work = 1.0

    sec = time_callable(lambda: run(lp, x), repeats=repeats)
    return work * 6.0 * rows * d_model * d_ff * layers * iters / sec


# -- cached driver ---------------------------------------------------------------
def measure_host(cache: Optional[MeasurementCache] = None, *,
                 archs=("qwen3_32b", "mamba2_780m"),
                 quick: bool = False) -> Dict[str, float]:
    """Run (or recall) the host microbenchmark suite → flat dict.

    Keys: ``matmul_peak_flops``, ``memory_bw``, ``kernel/<name>_flops``,
    ``step/<arch>/<mode>_s``, and — when >1 device is live —
    ``transfer_large_bps``, ``transfer_small_bps``, ``contended_flops``.
    """
    import jax

    cache = cache if cache is not None else MeasurementCache()
    rep = 2 if quick else 5
    dim = 512 if quick else 1024
    out: Dict[str, float] = {}
    out["matmul_peak_flops"] = cache.get_or_measure(
        "matmul_peak", f"d{dim}",
        lambda: matmul_peak_flops(dim, repeats=rep))
    out["memory_bw"] = cache.get_or_measure(
        "memory_bw", "64MiB", lambda: memory_bandwidth(repeats=rep))
    if not quick:
        names = ("flash_attention", "decode_attention", "ssd_scan",
                 "rglru_scan")
        cached = {n: cache.lookup(f"kernel_{n}", "default") for n in names}
        if any(v is None for v in cached.values()):
            cached = kernel_rates(repeats=3)
            for n in names:
                cache.put(f"kernel_{n}", "default", cached[n])
        for n in names:
            out[f"kernel/{n}_flops"] = cached[n]
    for arch in archs:
        for mode in ("train", "decode"):
            out[f"step/{arch}/{mode}_s"] = cache.get_or_measure(
                f"step_{mode}", f"{arch}/b2s32",
                lambda a=arch, m=mode: step_seconds(a, m, repeats=rep))
    if jax.device_count() > 1:
        out["transfer_large_bps"] = cache.get_or_measure(
            "transfer", "16MiB",
            lambda: transfer_goodput(1 << 24, repeats=rep))
        out["transfer_small_bps"] = cache.get_or_measure(
            "transfer", "64KiB",
            lambda: transfer_goodput(1 << 16, repeats=rep))
        out["contended_flops"] = cache.get_or_measure(
            "contended", f"n{jax.device_count()}/d512x8",
            lambda: contended_rate(repeats=rep))
        out["contended_mlp_flops"] = cache.get_or_measure(
            "contended_mlp", f"n{jax.device_count()}/r16/d512x2048/l4",
            lambda: contended_mlp_rate(repeats=rep))
    return out
