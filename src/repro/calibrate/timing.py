"""Timing primitives for the calibration microbenchmarks.

Everything here is deliberately boring: warmup the jitted callable so
compilation never lands in the timed region, ``block_until_ready`` the
outputs so async dispatch doesn't lie, take best-of-N so scheduler noise
on a shared host biases upward only, and cache the resulting seconds in
a JSON file keyed by (bench, shape, backend, jax version) so repeated
calibration runs are cheap.

This module must stay importable without initializing jax — jax is
imported lazily inside the functions so ``repro.dora`` (which is
jax-free by contract) can pull in ``repro.calibrate`` safely.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Optional


def ensure_host_devices(n: int) -> None:
    """Ask XLA for ``n`` forced host-platform devices — *before* jax
    initializes, and without clobbering flags the user already set.

    If ``XLA_FLAGS`` already mentions ``--xla_force_host_platform_
    device_count`` the user's choice wins; otherwise the flag is
    appended to whatever is there.  No-op after jax has initialized
    (the device count is locked on first use).
    """
    flag = "--xla_force_host_platform_device_count"
    existing = os.environ.get("XLA_FLAGS", "")
    if flag in existing:
        return
    os.environ["XLA_FLAGS"] = f"{existing} {flag}={n}".strip()


def block(tree):
    """``jax.block_until_ready`` on an arbitrary pytree, returned."""
    import jax
    return jax.block_until_ready(tree)


def time_callable(fn: Callable[[], object], *, warmup: int = 2,
                  repeats: int = 5) -> float:
    """Best-of-``repeats`` wall seconds of ``fn`` (outputs blocked).

    ``fn`` must be self-contained (arguments already closed over and
    device-resident).  The first ``warmup`` calls absorb compilation and
    first-touch page faults and are discarded.
    """
    for _ in range(max(warmup, 0)):
        block(fn())
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        block(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def backend_key() -> str:
    """``<backend>/<n_devices>/jax-<version>`` — the environment part of
    every cache key (a measurement from another backend or device count
    must never be reused)."""
    import jax
    return f"{jax.default_backend()}/{jax.device_count()}/jax-{jax.__version__}"


DEFAULT_CACHE = os.path.join(
    os.path.expanduser("~"), ".cache", "repro-calibrate", "measurements.json")


class MeasurementCache:
    """JSON-backed memo of microbenchmark measurements.

    Keys are ``"<bench>|<shape>|<backend_key>"`` — bench name, a
    canonical shape string (the *arch/shape* part), and the environment
    from :func:`backend_key`.  Values are plain floats (seconds or
    bytes/s).  The file is rewritten atomically after every new
    measurement; corrupt/missing files degrade to an empty cache.

    Pass ``path=None`` for a purely in-memory cache (tests, CI runs that
    must re-measure on their own hardware).
    """

    def __init__(self, path: Optional[str] = DEFAULT_CACHE):
        self.path = path
        self._data: Dict[str, float] = {}
        self.hits = 0
        self.misses = 0
        if path and os.path.exists(path):
            try:
                with open(path, encoding="utf-8") as f:
                    loaded = json.load(f)
                if isinstance(loaded, dict):
                    self._data = {str(k): float(v) for k, v in loaded.items()
                                  if isinstance(v, (int, float))}
            except (OSError, ValueError):
                self._data = {}

    @staticmethod
    def key(bench: str, shape: str, env: Optional[str] = None) -> str:
        return f"{bench}|{shape}|{env if env is not None else backend_key()}"

    def lookup(self, bench: str, shape: str) -> Optional[float]:
        """Cached value for (bench, shape, backend), or ``None``."""
        return self._data.get(self.key(bench, shape))

    def put(self, bench: str, shape: str, value: float) -> float:
        self._data[self.key(bench, shape)] = float(value)
        self._flush()
        return float(value)

    def get_or_measure(self, bench: str, shape: str,
                       measure: Callable[[], float]) -> float:
        """Cached value for (bench, shape, backend) or run ``measure``."""
        val = self.lookup(bench, shape)
        if val is not None:
            self.hits += 1
            return val
        self.misses += 1
        return self.put(bench, shape, measure())

    def _flush(self) -> None:
        if not self.path:
            return
        try:
            os.makedirs(os.path.dirname(self.path), exist_ok=True)
            tmp = self.path + ".tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(self._data, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            pass                    # cache is best-effort, never fatal

    def __len__(self) -> int:
        return len(self._data)
