"""``python -m repro.calibrate`` — measure, calibrate, check fidelity.

Default run: microbenchmark the host, write the ProfiledCosts artifact,
run the fidelity suite (plan → price both ways → execute → compare) and
rewrite ``BENCH_fidelity.json``.  ``--check`` is the CI gate: re-run the
quick subset with the cache off and fail on calibrated-error regression.
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.calibrate",
        description="host calibration + plan-vs-reality fidelity bench")
    ap.add_argument("--quick", action="store_true",
                    help="small fidelity cases only (also via BENCH_QUICK=1)")
    ap.add_argument("--check", action="store_true",
                    help="CI regression gate on the quick subset "
                         "(implies --quick, ignores the measurement cache)")
    ap.add_argument("--devices", type=int, default=4,
                    help="forced host device count if jax is uninitialized "
                         "and XLA_FLAGS doesn't already set one (default 4)")
    ap.add_argument("--artifact", default=None, metavar="PATH",
                    help="also write the ProfiledCosts JSON here "
                         "(e.g. calibration/host_cpu.json)")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="measurement cache file ('none' disables; default "
                         "~/.cache/repro-calibrate/measurements.json)")
    args = ap.parse_args(argv)
    quick = args.quick or bool(os.environ.get("BENCH_QUICK"))

    # must happen before anything imports jax
    from .timing import MeasurementCache, ensure_host_devices
    ensure_host_devices(args.devices)

    if args.cache == "none":
        cache = MeasurementCache(path=None)
    elif args.cache:
        cache = MeasurementCache(path=args.cache)
    else:
        cache = MeasurementCache(path=None) if args.check \
            else MeasurementCache()

    from . import fidelity
    if args.check:
        return fidelity.check_regression()

    from .host import calibrate_host
    costs = calibrate_host(cache, quick=quick, path=args.artifact)
    print(f"calibrated {costs.name}: "
          f"compute_factor={next(iter(costs.compute_factor.values())):.4f} "
          f"({len(costs.compute_factor)} devices)")
    if args.artifact:
        print(f"wrote {args.artifact}")

    current = fidelity.run_fidelity(quick=quick, cache=cache)
    if quick:
        fidelity.write_quick(current)
    else:
        fidelity.write_bench(current)
    for name, rec in current["cases"].items():
        print(f"  {name} ({rec['mode']}, S={rec['n_stages']}): "
              f"measured={rec['measured_s']*1e3:.1f}ms  "
              f"calibrated={rec['calibrated']['predicted_s']*1e3:.1f}ms "
              f"(err {rec['calibrated']['rel_err']:.1%})  "
              f"uncalibrated={rec['uncalibrated']['predicted_s']*1e3:.1f}ms "
              f"(err {rec['uncalibrated']['rel_err']:.1%})")
    print(f"mean rel err: calibrated "
          f"{current['mean_rel_err_calibrated']:.3f} vs uncalibrated "
          f"{current['mean_rel_err_uncalibrated']:.3f} "
          f"(gain {current['calibration_gain']:.1f}x)")
    print(f"wrote {fidelity.BENCH_PATH}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
