"""Plan→execute→compare: how well do planner latencies match reality?

For each fidelity case (a host-fleet twin of a catalog scenario, with
the scenario's real workload geometry) the loop:

1. builds a proxy model — a chain of silu-gated MLP blocks whose
   planning-graph costs (``6·d·f`` FLOPs/token forward, 3× for
   remat'd backward, f32 param bytes) exactly describe the executable
   ``gated_mlp_layer`` — and a host fleet whose per-device memory
   forces a multi-stage plan;
2. runs the real planner (``DoraPlanner``) over it and takes the best
   single-device-per-stage pipeline layout (falling back to an even
   chain split when every candidate is data-parallel);
3. prices that same layout under both cost providers — the analytic
   datasheet roofline and the measured :class:`ProfiledCosts` from
   :mod:`repro.calibrate.host` — giving two predicted iteration
   latencies;
4. executes the layout for real through
   :class:`repro.runtime.pipeline.DoraPipelineExecutor` on the forced-
   host-platform mesh (forward wave for serving; ``jax.value_and_grad``
   through the pipelined loss for training) and times the iteration;
5. reports both relative errors into ``BENCH_fidelity.json`` — the
   committed sim-to-real trajectory CI gates on.

The host twin makes calibration *matter*: N forced host devices
time-share one physical core, so the uncalibrated datasheet prediction
(single-stream peak × default MFU) is structurally ~N× optimistic,
while the contended-rate measurement prices exactly what a pipeline
stage actually gets.

CLI::

    PYTHONPATH=src python -m repro.calibrate                 # full bench + rewrite JSON
    BENCH_QUICK=1 PYTHONPATH=src python -m repro.calibrate --check
        # CI gate: re-run the quick subset; fail if the calibrated mean
        # relative error exceeds the committed quick numbers by
        # >BENCH_REGRESSION_FACTOR (default 1.5x)
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.cost_model import CostRef, Workload, resolve_costs
from ..core.device import Topology
from ..core.partitioner import PartitionerConfig
from ..core.planner import DoraPlanner
from ..core.planning_graph import LayerNode, ModelGraph
from ..core.plans import ParallelismPlan, Stage
from ..core.qoe import QoESpec
from .host import host_costs, host_topology
from .microbench import (contended_mlp_rate, gated_mlp_layer, init_gated_mlp,
                         matmul_peak_flops, memory_bandwidth,
                         transfer_goodput)
from .timing import MeasurementCache, backend_key, time_callable

BENCH_PATH = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "..",
                 "BENCH_fidelity.json"))
SCHEMA = "dora-bench-fidelity/v1"

#: Rank plans purely by latency (objective = λ·latency with λ huge):
#: fidelity measures latency prediction, not the QoE trade-off.
LATENCY_QOE = QoESpec(t_qoe=0.0, lam=1e15)


@dataclasses.dataclass(frozen=True)
class FidelityCase:
    """A host-fleet twin of one catalog scenario.

    The *workload* (train vs serve, global batch, microbatch geometry)
    comes from the named catalog scenario; the proxy model and fleet
    size are scaled so the case plans and executes in seconds on a CPU
    host while still forcing a genuine multi-stage pipeline.
    """

    scenario: str          # catalog scenario this case mirrors
    n_devices: int         # host fleet size (≤ forced device count)
    n_layers: int          # proxy chain depth
    d_model: int
    d_ff: int
    tokens: int            # tokens per workload sample

    def rows(self, wl: Workload) -> int:
        """Microbatch rows the executor sees (samples × tokens)."""
        return wl.microbatch_size * self.tokens


#: The committed fidelity suite — ≥3 catalog scenarios, serve + train.
CASES: Tuple[FidelityCase, ...] = (
    FidelityCase("traffic_monitor", 4, 16, 512, 2048, 16),
    FidelityCase("hospital_ward", 4, 12, 512, 2048, 16),
    FidelityCase("vehicle_platoon", 4, 8, 512, 2048, 16),
    FidelityCase("smart_home_2", 4, 12, 384, 1536, 4),
)

#: CI subset: smaller proxies, 2-device fleets, still serve + train.
QUICK_CASES: Tuple[FidelityCase, ...] = (
    FidelityCase("traffic_monitor", 2, 8, 256, 1024, 8),
    FidelityCase("vehicle_platoon", 2, 6, 256, 1024, 8),
    FidelityCase("smart_home_2", 2, 6, 256, 1024, 4),
)


# -- proxy model ------------------------------------------------------------------
def proxy_graph(case: FidelityCase) -> ModelGraph:
    """Chain of LayerNodes that *exactly* prices ``gated_mlp_layer``:
    3 matmuls → ``6·d·f`` FLOPs per token forward, 3× that backward
    (grad-x + grad-w + remat recompute — the executor remats every
    stage), f32 parameters, f32 boundary activations."""
    d, f, t = case.d_model, case.d_ff, case.tokens
    nodes = [LayerNode(name=f"mlp{i}",
                       flops_fwd=6.0 * d * f * t,
                       param_bytes=3.0 * d * f * 4.0,
                       act_bytes=4.0 * d * t,
                       flops_bwd=18.0 * d * f * t)
             for i in range(case.n_layers)]
    return ModelGraph.chain(nodes)


def fleet_memory(graph: ModelGraph, wl: Workload, n: int) -> float:
    """Per-device memory that forces a multi-stage plan: ~1.45× the
    even n-way share of the model (+ optimizer) state — one device can
    never hold the whole model, so the planner must pipeline."""
    mult = wl.optimizer_mult if wl.training else 1.0
    return 1.45 * graph.total_params * mult / n


# -- layout selection -------------------------------------------------------------
Layout = List[Tuple[List[int], int]]        # [(node_ids, device), ...] in order


def plan_layout(graph: ModelGraph, topo: Topology, wl: Workload
                ) -> Tuple[Layout, str]:
    """Run the real planner; return the best executable pipeline layout.

    The executor runs one device per stage, so we take the best-ranked
    candidate whose stages are all single-device (dp=1) with ≥2 stages.
    If the whole pool is data-parallel (it never is once memory forces
    pipelining), fall back to an even chain split — and say so in the
    record, because then the *planner's* choice was not what executed.
    """
    cfg = PartitionerConfig(schedule="gpipe", delta=0.0, top_k=8)
    planner = DoraPlanner(graph, topo, LATENCY_QOE,
                          partitioner_config=cfg)
    result = planner.plan(wl)
    for plan in result.candidates:
        if plan.n_stages >= 2 and all(len(s.devices) == 1
                                      for s in plan.stages):
            return ([(list(s.node_ids), s.devices[0])
                     for s in plan.stages], "planner")
    n = topo.n
    L = len(graph.nodes)
    bounds = [round(i * L / n) for i in range(n + 1)]
    layout = [(list(range(bounds[i], bounds[i + 1])), i)
              for i in range(n) if bounds[i + 1] > bounds[i]]
    return layout, "even-chain-fallback"


def evaluate_layout(layout: Layout, graph: ModelGraph, topo: Topology,
                    wl: Workload, costs: CostRef = None,
                    schedule: str = "gpipe") -> ParallelismPlan:
    """Price a fixed stage layout under any cost provider.

    Keeping the layout fixed while swapping the provider is what makes
    the calibrated-vs-uncalibrated comparison clean: same stages, same
    devices, only the assumed rates differ."""
    cm = resolve_costs(costs).cost_model(graph, topo, wl)
    stages = []
    for i, (ids, dev) in enumerate(layout):
        nxt = [layout[i + 1][1]] if i + 1 < len(layout) else None
        stages.append(cm.make_stage(ids, [dev], nxt))
    return cm.evaluate(stages, LATENCY_QOE, schedule=schedule)


# -- execution --------------------------------------------------------------------
def execute_layout(case: FidelityCase, layout: Layout, wl: Workload, *,
                   warmup: int = 1, repeats: int = 3) -> float:
    """Run the layout for real on the forced-host mesh; wall seconds of
    one iteration (all microbatches through the pipeline; training adds
    the full backward via ``jax.value_and_grad`` through the remat'd
    pipeline — the executor's GPipe-over-shard_map path)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..launch.mesh import use_mesh
    from ..runtime.pipeline import DoraPipelineExecutor

    need = max(dev for _, dev in layout) + 1
    if jax.device_count() < need:
        raise RuntimeError(
            f"fidelity needs {need} local devices but jax sees "
            f"{jax.device_count()}; run via `python -m repro.calibrate` "
            f"(which sets --xla_force_host_platform_device_count before "
            f"importing jax) or set XLA_FLAGS yourself")
    stages = [Stage(node_ids=list(ids), devices=[dev],
                    microbatch_split={dev: 1.0})
              for ids, dev in layout]
    plan = ParallelismPlan(stages=stages, microbatch_size=wl.microbatch_size,
                           n_microbatches=wl.n_microbatches,
                           training=wl.training)
    mesh = jax.sharding.Mesh(
        np.array([jax.devices()[dev] for _, dev in layout]), ("stage",))
    ex = DoraPipelineExecutor(plan, case.n_layers, mesh, gated_mlp_layer)
    packed = ex.pack_params(init_gated_mlp(case.n_layers, case.d_model,
                                           case.d_ff))
    x = jax.random.normal(
        jax.random.PRNGKey(1),
        (wl.n_microbatches, case.rows(wl), case.d_model), jnp.float32)
    with use_mesh(mesh):
        if wl.training:
            step = jax.jit(jax.value_and_grad(
                lambda p: ex.loss(p, x, lambda out: jnp.mean(out * out))))
            return time_callable(lambda: step(packed), warmup=warmup,
                                 repeats=repeats)
        fwd = jax.jit(ex.forward)
        return time_callable(lambda: fwd(packed, x), warmup=warmup,
                             repeats=repeats)


# -- per-case fidelity ------------------------------------------------------------
def run_case(case: FidelityCase, cache: Optional[MeasurementCache] = None, *,
             quick: bool = False) -> Dict[str, object]:
    """Measure one fidelity case end to end (see module docstring)."""
    import jax

    from ..runtime.pipeline import PipelineSpec
    from ..scenarios import get_scenario

    cache = cache if cache is not None else MeasurementCache()
    wl = get_scenario(case.scenario).workload
    graph = proxy_graph(case)
    rep = 2 if quick else 4
    dim = 512 if quick else 1024
    measure = {
        "matmul_peak_flops": cache.get_or_measure(
            "matmul_peak", f"d{dim}",
            lambda: matmul_peak_flops(dim, repeats=rep)),
        "memory_bw": cache.get_or_measure(
            "memory_bw", "64MiB", lambda: memory_bandwidth(repeats=rep)),
    }
    if jax.device_count() > 1:
        measure["transfer_large_bps"] = cache.get_or_measure(
            "transfer", "16MiB", lambda: transfer_goodput(1 << 24,
                                                          repeats=rep))
        measure["transfer_small_bps"] = cache.get_or_measure(
            "transfer", "64KiB", lambda: transfer_goodput(1 << 16,
                                                          repeats=rep))
    topo = host_topology(measure, case.n_devices,
                         memory=fleet_memory(graph, wl, case.n_devices))
    layout, source = plan_layout(graph, topo, wl)
    S = len(layout)
    # pad = layers a stage *computes* per tick (idle slots are masked but
    # not free) — measure the contended rate on exactly that block
    pad = PipelineSpec.from_plan(
        ParallelismPlan(stages=[Stage(node_ids=ids, devices=[d],
                                      microbatch_split={d: 1.0})
                                for ids, d in layout],
                        microbatch_size=wl.microbatch_size,
                        n_microbatches=wl.n_microbatches),
        case.n_layers).pad
    rows = case.rows(wl)
    mode = "train" if wl.training else "serve"
    contended = cache.get_or_measure(
        "contended_mlp",
        f"{mode}/n{S}/r{rows}/d{case.d_model}x{case.d_ff}/l{pad}",
        lambda: contended_mlp_rate(S, rows=rows, d_model=case.d_model,
                                   d_ff=case.d_ff, layers=pad,
                                   training=wl.training,
                                   repeats=max(rep, 3)))
    costs = host_costs(measure, case.n_devices, contended=contended,
                       name=f"profiled-host/{case.scenario}")
    uncal = evaluate_layout(layout, graph, topo, wl)
    cal = evaluate_layout(layout, graph, topo, wl, costs=costs)
    measured = execute_layout(case, layout, wl,
                              repeats=2 if quick else 3)
    rec: Dict[str, object] = {
        "scenario": case.scenario,
        "mode": "train" if wl.training else "serve",
        "layout": source,
        "n_stages": S,
        "layers": case.n_layers,
        "d_model": case.d_model,
        "d_ff": case.d_ff,
        "microbatches": wl.n_microbatches,
        "measured_s": measured,
        "uncalibrated": {"predicted_s": uncal.latency,
                         "rel_err": abs(uncal.latency - measured) / measured},
        "calibrated": {"predicted_s": cal.latency,
                       "rel_err": abs(cal.latency - measured) / measured},
        "compute_factor": next(iter(costs.compute_factor.values())),
    }
    return rec


def run_fidelity(cases: Optional[Sequence[FidelityCase]] = None, *,
                 quick: bool = False,
                 cache: Optional[MeasurementCache] = None
                 ) -> Dict[str, object]:
    """The ``current`` section of ``BENCH_fidelity.json``."""
    cases = list(cases if cases is not None
                 else (QUICK_CASES if quick else CASES))
    cache = cache if cache is not None else MeasurementCache()
    recs = {c.scenario: run_case(c, cache, quick=quick) for c in cases}
    mean_unc = sum(r["uncalibrated"]["rel_err"]
                   for r in recs.values()) / len(recs)
    mean_cal = sum(r["calibrated"]["rel_err"]
                   for r in recs.values()) / len(recs)
    return {
        "commit": _commit(),
        "backend": backend_key(),
        "cases": recs,
        "mean_rel_err_uncalibrated": mean_unc,
        "mean_rel_err_calibrated": mean_cal,
        "calibration_gain": (mean_unc / mean_cal if mean_cal > 0.0
                             else float("inf")),
    }


# -- the committed artifact -------------------------------------------------------
def _commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, cwd=os.path.dirname(BENCH_PATH)).stdout.strip()
    except OSError:
        return "unknown"


def write_bench(current: Dict[str, object],
                path: str = BENCH_PATH) -> Dict[str, object]:
    """Merge ``current`` with the committed doc and write ``path``.

    Mirrors ``BENCH_planner.json``: the ``baseline`` section is sticky
    (seeded from the first full run, never overwritten) so the
    trajectory of fidelity across PRs stays visible."""
    doc: Dict[str, object] = {"schema": SCHEMA}
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    doc["schema"] = SCHEMA
    doc.setdefault("method",
                   "plan a host-fleet proxy pipeline with DoraPlanner, "
                   "price the chosen layout under analytic vs measured "
                   "(ProfiledCosts) rates, execute it for real via "
                   "runtime.pipeline on forced host devices, report "
                   "|predicted-measured|/measured per catalog-scenario "
                   "twin")
    doc.setdefault("baseline", current)
    doc["current"] = current
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return doc


def write_quick(quick_section: Dict[str, object],
                path: str = BENCH_PATH) -> None:
    """Rewrite only the ``quick`` section of the committed doc."""
    doc: Dict[str, object] = {"schema": SCHEMA}
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    doc["quick"] = quick_section
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


def refresh_quick(path: str = BENCH_PATH,
                  cache: Optional[MeasurementCache] = None) -> None:
    """Re-measure and rewrite only the ``quick`` section."""
    write_quick(run_fidelity(quick=True, cache=cache), path)


#: Absolute error the gate always tolerates: with a well-calibrated
#: committed reference (errors of a few %), a pure ratio gate would sit
#: inside run-to-run wall-clock noise on shared CI runners.  Genuine
#: fidelity regressions (a broken calibration path reverts predictions
#: toward the ~60-90% uncalibrated error) clear this floor by a wide
#: margin.
GATE_FLOOR = 0.25


def check_regression(path: str = BENCH_PATH) -> int:
    """CI gate: quick-subset calibrated fidelity vs. committed numbers.

    Re-runs the quick cases on this runner (measurement cache off —
    CI must measure its own hardware) and rewrites the artifact's
    ``quick`` section for upload.  Fails (exit 1) when either

    * calibration stops helping — calibrated mean relative error is no
      longer below uncalibrated (the machine-independent invariant) —
    * or the calibrated error exceeds the committed quick value by more
      than ``BENCH_REGRESSION_FACTOR`` (default 1.5x) *and* the
      absolute :data:`GATE_FLOOR`.
    """
    factor = float(os.environ.get("BENCH_REGRESSION_FACTOR", "1.5"))
    with open(path, encoding="utf-8") as f:
        committed = json.load(f)
    ref = committed.get("quick")
    cur = run_fidelity(quick=True, cache=MeasurementCache(path=None))
    write_quick(cur, path)
    cal = cur["mean_rel_err_calibrated"]
    unc = cur["mean_rel_err_uncalibrated"]
    print(f"quick calibrated mean rel err: {cal:.3f} "
          f"(uncalibrated {unc:.3f})")
    if cal >= unc:
        print(f"FAIL: calibration no longer helps "
              f"(calibrated {cal:.3f} >= uncalibrated {unc:.3f})")
        return 1
    if ref is None:
        print("no committed quick section; recorded this run as the seed")
        return 0
    gate = max(ref["mean_rel_err_calibrated"] * factor, GATE_FLOOR)
    if cal > gate:
        print(f"FAIL: calibrated fidelity regressed to {cal:.3f} "
              f"(committed {ref['mean_rel_err_calibrated']:.3f}, "
              f"gate max({factor:.2f}x, floor {GATE_FLOOR}) -> {gate:.3f})")
        return 1
    print(f"fidelity regression gate: OK ({cal:.3f} <= {gate:.3f})")
    return 0
