"""repro.calibrate — sim-to-real calibration & fidelity.

Microbenchmarks the real compute path (kernels, jitted steps, the
pipeline stage block) on the local host, converts the measurements into
a committed :class:`~repro.core.profiler.ProfiledCosts` artifact, and
closes the loop by executing planned pipelines for real and comparing
measured wall-clock against the planner's predictions
(``BENCH_fidelity.json``).

Importing this package never initializes jax: :mod:`timing` is eager
(it is jax-free at import), while :mod:`microbench`, :mod:`host` and
:mod:`fidelity` load on first attribute access.  Run the whole loop
with ``python -m repro.calibrate``.
"""
from __future__ import annotations

from .timing import (DEFAULT_CACHE, MeasurementCache, backend_key, block,
                     ensure_host_devices, time_callable)

_LAZY = {
    "measure_host": "microbench",
    "matmul_peak_flops": "microbench",
    "memory_bandwidth": "microbench",
    "kernel_rates": "microbench",
    "step_seconds": "microbench",
    "transfer_goodput": "microbench",
    "contended_mlp_rate": "microbench",
    "host_device": "host",
    "host_topology": "host",
    "host_costs": "host",
    "calibrate_host": "host",
    "FidelityCase": "fidelity",
    "CASES": "fidelity",
    "QUICK_CASES": "fidelity",
    "run_case": "fidelity",
    "run_fidelity": "fidelity",
    "write_bench": "fidelity",
    "check_regression": "fidelity",
    "BENCH_PATH": "fidelity",
}

__all__ = ["DEFAULT_CACHE", "MeasurementCache", "backend_key", "block",
           "ensure_host_devices", "time_callable", *sorted(_LAZY)]


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.calibrate' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
