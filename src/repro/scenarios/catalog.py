"""Built-in scenario catalogue.

The four Table-3 settings of the paper plus new deployments that stress
different corners of the QoE space (tight serving latency, per-device
energy budgets, lossy vehicle links, TPU-pod planning).  Device profiles
come from ``core.device.CATALOG``; degraded fleets are derived with
``dataclasses.replace`` so the catalogue stays the single source of
hardware truth.
"""
from __future__ import annotations

import dataclasses

from ..core.adapter import DynamicsEvent
from ..core.cost_model import PAPER_SERVE_WORKLOAD, PAPER_TRAIN_WORKLOAD
from ..core.device import CATALOG, MBPS, LinkResource, Topology, make_setting
from ..core.events import (DiurnalArrivals, FlashCrowdArrivals,
                           interactive_batch)
from ..core.qoe import QoESpec
from . import Scenario, register

# Default paper-style workloads, shared with sim.runner.workload_for.
TRAIN_WL = PAPER_TRAIN_WORKLOAD
SERVE_WL = PAPER_SERVE_WORKLOAD


# -- the paper's Table-3 settings ----------------------------------------------
register(Scenario(
    name="smart_home_1",
    description="Paper Table 3: well-provisioned smart home — 2 gaming "
                "laptops + 3 mini-PC dGPUs on 900 Mbps WiFi, fine-tuning.",
    topology=lambda: make_setting("smart_home_1"),
    model="qwen3-0.6b", workload=TRAIN_WL,
    qoe=QoESpec(t_qoe=6.0, lam=50.0),
    tags=("paper", "train"),
    request_rate=0.08,
))

register(Scenario(
    name="smart_home_2",
    description="Paper Table 3: mixed smart home — 2 laptop dGPUs + 3 "
                "phones on 600 Mbps WiFi, fine-tuning under a latency "
                "target.",
    topology=lambda: make_setting("smart_home_2"),
    model="qwen3-0.6b", workload=TRAIN_WL,
    qoe=QoESpec(t_qoe=8.0, lam=50.0),
    tags=("paper", "train"),
    request_rate=0.04,
    timeline=(
        ("evening 4K stream saturates WiFi (-50%)",
         DynamicsEvent(t=30.0, bandwidth_scale={"wifi": 0.5})),
        ("phone 4 unplugged, leaves the fleet",
         DynamicsEvent(t=60.0, leave=(4,))),
        ("stream ends",
         DynamicsEvent(t=150.0, bandwidth_scale={"wifi": 1.0})),
        ("phone 4 back on the charger, rejoins",
         DynamicsEvent(t=1200.0, join=(4,))),
    ),
))

register(Scenario(
    name="traffic_monitor",
    description="Paper Table 3: roadside camera fleet — 4 Genio boards "
                "on a wired ring + shared WiFi, per-token serving.",
    topology=lambda: make_setting("traffic_monitor"),
    model="qwen3-0.6b", workload=SERVE_WL,
    qoe=QoESpec(t_qoe=0.2, lam=100.0),
    tags=("paper", "serve"),
    request_rate=3.0,
    timeline=(
        ("camera 3 powers down for maintenance",
         DynamicsEvent(t=20.0, leave=(3,))),
        ("camera 3 back online",
         DynamicsEvent(t=60.0, join=(3,))),
    ),
))

register(Scenario(
    name="edge_cluster",
    description="Paper Table 3: small edge cluster — 2×A40 + 2×V100 on a "
                "4 Gbps wired LAN ring, fine-tuning a larger model.",
    topology=lambda: make_setting("edge_cluster"),
    model="qwen3-1.7b", workload=TRAIN_WL,
    qoe=QoESpec(t_qoe=2.0, lam=50.0),
    tags=("paper", "train"),
    request_rate=0.2,
))


# -- new deployments ------------------------------------------------------------
def _retail_topology() -> Topology:
    """RTX back-office server + two camera-hub Genio boards + one shelf
    gateway. Everyone is on store WiFi; the server additionally has
    dedicated ethernet to the camera hubs. The server is device 0 (the
    partitioner's DP grows plans over device prefixes)."""
    c = CATALOG
    devs = [c["rtx4060"], c["genio720"], c["genio720"], c["genio520"]]
    wifi = LinkResource("wifi", 600.0 * MBPS, frozenset(range(4)),
                        shared=True, latency=3e-3)
    eth = [LinkResource(f"eth-0-{i}", 1000.0 * MBPS, frozenset((0, i)),
                        shared=False, latency=0.3e-3) for i in (1, 2)]
    p2p = {}
    for i in (1, 2):
        p2p[(0, i)] = [f"eth-0-{i}"]
        p2p[(i, 0)] = [f"eth-0-{i}"]
    return Topology.mixed(devs, [wifi] + eth, p2p)


register(Scenario(
    name="retail_analytics",
    description="Retail-camera analytics: 2 camera hubs + shelf gateway "
                "on store WiFi, RTX back-office server on ethernet; "
                "serving shopper-flow queries.",
    topology=_retail_topology,
    model="qwen3-0.6b", workload=SERVE_WL,
    qoe=QoESpec(t_qoe=0.25, lam=100.0),
    tags=("serve", "mixed-network"),
    request_rate=3.0,
    timeline=(
        ("checkout rush saturates store WiFi (-60%)",
         DynamicsEvent(t=30.0, bandwidth_scale={"wifi": 0.4})),
        ("rush clears",
         DynamicsEvent(t=120.0, bandwidth_scale={"wifi": 1.0})),
    ),
))


def _hospital_topology() -> Topology:
    """Bedside tablets + two ward gateways on hospital WiFi (data must
    stay on-prem, so the fleet is all there is)."""
    c = CATALOG
    devs = [c["s25"], c["s25"], c["s25"], c["s25"],
            c["genio720"], c["genio720"]]
    return Topology.shared_medium(devs, 300.0, latency=4e-3)


register(Scenario(
    name="hospital_ward",
    description="Hospital ward monitoring: 4 bedside tablets + 2 "
                "gateways on 300 Mbps WiFi; on-prem serving with a "
                "strict alarm-latency target.",
    topology=_hospital_topology,
    model="qwen3-0.6b", workload=SERVE_WL,
    qoe=QoESpec(t_qoe=0.3, e_qoe=5.0, lam=200.0),
    tags=("serve", "energy-budget"),
    request_rate=3.0,
))


def _platoon_topology() -> Topology:
    """Four vehicles in convoy: V2V side links form a ring; hops are
    slow (100 Mbps) and high-latency (5 ms MAC/retry budget)."""
    devs = [CATALOG["genio520"]] * 4
    return Topology.ring(devs, 100.0, name="v2v", latency=5e-3)


register(Scenario(
    name="vehicle_platoon",
    description="Vehicle platoon: 4 in-car Genio boards over lossy "
                "100 Mbps V2V links; cooperative perception serving.",
    topology=_platoon_topology,
    model="bert", workload=SERVE_WL,
    qoe=QoESpec(t_qoe=0.25, lam=100.0),
    tags=("serve", "lossy-network"),
    request_rate=10.0,
    timeline=(
        ("overtaking truck shadows V2V links (-50%)",
         DynamicsEvent(t=15.0, bandwidth_scale={
             "v2v-0-1": 0.5, "v2v-1-2": 0.5, "v2v-2-3": 0.5,
             "v2v-3-0": 0.5})),
        ("truck passes",
         DynamicsEvent(t=45.0, bandwidth_scale={
             "v2v-0-1": 1.0, "v2v-1-2": 1.0, "v2v-2-3": 1.0,
             "v2v-3-0": 1.0})),
    ),
))


def _degraded_home_topology() -> Topology:
    """Smart Home 2's fleet with the phones on battery saver: thermal +
    battery governors cap sustained compute at ~60% of peak."""
    c = CATALOG
    throttle = lambda d: dataclasses.replace(d, flops=d.flops * 0.6)
    devs = [c["rtx4050"], c["rtx4050"],
            throttle(c["mi15"]), throttle(c["mi15"]), throttle(c["s25"])]
    return Topology.shared_medium(devs, 600.0)


# e_qoe calibration: the fleet's best plan costs ~270 J/device-iteration
# (11.8 s iterations × dGPU idle+compute draw), so the budget sits just
# above the healthy-plan envelope — bad plans (or refusing to shed the
# throttled phone) blow it, good ones do not.
register(Scenario(
    name="smart_home_degraded",
    description="Battery-degraded smart home: Smart Home 2 with phones "
                "throttled to 60% and a hard per-device energy budget; "
                "overnight fine-tuning.",
    topology=_degraded_home_topology,
    model="qwen3-0.6b", workload=TRAIN_WL,
    qoe=QoESpec(t_qoe=12.0, e_qoe=400.0, lam=20.0, deadline=8 * 3600.0),
    tags=("train", "energy-budget"),
    request_rate=0.02,
    timeline=(
        ("phone 4 hits battery saver (compute -50%)",
         DynamicsEvent(t=60.0, compute_speed={4: 0.5})),
        ("4K stream on home WiFi (-40%)",
         DynamicsEvent(t=180.0, bandwidth_scale={"wifi": 0.6})),
        ("stream ends",
         DynamicsEvent(t=600.0, bandwidth_scale={"wifi": 1.0})),
        ("phone 4 off battery saver",
         DynamicsEvent(t=900.0, compute_speed={4: 1.0})),
    ),
))


def _v5e_pod_topology() -> Topology:
    """A 4-chip TPU v5e ring for pod-level planning (the hardware
    target of the jax_pallas substrate): ICI-class 50 GB/s links."""
    devs = [CATALOG["v5e"]] * 4
    return Topology.ring(devs, 400000.0, name="ici", latency=0.05e-3)


register(Scenario(
    name="edge_pod_v5e",
    description="TPU v5e pod slice: 4 chips on ICI-class links; Dora "
                "plans the same graph it partitions for edge fleets.",
    topology=_v5e_pod_topology,
    model="qwen3-1.7b", workload=TRAIN_WL,
    qoe=QoESpec(t_qoe=0.8, lam=50.0),
    tags=("train", "pod"),
    request_rate=0.4,
))


# -- trace-driven arrival scenarios --------------------------------------------
# Serving deployments whose load is *not* a flat Poisson stream: the
# serving kernel's arrival zoo (``repro.core.events``) modulates the
# registered mean rate, and multi-class tiers judge each request
# against its own SLO.  ``dora.simulate(..., mode="requests")`` picks
# both up automatically.
register(Scenario(
    name="transit_hub",
    description="Transit-station kiosks: commuter queries swing through "
                "a rush-hour cycle; an interactive rider tier rides "
                "alongside a lax batch analytics tier.",
    topology=lambda: make_setting("traffic_monitor"),
    model="qwen3-0.6b", workload=SERVE_WL,
    qoe=QoESpec(t_qoe=0.3, lam=100.0),
    tags=("serve", "trace-driven"),
    request_rate=4.0,
    arrival=DiurnalArrivals(period_s=240.0, amplitude=0.9),
    request_classes=interactive_batch(0.25, 2.0, interactive_share=0.75),
))

register(Scenario(
    name="stadium_gate",
    description="Stadium-entrance screening: steady trickle until the "
                "gates open, then a flash crowd 8x the baseline slams "
                "the fleet for a minute.",
    topology=lambda: make_setting("traffic_monitor"),
    model="qwen3-0.6b", workload=SERVE_WL,
    qoe=QoESpec(t_qoe=0.4, lam=100.0),
    tags=("serve", "trace-driven"),
    request_rate=2.0,
    arrival=FlashCrowdArrivals(peak_multiplier=8.0, t_start=30.0,
                               ramp_s=10.0, hold_s=60.0),
))


# -- generated-family representatives ------------------------------------------
# ``repro.scenarios.generate`` samples whole *families* of deployments;
# the catalog pins one named representative per new family so the
# list/plan/simulate surfaces (and the strategy matrix tests) always
# exercise them.  The seeds are verified: Dora meets the sampled QoE,
# the dynamics timeline ends QoE-ok, and every registered strategy
# produces a valid plan.  Reproduce either one with
# ``generate("vehicle_platoon", 2)`` / ``generate("lossy_mesh", 24)``.
from .generate import register_generated  # noqa: E402  (cycle-safe)

register_generated(
    "vehicle_platoon", seed=2, name="platoon_convoy",
    description="Generated convoy: four vehicles on a lossy V2V ring "
                "whose link quality is redrawn by mobility events; "
                "per-token serving under churn (vehicle_platoon family, "
                "seed 2).")

register_generated(
    "lossy_mesh", seed=24, name="lossy_mesh",
    description="Generated degraded mesh: four boards on a partial 5G "
                "mesh with a thermal throttle and repeated bandwidth "
                "dips (lossy_mesh family, seed 24).")

register_generated(
    "battery_constrained", seed=12, name="battery_constrained",
    description="Generated battery-constrained fleet: six devices on a "
                "shared home medium, four running off finite batteries "
                "the serving load drains mid-horizon — exercises the "
                "control plane's SoC tracking and pre-death evacuation "
                "(battery_constrained family, seed 12).")

register_generated(
    "faulty_sites", seed=16, name="faulty_sites",
    description="Generated chaos site: seven devices on a partial "
                "wifi mesh whose timeline carries unannounced "
                "crash-stops, a link flap and a silent straggler — "
                "request-mode simulation routes through the "
                "resilience engine (faulty_sites family, seed 16).")
