"""Seeded, parameterized scenario generator — thousands of deployments
from a dozen hand-built ones.

The catalog (``repro.scenarios.catalog``) hand-wires nine deployments;
Dora's QoE claims live in a far larger space of fleets, networks and
runtime dynamics.  This module samples that space *deterministically*:

    from repro.scenarios.generate import generate
    sc = generate("lossy_mesh", seed=7)      # a valid Scenario
    report = dora.plan(sc)

Every scenario is fully determined by ``(family, seed)`` — the same
pair always yields a byte-identical parameter summary (locked by
``tests/golden/scenario_gen_golden.json``), so a falsified property
test names a reproducible deployment.

A **family** bundles the distributions one deployment archetype is
drawn from: topology families (star / ring / mesh / multi-hop / shared
medium), link technologies (wifi / 5G / ethernet / V2V with
bandwidth + latency envelopes), device classes from
``core.device.CATALOG``, battery/thermal-throttle models, dynamics
timelines (churn, bandwidth dips, load shifts) and workload mixes.
Built-in families:

========================  ====================================================
``edge_sites``            generic heterogeneous edge sites over all four
                          structured topology families
``smart_home``            phones + consumer dGPUs on one shared medium
``vehicle_platoon``       convoy mobility: lossy V2V chains/rings with
                          *time-varying* link quality (DistrEdge-style)
``lossy_mesh``            degraded partial meshes: low-bandwidth, high-latency
                          links that keep dropping further (DEFER-style)
``faulty_sites``          chaos archetype: edge sites under *unannounced*
                          failures — crash-stop devices, link flaps and
                          silent stragglers (see :mod:`repro.resilience`)
``battery_constrained``   battery-powered fleets: finite per-device energy
                          stores (``DeviceProfile.battery_j``) the serving
                          load drains (see :mod:`repro.control.battery`)
``mixed_train_serve``     fleet family: a fine-tuning tenant co-deployed with
                          serving tenants (see :func:`generate_fleet`)
========================  ====================================================

Generated scenarios are plain :class:`~repro.scenarios.Scenario`
objects; :func:`register_generated` pushes one into the global registry
through the normal ``register`` idiom (the catalog registers one named
representative per new family).  Topology factories build a *fresh*
``Topology`` per call — see ``Scenario.build_topology``'s fresh-copy
contract.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..core.adapter import DynamicsEvent
from ..core.cost_model import Workload
from ..core.device import CATALOG, DeviceProfile, Topology
from ..core.graph_builders import GraphSpec, build_lm_graph, paper_model
from ..core.planning_graph import ModelGraph
from ..core.qoe import QoESpec
from . import Scenario, register

__all__ = [
    "LinkTech", "FamilySpec", "ScenarioParams", "LINK_TECHS",
    "DEVICE_CLASSES", "FAMILIES", "TOPOLOGY_FAMILIES", "list_families",
    "sample_params", "scenario_from_params", "generate", "generate_many",
    "register_generated", "generate_fleet", "summarize",
]


# -- building blocks ------------------------------------------------------------
#: Topology families the generator composes (the "shared" family is one
#: shared medium; the other four are structured dedicated-link fabrics).
TOPOLOGY_FAMILIES = ("star", "ring", "mesh", "multi_hop", "shared")


@dataclasses.dataclass(frozen=True)
class LinkTech:
    """One link technology: bandwidth/latency envelopes + jitter depth.

    ``mbps``/``latency_s`` bound the uniform draw for a deployment's
    links; ``dip`` bounds how deep this technology's bandwidth dips go
    in generated dynamics timelines (0.6 = drops to 40% of nominal).
    """

    name: str
    mbps: Tuple[float, float]
    latency_s: Tuple[float, float]
    shared: bool                      # can form a shared medium
    dip: Tuple[float, float]


LINK_TECHS: Dict[str, LinkTech] = {
    "wifi": LinkTech("wifi", (150.0, 900.0), (2e-3, 5e-3), True, (0.3, 0.6)),
    "5g": LinkTech("5g", (80.0, 400.0), (8e-3, 20e-3), True, (0.4, 0.7)),
    "ethernet": LinkTech("ethernet", (1000.0, 4000.0), (1e-4, 5e-4), False,
                         (0.0, 0.2)),
    "v2v": LinkTech("v2v", (40.0, 150.0), (4e-3, 10e-3), False, (0.4, 0.8)),
}

#: Device classes over ``core.device.CATALOG`` profiles.
DEVICE_CLASSES: Dict[str, Tuple[str, ...]] = {
    "phone": ("s25", "mi15"),
    "board": ("genio520", "genio720"),
    "dgpu": ("rtx4050", "rtx4060", "rtx4060ti"),
    "server": ("v100", "a40"),
}

# -- models the generator can draw ----------------------------------------------
# Tiny planning graphs keep property-test sweeps at ~ms per plan; the
# builders are module-level named functions so ``Scenario.model_name``
# (and the golden summaries) stay stable.
_TINY_SPECS: Dict[str, GraphSpec] = {
    "tiny_lm_4": GraphSpec("tiny_lm_4", 4, 64, 4, 2, 192, 1000, seq_len=64,
                           gated_mlp=False),
    "tiny_lm_8": GraphSpec("tiny_lm_8", 8, 128, 4, 2, 384, 2000, seq_len=64),
}


def tiny_lm_4(seq_len: int) -> ModelGraph:
    return build_lm_graph(_TINY_SPECS["tiny_lm_4"], seq_len=seq_len)


def tiny_lm_8(seq_len: int) -> ModelGraph:
    return build_lm_graph(_TINY_SPECS["tiny_lm_8"], seq_len=seq_len)


_MODEL_BUILDERS: Dict[str, Callable[[int], ModelGraph]] = {
    "tiny_lm_4": tiny_lm_4,
    "tiny_lm_8": tiny_lm_8,
}


def _model_ref(name: str):
    """A ``Scenario.model`` value for ``name`` (paper name or tiny)."""
    return _MODEL_BUILDERS.get(name, name)


def _model_graph(name: str, seq_len: int) -> ModelGraph:
    if name in _MODEL_BUILDERS:
        return _MODEL_BUILDERS[name](seq_len)
    return paper_model(name, seq_len=seq_len)


# param bytes per model (cached; drives the memory-feasibility filter)
_PARAM_BYTES: Dict[str, float] = {}


def _model_param_bytes(name: str) -> float:
    if name not in _PARAM_BYTES:
        _PARAM_BYTES[name] = _model_graph(name, 32).total_params
    return _PARAM_BYTES[name]


# -- family specifications ------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FamilySpec:
    """Parameter distributions for one deployment archetype."""

    name: str
    description: str
    topologies: Tuple[str, ...]
    techs: Tuple[str, ...]
    device_classes: Tuple[str, ...]
    n_devices: Tuple[int, int]
    modes: Tuple[str, ...]                # "train" / "serve"
    models: Tuple[str, ...]
    #: t_qoe = (ideal aggregate-compute latency) × slack drawn from here
    qoe_slack: Tuple[float, float] = (1.5, 6.0)
    #: probability the QoE carries a per-device energy budget
    energy_budget_p: float = 0.3
    #: probability any one device is battery/thermal-throttled (its
    #: sustained FLOP/s capped at 50-80% of peak via the device profile)
    throttle_p: float = 0.15
    #: dynamics-event kinds the timeline is composed from
    dynamics: Tuple[str, ...] = ("bw_dip", "throttle", "churn")
    max_events: int = 3
    #: probability any one device runs on battery (a finite
    #: ``DeviceProfile.battery_j`` store the serving kernel's energy
    #: attribution drains — see ``repro.control.battery``)
    battery_p: float = 0.0
    #: battery capacity drawn as seconds of the device's own idle draw,
    #: so deaths land within simulated horizons regardless of class
    battery_idle_s: Tuple[float, float] = (120.0, 900.0)


FAMILIES: Dict[str, FamilySpec] = {}


def _family(spec: FamilySpec) -> FamilySpec:
    if spec.name in FAMILIES:
        raise ValueError(f"generator family {spec.name!r} already defined")
    FAMILIES[spec.name] = spec
    return spec


_family(FamilySpec(
    name="edge_sites",
    description="Generic heterogeneous edge sites: boards/dGPUs/servers "
                "on structured fabrics (star, ring, mesh, multi-hop).",
    topologies=("star", "ring", "mesh", "multi_hop"),
    techs=("ethernet", "wifi", "5g"),
    device_classes=("board", "dgpu", "server"),
    n_devices=(2, 8), modes=("train", "serve"),
    models=("bert", "qwen3-0.6b", "tiny_lm_8"),
))

_family(FamilySpec(
    name="smart_home",
    description="Phones + consumer dGPUs on one shared home medium; "
                "battery-saver throttles and evening-stream WiFi dips.",
    topologies=("shared",),
    techs=("wifi", "5g"),
    device_classes=("phone", "dgpu"),
    n_devices=(2, 6), modes=("train", "serve"),
    models=("bert", "qwen3-0.6b", "tiny_lm_8"),
    energy_budget_p=0.5, throttle_p=0.35,
    dynamics=("bw_dip", "throttle", "churn"),
))

_family(FamilySpec(
    name="vehicle_platoon",
    description="Convoy mobility: in-vehicle boards over lossy V2V "
                "chains/rings whose link quality varies continuously "
                "as the platoon stretches and closes up.",
    topologies=("multi_hop", "ring"),
    techs=("v2v",),
    device_classes=("board", "phone"),
    n_devices=(3, 6), modes=("serve",),
    models=("bert", "tiny_lm_8", "tiny_lm_4"),
    qoe_slack=(2.0, 8.0),
    dynamics=("mobility", "churn"),
    max_events=6,
))

_family(FamilySpec(
    name="faulty_sites",
    description="Chaos archetype: heterogeneous edge sites whose "
                "devices crash-stop silently, links flap and "
                "stragglers slow down without announcing it — the "
                "resilience layer's native habitat.",
    topologies=("star", "mesh", "ring"),
    techs=("wifi", "5g", "ethernet"),
    device_classes=("board", "dgpu", "server"),
    n_devices=(3, 7), modes=("serve",),
    models=("bert", "tiny_lm_8", "tiny_lm_4"),
    qoe_slack=(2.0, 8.0),
    dynamics=("crash", "link_flap", "straggler", "bw_dip"),
    max_events=4,
))

_family(FamilySpec(
    name="lossy_mesh",
    description="Degraded partial meshes: low-bandwidth high-latency "
                "links that keep losing capacity; traffic reroutes "
                "multi-hop around the damage.",
    topologies=("mesh",),
    techs=("v2v", "5g", "wifi"),
    device_classes=("board", "dgpu"),
    n_devices=(3, 7), modes=("serve", "train"),
    models=("bert", "tiny_lm_8"),
    qoe_slack=(2.0, 8.0),
    dynamics=("bw_dip", "churn"),
    max_events=4,
))


_family(FamilySpec(
    name="battery_constrained",
    description="Battery-powered fleets: phones and boards serving off "
                "finite energy stores the request load drains — the "
                "control plane's SoC mechanisms' native habitat.",
    topologies=("shared", "star"),
    techs=("wifi", "5g"),
    device_classes=("phone", "board", "dgpu"),
    n_devices=(3, 6), modes=("serve",),
    models=("bert", "tiny_lm_8", "tiny_lm_4"),
    qoe_slack=(2.0, 8.0),
    energy_budget_p=0.5,
    dynamics=("bw_dip", "throttle"),
    max_events=2,
    battery_p=0.5,
    battery_idle_s=(45.0, 240.0),
))


def list_families() -> List[str]:
    """Names of all generator families, sorted."""
    return sorted(FAMILIES)


# -- sampled parameter bundle ---------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ScenarioParams:
    """Everything :func:`generate` sampled for one ``(family, seed)``.

    Frozen and fully value-typed: two identical ``ScenarioParams`` build
    byte-identical scenarios, and :meth:`summary` is the canonical
    (golden-locked) serialization of the draw.
    """

    family: str
    seed: int
    topology_family: str
    tech: str
    device_names: Tuple[str, ...]
    throttles: Tuple[Tuple[int, float], ...]      # (device, sustained factor)
    link_mbps: float
    link_latency_s: float
    edges: Tuple[Tuple[int, int], ...]            # () for shared/derived fabrics
    model: str
    mode: str
    seq_len: int
    global_batch: int
    microbatch_size: int
    optimizer_mult: float
    t_qoe: float
    e_qoe: Optional[float]
    lam: float
    request_rate: float
    events: Tuple[Tuple[str, float, str, float], ...]
    # ^ (kind, t, target, value): kind in bw_dip/throttle/churn_leave/
    #   churn_join/mobility plus the unannounced fault kinds
    #   crash/link_down/link_up/straggler; target is a resource name
    #   or device index
    #: battery-backed devices as (device, capacity joules); empty for
    #: wall-powered fleets — drawn last so pre-battery families keep
    #: byte-identical summaries
    batteries: Tuple[Tuple[int, float], ...] = ()

    @property
    def name(self) -> str:
        return f"gen/{self.family}/{self.seed:04d}"

    def summary(self) -> str:
        """Canonical one-line serialization (byte-stable per seed)."""
        g6 = lambda x: format(x, ".6g")  # noqa: E731
        thr = ",".join(f"{d}:{g6(f)}" for d, f in self.throttles) or "-"
        edges = ",".join(f"{a}-{b}" for a, b in self.edges) or "-"
        evs = ";".join(f"{k}@{g6(t)}:{tgt}={g6(v)}"
                       for k, t, tgt, v in self.events) or "-"
        # only battery-drawing families carry the segment: pre-battery
        # summaries must stay byte-identical
        batt = ("" if not self.batteries else
                " batt=" + ",".join(f"{d}:{g6(j)}"
                                    for d, j in self.batteries))
        return (f"{self.name} topo={self.topology_family} tech={self.tech} "
                f"devs=[{','.join(self.device_names)}] throttle={thr} "
                f"link={g6(self.link_mbps)}Mbps/{g6(self.link_latency_s * 1e3)}ms "
                f"edges={edges} model={self.model} mode={self.mode} "
                f"seq={self.seq_len} wl=gb{self.global_batch}/"
                f"mb{self.microbatch_size}/om{g6(self.optimizer_mult)} "
                f"qoe=t{g6(self.t_qoe)}/"
                f"e{g6(self.e_qoe) if self.e_qoe is not None else 'None'}/"
                f"lam{g6(self.lam)} rate={g6(self.request_rate)} "
                f"events={evs}{batt}")

    # -- builders -------------------------------------------------------------
    def devices(self) -> List[DeviceProfile]:
        devs = [CATALOG[n] for n in self.device_names]
        for d, f in self.throttles:
            devs[d] = dataclasses.replace(devs[d], flops=devs[d].flops * f)
        for d, j in self.batteries:
            devs[d] = dataclasses.replace(devs[d], battery_j=j)
        return devs

    def build_topology(self) -> Topology:
        """A fresh ``Topology`` (never cached — every call re-builds, per
        the ``Scenario.build_topology`` fresh-copy contract)."""
        devs = self.devices()
        fam, mbps, lat = self.topology_family, self.link_mbps, self.link_latency_s
        if fam == "shared":
            return Topology.shared_medium(devs, mbps, name=self.tech,
                                          latency=lat)
        name = self.tech
        if fam == "star":
            return Topology.star(devs, mbps, name=name, latency=lat)
        if fam == "ring":
            return Topology.ring(devs, mbps, name=name, latency=lat)
        if fam == "multi_hop":
            return Topology.line(devs, mbps, name=name, latency=lat)
        if fam == "mesh":
            return Topology.mesh(devs, mbps, name=name, latency=lat,
                                 edges=self.edges or None)
        raise ValueError(f"unknown topology family {fam!r}")

    def timeline(self) -> Tuple[Tuple[str, DynamicsEvent], ...]:
        out: List[Tuple[str, DynamicsEvent]] = []
        for kind, t, target, value in self.events:
            if kind in ("bw_dip", "mobility"):
                label = (f"{kind}: {target} -> x{format(value, '.3g')}")
                ev = DynamicsEvent(t=t, bandwidth_scale={target: value})
            elif kind == "throttle":
                label = (f"throttle: device {target} -> "
                         f"x{format(value, '.3g')}")
                ev = DynamicsEvent(t=t, compute_speed={int(target): value})
            elif kind == "churn_leave":
                label = f"churn: device {target} leaves"
                ev = DynamicsEvent(t=t, leave=(int(target),))
            elif kind == "churn_join":
                label = f"churn: device {target} rejoins"
                ev = DynamicsEvent(t=t, join=(int(target),))
            elif kind == "crash":
                label = f"crash: device {target}"
                ev = DynamicsEvent(t=t, crash=(int(target),))
            elif kind == "link_down":
                label = f"link down: {target}"
                ev = DynamicsEvent(t=t, link_down=(target,))
            elif kind == "link_up":
                label = f"link up: {target}"
                ev = DynamicsEvent(t=t, link_up=(target,))
            elif kind == "straggler":
                label = (f"straggler: device {target} -> "
                         f"x{format(value, '.3g')}")
                ev = DynamicsEvent(t=t, straggler={int(target): value})
            else:
                raise ValueError(f"unknown event kind {kind!r}")
            out.append((label, ev))
        return tuple(out)


# -- sampling -------------------------------------------------------------------
def _rng(family: str, seed: int) -> random.Random:
    # string seeding hashes via sha512 — stable across processes and
    # platforms, unaffected by PYTHONHASHSEED
    return random.Random(f"dora-gen:{family}:{seed}")


def _ring_link(name: str, i: int, n: int) -> str:
    return f"{name}-{i}-{(i + 1) % n}"


def _resource_names(topology_family: str, tech: str, n: int,
                    edges: Sequence[Tuple[int, int]]) -> List[str]:
    """Names of the link resources the built topology will expose (for
    sampling dynamics targets without building the topology)."""
    if topology_family == "shared":
        return [tech]
    if topology_family == "ring":
        return [_ring_link(tech, i, n) for i in range(n)]
    if topology_family == "star":
        return [f"{tech}-0-{i}" for i in range(1, n)]
    if topology_family == "multi_hop":
        return [f"{tech}-{i}-{i + 1}" for i in range(n - 1)]
    return [f"{tech}-{min(a, b)}-{max(a, b)}" for a, b in edges]


def _sample_mesh_edges(rng: random.Random, n: int
                       ) -> Tuple[Tuple[int, int], ...]:
    """A connected partial mesh: a random spanning tree plus a sampled
    fraction of the remaining pairs."""
    order = list(range(1, n))
    rng.shuffle(order)
    edges = set()
    connected = [0]
    for v in order:
        u = rng.choice(connected)
        edges.add((min(u, v), max(u, v)))
        connected.append(v)
    extra_p = rng.uniform(0.15, 0.6)
    for i in range(n):
        for j in range(i + 1, n):
            if (i, j) not in edges and rng.random() < extra_p:
                edges.add((i, j))
    return tuple(sorted(edges))


def _churn_candidates(params_devices: int, topology_family: str,
                      edges: Sequence[Tuple[int, int]]) -> List[int]:
    """Devices whose departure keeps the fleet connected (device 0 — the
    hub / DP anchor — never churns)."""
    n = params_devices
    if n <= 2:
        return []
    if topology_family in ("shared", "mesh", "ring"):
        # shared medium / ring reroute always survive one departure;
        # mesh connectivity must be checked against the edge list
        if topology_family != "mesh":
            return list(range(1, n))
        out = []
        for d in range(1, n):
            adj: Dict[int, Dict[int, str]] = {}
            for a, b in edges:
                if d in (a, b):
                    continue
                adj.setdefault(a, {})[b] = "x"
                adj.setdefault(b, {})[a] = "x"
            rest = [v for v in range(n) if v != d]
            seen = {rest[0]}
            frontier = [rest[0]]
            while frontier:
                nxt = []
                for a in frontier:
                    for b in adj.get(a, {}):
                        if b not in seen:
                            seen.add(b)
                            nxt.append(b)
                frontier = nxt
            if set(rest) <= seen:
                out.append(d)
        return out
    if topology_family == "multi_hop":
        return [n - 1]          # only the tail is removable
    if topology_family == "star":
        return list(range(1, n))  # any leaf (never the hub)
    return []


def _ideal_latency(devs: Sequence[DeviceProfile], model: str, mode: str,
                   seq_len: int, n_micro: int,
                   link_mbps: float = 1000.0,
                   link_latency_s: float = 1e-3) -> float:
    """Optimistic-but-honest latency anchor the sampled QoE slack
    multiplies: aggregate-compute lower bound plus a two-hop network
    floor (one boundary activation each way) — per-token serving is
    dominated by the latter on edge links."""
    g = _model_graph(model, seq_len if mode == "train" else 1)
    flops = sum(n.flops_fwd for n in g.nodes)
    if mode == "train":
        flops = 3.0 * flops * n_micro
    agg = sum(d.effective_flops() for d in devs)
    act = max(n.act_bytes for n in g.nodes)
    from ..core.device import MBPS
    hop = link_latency_s + act / (link_mbps * MBPS)
    return flops / agg + 2.0 * hop * (n_micro if mode == "train" else 1.0)


def sample_params(family: str, seed: int) -> ScenarioParams:
    """Draw one deterministic parameter bundle for ``(family, seed)``."""
    try:
        spec = FAMILIES[family]
    except KeyError:
        known = ", ".join(sorted(FAMILIES))
        raise KeyError(f"unknown generator family {family!r}; "
                       f"known: {known}") from None
    rng = _rng(family, seed)

    topology_family = rng.choice(spec.topologies)
    tech = rng.choice([t for t in spec.techs
                       if topology_family != "shared"
                       or LINK_TECHS[t].shared])
    lt = LINK_TECHS[tech]
    n = rng.randint(*spec.n_devices)
    classes = [rng.choice(spec.device_classes) for _ in range(n)]
    # the DP grows plans over device prefixes: lead with the most
    # capable sampled class so star hubs / plan anchors are credible
    classes.sort(key=lambda c: -max(CATALOG[m].flops
                                    for m in DEVICE_CLASSES[c]))
    device_names = tuple(rng.choice(DEVICE_CLASSES[c]) for c in classes)
    throttles = tuple(
        (d, round(rng.uniform(0.5, 0.8), 4))
        for d in range(n) if rng.random() < spec.throttle_p)

    link_mbps = round(rng.uniform(*lt.mbps), 3)
    link_latency = round(rng.uniform(*lt.latency_s), 6)
    edges: Tuple[Tuple[int, int], ...] = ()
    if topology_family == "mesh":
        edges = _sample_mesh_edges(rng, n)

    mode = rng.choice(spec.modes)
    seq_len = rng.choice((64, 128, 256, 512))
    if mode == "train":
        global_batch = rng.choice((8, 16, 32))
        microbatch = rng.choice((1, 2, 4))
        optimizer_mult = rng.choice((3.0, 8.0))
    else:
        global_batch = rng.choice((1, 2, 4, 8))
        microbatch = 1
        optimizer_mult = 1.0

    # memory-feasibility filter: keep only models whose (optimizer-
    # inflated) parameters fit in ~80% of the fleet's aggregate memory;
    # every family lists a tiny fallback that always fits
    devs = [CATALOG[m] for m in device_names]
    cap = 0.8 * sum(d.memory for d in devs)
    mult = optimizer_mult + 1.0 if mode == "train" else 1.2
    fitting = [m for m in spec.models
               if _model_param_bytes(m) * mult <= cap]
    model = rng.choice(fitting) if fitting else "tiny_lm_4"

    n_micro = max(1, global_batch // microbatch)
    ideal = _ideal_latency(devs, model, mode, seq_len, n_micro,
                           link_mbps=link_mbps,
                           link_latency_s=link_latency)
    t_qoe = round(ideal * rng.uniform(*spec.qoe_slack), 6)
    lam = rng.choice((10.0, 50.0, 100.0, 200.0))
    e_qoe = None
    if rng.random() < spec.energy_budget_p:
        # envelope: average compute energy per device plus idle draw
        # over the latency target, with generous slack
        g = _model_graph(model, seq_len if mode == "train" else 1)
        flops = sum(nd.flops_fwd for nd in g.nodes)
        if mode == "train":
            flops = 3.0 * flops * n_micro
        e_est = (flops * max(d.e_flop for d in devs) / n
                 + max(d.p_idle for d in devs) * t_qoe)
        e_qoe = round(e_est * rng.uniform(2.0, 6.0), 4)
    request_rate = (round(rng.uniform(0.02, 0.4), 4) if mode == "train"
                    else round(rng.uniform(0.5, 10.0), 4))

    # -- dynamics timeline -----------------------------------------------------
    resources = _resource_names(topology_family, tech, n, edges)
    churnable = _churn_candidates(n, topology_family, edges)
    events: List[Tuple[str, float, str, float]] = []
    n_events = rng.randint(0, spec.max_events)
    t = 0.0
    for _ in range(n_events):
        t = round(t + rng.uniform(10.0, 60.0), 3)
        kinds = [k for k in spec.dynamics
                 if k not in ("churn", "crash") or churnable]
        if not kinds:
            break
        kind = rng.choice(kinds)
        if kind == "bw_dip":
            res = rng.choice(resources)
            depth = round(1.0 - rng.uniform(*lt.dip), 4)
            events.append(("bw_dip", t, res, depth))
            t = round(t + rng.uniform(20.0, 90.0), 3)
            events.append(("bw_dip", t, res, 1.0))
        elif kind == "mobility":
            # time-varying link quality: every link re-draws its scale
            for res in resources:
                events.append(("mobility", t, res,
                               round(rng.uniform(1.0 - lt.dip[1], 1.0), 4)))
        elif kind == "throttle":
            d = rng.randrange(n)
            events.append(("throttle", t, str(d),
                           round(rng.uniform(0.4, 0.8), 4)))
            t = round(t + rng.uniform(20.0, 90.0), 3)
            events.append(("throttle", t, str(d), 1.0))
        elif kind == "churn":
            d = rng.choice(churnable)
            events.append(("churn_leave", t, str(d), 0.0))
            t = round(t + rng.uniform(30.0, 120.0), 3)
            events.append(("churn_join", t, str(d), 1.0))
        elif kind == "crash":
            # unannounced crash-stop; the repair IS announced (the
            # rebooted device re-registers via ordinary join churn)
            d = rng.choice(churnable)
            events.append(("crash", t, str(d), 0.0))
            t = round(t + rng.uniform(30.0, 120.0), 3)
            events.append(("churn_join", t, str(d), 1.0))
        elif kind == "link_flap":
            res = rng.choice(resources)
            events.append(("link_down", t, res, 0.0))
            t = round(t + rng.uniform(15.0, 60.0), 3)
            events.append(("link_up", t, res, 1.0))
        elif kind == "straggler":
            d = rng.randrange(n)
            events.append(("straggler", t, str(d),
                           round(rng.uniform(0.2, 0.6), 4)))
            t = round(t + rng.uniform(20.0, 90.0), 3)
            events.append(("straggler", t, str(d), 1.0))
    events.sort(key=lambda e: e[1])

    # batteries draw LAST and only for battery families: every draw
    # before this point replays the exact pre-battery RNG stream, so
    # existing families' golden summaries stay byte-identical
    batteries: Tuple[Tuple[int, float], ...] = ()
    if spec.battery_p > 0.0:
        batteries = tuple(
            (d, round(devs[d].p_idle * rng.uniform(*spec.battery_idle_s), 1))
            for d in range(n) if rng.random() < spec.battery_p)

    return ScenarioParams(
        family=family, seed=seed, topology_family=topology_family,
        tech=tech, device_names=device_names, throttles=throttles,
        link_mbps=link_mbps, link_latency_s=link_latency, edges=edges,
        model=model, mode=mode, seq_len=seq_len, global_batch=global_batch,
        microbatch_size=microbatch, optimizer_mult=optimizer_mult,
        t_qoe=t_qoe, e_qoe=e_qoe, lam=lam, request_rate=request_rate,
        events=tuple(events), batteries=batteries)


def scenario_from_params(params: ScenarioParams, *,
                         name: Optional[str] = None,
                         description: Optional[str] = None) -> Scenario:
    """Materialize a :class:`Scenario` from a sampled parameter bundle."""
    spec = FAMILIES[params.family]
    wl = Workload(global_batch=params.global_batch,
                  microbatch_size=params.microbatch_size,
                  training=params.mode == "train",
                  optimizer_mult=params.optimizer_mult)
    return Scenario(
        name=name or params.name,
        description=description
        or (f"[generated:{params.family}] {spec.description} "
            f"(seed {params.seed}: {params.topology_family}/"
            f"{params.tech}, {len(params.device_names)} devices)"),
        topology=params.build_topology,
        model=_model_ref(params.model),
        workload=wl,
        qoe=QoESpec(t_qoe=params.t_qoe, e_qoe=params.e_qoe, lam=params.lam),
        seq_len=params.seq_len,
        tags=("generated", params.family, params.topology_family,
              params.mode),
        timeline=params.timeline(),
        request_rate=params.request_rate,
    )


def generate(family: str, seed: int = 0, **overrides) -> Scenario:
    """One deterministic scenario for ``(family, seed)``.

    ``overrides`` replace sampled fields of the underlying
    :class:`ScenarioParams` before the scenario is built (e.g.
    ``model="tiny_lm_4"``, ``t_qoe=1.0``, ``events=()``) — the name
    keeps the ``gen/<family>/<seed>`` form either way.
    """
    params = sample_params(family, seed)
    if overrides:
        bad = set(overrides) - {f.name for f in
                                dataclasses.fields(ScenarioParams)}
        if bad:
            raise TypeError(f"unknown ScenarioParams overrides: {sorted(bad)}")
        params = dataclasses.replace(params, **overrides)
    return scenario_from_params(params)


def generate_many(families: Optional[Sequence[str]] = None,
                  seeds: Sequence[int] = range(10)) -> List[Scenario]:
    """The cross product ``families × seeds`` as scenarios (generation
    order: family-major, matching :func:`list_families`)."""
    out = []
    for family in (families or list_families()):
        for seed in seeds:
            out.append(generate(family, seed))
    return out


def register_generated(family: str, seed: int, *, name: Optional[str] = None,
                       description: Optional[str] = None,
                       overwrite: bool = False, **overrides) -> Scenario:
    """Generate and push into the global scenario registry (the normal
    ``repro.scenarios.register`` idiom).  ``name``/``description``
    rename the registered copy (e.g. the catalog's ``lossy_mesh``
    representative); the generated tags are preserved."""
    sc = generate(family, seed, **overrides)
    fields = {}
    if name is not None:
        fields["name"] = name
    if description is not None:
        fields["description"] = description
    if fields:
        sc = dataclasses.replace(sc, **fields)
    return register(sc, overwrite=overwrite)


def summarize(ref) -> str:
    """Canonical summary for a ``(family, seed)`` pair or
    :class:`ScenarioParams` (what the golden file locks)."""
    if isinstance(ref, ScenarioParams):
        return ref.summary()
    family, seed = ref
    return sample_params(family, seed).summary()


# -- fleet family: mixed train + serve ------------------------------------------
def generate_fleet(seed: int = 0, *, name: Optional[str] = None):
    """The ``mixed_train_serve`` fleet family: a fine-tuning tenant
    co-deployed with a serving tenant on one generated shared-capable
    fleet (smart-home or edge-site archetype).  Deterministic per seed;
    returns an *unregistered* :class:`repro.fleet.FleetScenario`.
    """
    from ..fleet import FleetScenario
    rng = _rng("mixed_train_serve", seed)
    base_family = rng.choice(("smart_home", "edge_sites"))
    base_seed = rng.randrange(1 << 16)
    base = sample_params(base_family, base_seed)
    # the shared fleet: the base draw's topology, no timeline churn of
    # its own (fleet timelines are sampled below, in fleet device space)
    devs = [CATALOG[m] for m in base.device_names]
    tune_model = rng.choice(("tiny_lm_8", "bert"))
    tune_gb = rng.choice((8, 16))
    tune = dataclasses.replace(
        base, mode="train", model=tune_model,
        global_batch=tune_gb, microbatch_size=2,
        optimizer_mult=3.0, events=(),
        t_qoe=round(_ideal_latency(devs, tune_model, "train", base.seq_len,
                                   tune_gb // 2, link_mbps=base.link_mbps,
                                   link_latency_s=base.link_latency_s)
                    * rng.uniform(2.0, 6.0), 6),
        e_qoe=None,
        request_rate=round(rng.uniform(0.02, 0.1), 4))
    serve_model = rng.choice(("tiny_lm_4", "bert"))
    serve = dataclasses.replace(
        base, mode="serve", model=serve_model,
        global_batch=rng.choice((1, 2, 4)), microbatch_size=1,
        optimizer_mult=1.0, events=(),
        t_qoe=round(_ideal_latency(devs, serve_model, "serve", base.seq_len,
                                   1, link_mbps=base.link_mbps,
                                   link_latency_s=base.link_latency_s)
                    * rng.uniform(4.0, 12.0), 6),
        e_qoe=None,
        lam=rng.choice((100.0, 200.0)),
        request_rate=round(rng.uniform(0.5, 4.0), 4))
    tenants = (
        scenario_from_params(tune, name=f"gen_tune_{seed:04d}",
                             description="Generated fine-tuning tenant."),
        scenario_from_params(serve, name=f"gen_serve_{seed:04d}",
                             description="Generated serving tenant."),
    )
    timeline: List[Tuple[str, DynamicsEvent]] = []
    resources = _resource_names(base.topology_family, base.tech,
                                len(base.device_names), base.edges)
    if rng.random() < 0.8:
        res = rng.choice(resources)
        t0 = round(rng.uniform(20.0, 60.0), 3)
        depth = round(1.0 - rng.uniform(*LINK_TECHS[base.tech].dip), 4)
        timeline.append((f"bw_dip: {res} -> x{format(depth, '.3g')}",
                         DynamicsEvent(t=t0, bandwidth_scale={res: depth})))
        timeline.append((f"bw_dip: {res} recovers",
                         DynamicsEvent(t=round(t0 + rng.uniform(30.0, 90.0), 3),
                                       bandwidth_scale={res: 1.0})))
    return FleetScenario(
        name=name or f"gen/mixed_train_serve/{seed:04d}",
        description=f"[generated:mixed_train_serve] overnight tune + "
                    f"always-on serving on one generated "
                    f"{base.topology_family}/{base.tech} fleet "
                    f"(seed {seed}).",
        topology=base.build_topology,
        tenants=tenants,
        timeline=tuple(timeline),
        tags=("fleet", "generated", "mixed_train_serve"),
    )
