"""Scenario sweep CLI.

    PYTHONPATH=src python -m repro.scenarios --list
    PYTHONPATH=src python -m repro.scenarios --strategies
    PYTHONPATH=src python -m repro.scenarios --run smart_home_2
    PYTHONPATH=src python -m repro.scenarios --run all [--simulate]
    PYTHONPATH=src python -m repro.scenarios --run traffic_monitor --requests
    PYTHONPATH=src python -m repro.scenarios --run smart_home_2 \
        --strategy chain_split
    PYTHONPATH=src python -m repro.scenarios --run smart_home_2 \
        --compare dora throughput_max chain_split --json out.json

``--list`` prints the scenario registry; ``--strategies`` the planner
registry; ``--run`` plans the named scenario(s) through the
``repro.dora`` facade and prints each PlanReport; ``--strategy`` swaps
the planner; ``--compare`` runs several strategies side by side;
``--simulate`` additionally replays each scenario's registered dynamics
timeline through the runtime adapter; ``--requests`` runs the
request-level serving simulator (open-loop arrivals at the scenario's
registered rate, timeline + churn included) and reports p50/p95/p99
latency, SLO attainment and energy; ``--json PATH`` writes everything
the run produced as one machine-readable artifact.

``--fleet`` switches both ``--list`` and ``--run`` to the multi-tenant
fleet registry (``repro.fleet``)::

    PYTHONPATH=src python -m repro.scenarios --list --fleet
    PYTHONPATH=src python -m repro.scenarios --run smart_home_assist --fleet
    PYTHONPATH=src python -m repro.scenarios --run all --fleet --requests

``--run NAME --fleet`` co-plans the fleet (``dora.plan_fleet``) and
prints every tenant's allotment + QoE verdict; ``--requests`` then runs
the multi-tenant serving simulator on the fleet timeline.

``--generate`` samples from the seeded generator families
(``repro.scenarios.generate``) instead of the registry::

    PYTHONPATH=src python -m repro.scenarios --generate lossy_mesh \
        --seed 0 --count 5
    PYTHONPATH=src python -m repro.scenarios --generate all --count 3

Each sampled deployment prints its canonical (golden-locked) parameter
summary and is planned end to end; ``--list`` also reports per-family
counts of registered generated representatives.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence

from .. import dora
from ..strategies import list_strategies
from . import get_scenario, iter_scenarios, list_scenarios


def _print_listing(tag: str = None) -> None:
    rows = [s.summary_row() for s in iter_scenarios(tag)]
    headers = ("name", "mode", "model", "devs", "t_qoe", "description")
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    print("  ".join(h.ljust(w) for h, w in zip(headers, widths)))
    print("  ".join("-" * w for w in widths))
    for r in rows:
        print("  ".join(v.ljust(w) for v, w in zip(r, widths)))
    print(f"\n{len(rows)} scenarios registered")
    _print_generator_coverage()


def _print_generator_coverage() -> None:
    """One coverage line per generator family: how many registered
    catalog representatives each has (the families themselves are
    unbounded — any seed is a valid deployment)."""
    from ..fleet import iter_fleets
    from .generate import FAMILIES
    counts = {fam: sum(1 for s in iter_scenarios("generated")
                       if fam in s.tags)
              for fam in sorted(FAMILIES)}
    fleet_count = sum(1 for f in iter_fleets("generated"))
    parts = [f"{fam}:{n}" for fam, n in counts.items()]
    parts.append(f"mixed_train_serve:{fleet_count} (fleet)")
    print(f"generator families ({len(FAMILIES) + 1}, seeded — see "
          f"--generate): registered representatives " + " ".join(parts))


def _print_fleet_listing(tag: str = None) -> None:
    from ..fleet import iter_fleets
    rows = [f.summary_row() for f in iter_fleets(tag)]
    headers = ("name", "tenants", "devs", "description")
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    print("  ".join(h.ljust(w) for h, w in zip(headers, widths)))
    print("  ".join("-" * w for w in widths))
    for r in rows:
        print("  ".join(v.ljust(w) for v, w in zip(r, widths)))
    print(f"\n{len(rows)} fleet scenarios registered")


def _run_fleets(names: List[str], requests: bool,
                json_path: Optional[str]) -> int:
    from .. import dora
    failures = 0
    artifact: Dict[str, Dict[str, object]] = {}
    for name in names:
        entry: Dict[str, object] = {}
        artifact[name] = entry
        print(f"\n===== {name} " + "=" * max(0, 60 - len(name)))
        try:
            session = dora.serve_fleet(name)
        except Exception as e:  # noqa: BLE001 — keep sweeping on failure
            print(f"[ERROR] fleet planning failed: {type(e).__name__}: {e}")
            entry["error"] = f"{type(e).__name__}: {e}"
            failures += 1
            continue
        print(session.plan.summary())
        entry["plan"] = session.plan.to_dict()
        if not session.plan.feasible:
            failures += 1
        if requests:
            print("\nmulti-tenant serving simulation:")
            try:
                trace = dora.simulate(name, mode="fleet", session=session)
                print(trace.summary())
                entry["serving"] = trace.to_dict()
            except Exception as e:  # noqa: BLE001 — keep sweeping
                print(f"[ERROR] fleet sim failed: {type(e).__name__}: {e}")
                entry["serving_error"] = f"{type(e).__name__}: {e}"
                failures += 1
    if json_path is not None:
        with open(json_path, "w", encoding="utf-8") as f:
            json.dump({"fleets": artifact}, f, indent=2, allow_nan=False)
            f.write("\n")
        print(f"\nwrote {json_path}")
    return failures


def _run_generated(family: str, seed: int, count: int, strategy: str,
                   json_path: Optional[str]) -> int:
    """Sample ``count`` scenarios per family starting at ``seed``,
    print each draw's canonical summary, and plan it."""
    from .generate import generate, list_families, sample_params
    fams = list_families() if family == "all" else [family]
    failures = 0
    artifact: Dict[str, Dict[str, object]] = {}
    for fam in fams:
        for s in range(seed, seed + count):
            try:
                params = sample_params(fam, s)
            except KeyError as e:
                print(f"error: {e.args[0]}", file=sys.stderr)
                return 1
            print(params.summary())
            entry: Dict[str, object] = {"summary": params.summary()}
            artifact[params.name] = entry
            try:
                report = dora.plan(generate(fam, s), strategy=strategy)
            except Exception as e:  # noqa: BLE001 — keep sweeping
                print(f"  [ERROR] planning failed: "
                      f"{type(e).__name__}: {e}")
                entry["error"] = f"{type(e).__name__}: {e}"
                failures += 1
                continue
            verdict = "QoE ok" if report.meets_qoe else "QoE MISS"
            print(f"  -> {len(report.best.stages)} stages, "
                  f"{report.latency * 1e3:.2f} ms, "
                  f"{report.energy:.2f} J, {verdict}")
            entry["plan"] = report.to_dict()
            if not report.meets_qoe:
                failures += 1
    if json_path is not None:
        with open(json_path, "w", encoding="utf-8") as f:
            json.dump({"generated": artifact}, f, indent=2, allow_nan=False)
            f.write("\n")
        print(f"\nwrote {json_path}")
    return failures


def _run(names: List[str], strategy: str, compare: Optional[Sequence[str]],
         simulate: bool, requests: bool, json_path: Optional[str]) -> int:
    failures = 0
    artifact: Dict[str, Dict[str, object]] = {}
    for name in names:
        try:
            sc = get_scenario(name)
        except KeyError as e:
            print(f"error: {e.args[0]}", file=sys.stderr)
            failures += 1
            continue
        entry: Dict[str, object] = {}
        artifact[sc.name] = entry
        print(f"\n===== {name} " + "=" * max(0, 60 - len(name)))
        if compare is not None:
            strategies = list(compare) or list(dora.DEFAULT_COMPARISON)
            try:
                cmp = dora.compare(sc, strategies=strategies)
            except ValueError as e:      # e.g. a typo'd strategy name
                print(f"error: {e}", file=sys.stderr)
                entry["error"] = str(e)
                failures += 1
                continue
            print(cmp.summary())
            entry["compare"] = cmp.to_dict()
            failures += sum(1 for s in cmp.strategies if not cmp[s].ok)
            continue
        try:
            if strategy == "dora":
                session = dora.serve(sc)
                report = session.report
            else:
                session = None
                report = dora.plan(sc, strategy=strategy)
        except Exception as e:  # noqa: BLE001 — keep sweeping on failure
            print(f"[ERROR] planning failed: {type(e).__name__}: {e}")
            entry["error"] = f"{type(e).__name__}: {e}"
            failures += 1
            continue
        print(report.summary())
        entry["plan"] = report.to_dict()
        if requests:
            print("\nrequest-level serving simulation:")
            try:
                # copy=True: a later --simulate must see a fresh session,
                # and non-dora strategies reuse the plan already computed
                trace = dora.simulate(sc, mode="requests", copy=True,
                                      session=session, strategy=strategy,
                                      report=None if session else report)
                print(trace.summary())
                entry["serving"] = trace.to_dict()
            except Exception as e:  # noqa: BLE001 — keep sweeping
                print(f"[ERROR] serving sim failed: {type(e).__name__}: {e}")
                entry["serving_error"] = f"{type(e).__name__}: {e}"
                failures += 1
        if simulate and sc.timeline:
            if session is None:
                print("\n(--simulate needs the runtime adapter, which only "
                      "the 'dora' strategy arms; skipping timeline)")
            else:
                print("\ndynamics timeline:")
                trace = dora.simulate(sc, session=session)
                print(trace.summary())
                entry["simulate"] = trace.to_dict()
    if json_path is not None:
        with open(json_path, "w", encoding="utf-8") as f:
            json.dump({"scenarios": artifact}, f, indent=2, allow_nan=False)
            f.write("\n")
        print(f"\nwrote {json_path}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.scenarios",
        description="List or sweep Dora's registered deployment scenarios.")
    ap.add_argument("--list", action="store_true",
                    help="print the scenario registry and exit")
    ap.add_argument("--strategies", action="store_true",
                    help="print the planner-strategy registry and exit")
    ap.add_argument("--run", nargs="+", metavar="NAME",
                    help="plan the named scenario(s); 'all' sweeps the "
                         "whole registry")
    ap.add_argument("--tag", default=None,
                    help="filter --list/--run all by tag (e.g. paper, serve)")
    ap.add_argument("--strategy", default="dora", metavar="STRAT",
                    help="planner strategy for --run (see --strategies)")
    ap.add_argument("--compare", nargs="*", metavar="STRAT", default=None,
                    help="with --run: compare strategies side by side "
                         "(no names -> the default line-up)")
    ap.add_argument("--simulate", action="store_true",
                    help="with --run: also replay each scenario's dynamics "
                         "timeline through the runtime adapter")
    ap.add_argument("--requests", action="store_true",
                    help="with --run: request-level serving simulation at "
                         "the scenario's registered request rate (p50/p95/"
                         "p99 latency, SLO attainment, energy)")
    ap.add_argument("--json", default=None, metavar="PATH", dest="json_path",
                    help="with --run: write plans/comparisons/traces as one "
                         "machine-readable JSON artifact")
    ap.add_argument("--fleet", action="store_true",
                    help="operate on the multi-tenant fleet registry: "
                         "--list prints it, --run co-plans fleets "
                         "(dora.plan_fleet) and --requests runs the "
                         "multi-tenant serving simulator")
    ap.add_argument("--generate", default=None, metavar="FAMILY",
                    help="sample scenarios from a generator family "
                         "(repro.scenarios.generate) and plan each; "
                         "'all' sweeps every family")
    ap.add_argument("--seed", type=int, default=0,
                    help="with --generate: first seed (default 0)")
    ap.add_argument("--count", type=int, default=1,
                    help="with --generate: seeds per family (default 1)")
    args = ap.parse_args(argv)

    if args.strategies:
        for name in list_strategies():
            print(name)
        print(f"\n{len(list_strategies())} strategies registered")
        return 0
    if args.generate:
        return _run_generated(args.generate, args.seed, args.count,
                              args.strategy, args.json_path)
    if args.fleet:
        from ..fleet import list_fleets
        if args.list or not args.run:
            _print_fleet_listing(args.tag)
            return 0
        names = (list_fleets(args.tag) if args.run == ["all"]
                 else list(args.run))
        return _run_fleets(names, args.requests, args.json_path)
    if args.list or not args.run:
        _print_listing(args.tag)
        return 0
    names = (list_scenarios(args.tag) if args.run == ["all"]
             else list(args.run))
    return _run(names, args.strategy, args.compare, args.simulate,
                args.requests, args.json_path)


if __name__ == "__main__":
    sys.exit(main())
