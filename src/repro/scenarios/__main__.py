"""Scenario sweep CLI.

    PYTHONPATH=src python -m repro.scenarios --list
    PYTHONPATH=src python -m repro.scenarios --run smart_home_2
    PYTHONPATH=src python -m repro.scenarios --run all [--simulate]

``--list`` prints the registry; ``--run`` plans the named scenario(s)
through the ``repro.dora`` facade and prints each PlanReport;
``--simulate`` additionally replays each scenario's registered dynamics
timeline through the runtime adapter.
"""
from __future__ import annotations

import argparse
import sys
from typing import List

from .. import dora
from . import get_scenario, iter_scenarios, list_scenarios


def _print_listing(tag: str = None) -> None:
    rows = [s.summary_row() for s in iter_scenarios(tag)]
    headers = ("name", "mode", "model", "devs", "t_qoe", "description")
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    print("  ".join(h.ljust(w) for h, w in zip(headers, widths)))
    print("  ".join("-" * w for w in widths))
    for r in rows:
        print("  ".join(v.ljust(w) for v, w in zip(r, widths)))
    print(f"\n{len(rows)} scenarios registered")


def _run(names: List[str], simulate: bool) -> int:
    failures = 0
    for name in names:
        try:
            sc = get_scenario(name)
        except KeyError as e:
            print(f"error: {e.args[0]}", file=sys.stderr)
            failures += 1
            continue
        print(f"\n===== {name} " + "=" * max(0, 60 - len(name)))
        try:
            session = dora.serve(sc)
        except Exception as e:  # noqa: BLE001 — keep sweeping on failure
            print(f"[ERROR] planning failed: {type(e).__name__}: {e}")
            failures += 1
            continue
        print(session.report.summary())
        if simulate and sc.timeline:
            print("\ndynamics timeline:")
            print(dora.simulate(sc, session=session).summary())
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.scenarios",
        description="List or sweep Dora's registered deployment scenarios.")
    ap.add_argument("--list", action="store_true",
                    help="print the scenario registry and exit")
    ap.add_argument("--run", nargs="+", metavar="NAME",
                    help="plan the named scenario(s); 'all' sweeps the "
                         "whole registry")
    ap.add_argument("--tag", default=None,
                    help="filter --list/--run all by tag (e.g. paper, serve)")
    ap.add_argument("--simulate", action="store_true",
                    help="with --run: also replay each scenario's dynamics "
                         "timeline through the runtime adapter")
    args = ap.parse_args(argv)

    if args.list or not args.run:
        _print_listing(args.tag)
        return 0
    names = (list_scenarios(args.tag) if args.run == ["all"]
             else list(args.run))
    return _run(names, args.simulate)


if __name__ == "__main__":
    sys.exit(main())
