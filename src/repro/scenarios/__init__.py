"""Named end-to-end deployment scenarios for Dora.

Dora's headline claim is breadth across *deployments* — smart homes,
traffic analytics, small edge clusters — under multi-dimensional QoE.
This package makes that breadth the organizing axis of the codebase: a
:class:`Scenario` bundles everything Algorithm 1 needs to plan one
deployment end to end —

* a device fleet + network substrate (``core.device.Topology``),
* a model planning graph (``core.planning_graph.ModelGraph``),
* a workload (``core.cost_model.Workload``: training vs serving,
  batch/microbatch geometry),
* QoE targets (``core.qoe.QoESpec``: latency target, energy budget, λ),
* optionally a runtime-dynamics timeline (``core.adapter.DynamicsEvent``
  sequence) describing how conditions evolve mid-run.

Scenarios live in a process-global registry keyed by name.  The four
Table-3 settings of the paper are registered out of the box alongside
new deployments (retail analytics, hospital ward, vehicle platoon,
battery-degraded smart home, TPU-pod planning); adding another is one
:class:`Scenario` dataclass + :func:`register` call — see
``docs/ARCHITECTURE.md`` ("How to add a scenario").

Consumers:

* ``repro.dora`` — the facade: ``dora.plan("hospital_ward")`` etc.;
* ``python -m repro.scenarios --list/--run`` — the sweep CLI;
* ``repro.sim.runner`` and the ``benchmarks/`` harnesses — resolve
  (setting, model) pairs through this registry.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Tuple, Union

from ..core.adapter import DynamicsEvent
from ..core.cost_model import Workload
from ..core.device import Topology
from ..core.graph_builders import paper_model
from ..core.planning_graph import ModelGraph
from ..core.qoe import QoESpec

# A model reference is either a paper-model name ("qwen3-0.6b") or a
# builder taking the effective sequence length.
ModelRef = Union[str, Callable[[int], ModelGraph]]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named end-to-end deployment: fleet + model + workload + QoE."""

    name: str
    description: str
    topology: Callable[[], Topology]
    model: ModelRef
    workload: Workload
    qoe: QoESpec
    seq_len: int = 512
    tags: Tuple[str, ...] = ()
    # (label, event) pairs of runtime dynamics this deployment typically
    # experiences; ``dora.simulate`` replays them by default.
    timeline: Tuple[Tuple[str, DynamicsEvent], ...] = ()
    # mean open-loop request rate (requests/sec) this deployment serves;
    # drives the request-level simulator (``dora.simulate`` with
    # ``mode="requests"``).  For training deployments one "request" is
    # one iteration.  ``None`` → half the plan's service capacity.
    request_rate: Optional[float] = None
    # arrival process from the serving kernel's zoo
    # (``repro.core.events``: DiurnalArrivals / MMPPArrivals /
    # FlashCrowdArrivals / TraceArrivals), modulating ``request_rate``;
    # ``None`` → homogeneous Poisson.
    arrival: Optional[object] = None
    # multi-class SLO tiers (``repro.core.events.RequestClass`` tuple,
    # e.g. interactive vs. batch); empty → one implicit class at the
    # load's SLO.
    request_classes: Tuple[object, ...] = ()

    @property
    def mode(self) -> str:
        """``"train"`` or ``"serve"`` (from the workload)."""
        return "train" if self.workload.training else "serve"

    @property
    def model_name(self) -> str:
        if isinstance(self.model, str):
            return self.model
        return getattr(self.model, "__name__", "custom")

    def build_topology(self) -> Topology:
        """A **fresh** ``Topology`` on every call — never memoized.

        ``Topology`` carries mutable post-construction state (route /
        bandwidth memo caches, calibrated link rates), and consumers
        mutate their copy freely: ``FleetPlanner`` calibrates it,
        adapter sessions scale link capacities as dynamics land.
        Memoizing here would alias that state across sessions — two
        concurrent ``dora.serve`` sessions would see each other's
        bandwidth dips.  Topology factories must therefore rebuild from
        scratch (all catalog + generated factories do); the contract is
        locked by ``test_build_topology_returns_fresh_copies``.
        """
        return self.topology()

    def build_graph(self, seq_len: Optional[int] = None) -> ModelGraph:
        """Planning graph at the scenario's effective sequence length.

        Serving plans per generated token, so the planning graph is built
        at seq_len=1 unless explicitly overridden (matching the paper's
        per-token serving latency measurements).
        """
        if seq_len is None:
            seq_len = self.seq_len if self.workload.training else 1
        if isinstance(self.model, str):
            return paper_model(self.model, seq_len=seq_len)
        return self.model(seq_len)

    def summary_row(self) -> Tuple[str, str, str, str, str, str]:
        topo = self.build_topology()
        qoe = (f"{self.qoe.t_qoe:g}s" if self.qoe.t_qoe != float("inf")
               else "-")
        return (self.name, self.mode, self.model_name, str(topo.n), qoe,
                self.description)


# -- registry ------------------------------------------------------------------
_REGISTRY: Dict[str, Scenario] = {}

#: The paper's Table-3 settings, in paper order (used by benchmarks).
PAPER_SETTINGS: Tuple[str, ...] = (
    "smart_home_1", "smart_home_2", "traffic_monitor", "edge_cluster")


def register(scenario: Scenario, overwrite: bool = False) -> Scenario:
    """Add a scenario to the global registry (returns it for chaining)."""
    if scenario.name in _REGISTRY and not overwrite:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get_scenario(ref: Union[str, Scenario]) -> Scenario:
    """Resolve a name (or pass through an ad-hoc Scenario object)."""
    if isinstance(ref, Scenario):
        return ref
    try:
        return _REGISTRY[ref]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown scenario {ref!r}; known: {known}") from None


def list_scenarios(tag: Optional[str] = None) -> List[str]:
    """Registered scenario names (optionally filtered by tag), sorted."""
    names = [n for n, s in _REGISTRY.items() if tag is None or tag in s.tags]
    return sorted(names)


def iter_scenarios(tag: Optional[str] = None) -> Iterable[Scenario]:
    for name in list_scenarios(tag):
        yield _REGISTRY[name]


# Populate the registry with the built-in catalogue on import.  The
# catalogue pulls in ``generate`` (the seeded scenario generator) for
# its generated-family representatives, so ``repro.scenarios.generate``
# is always importable once the package is.
from . import catalog  # noqa: E402,F401  (registration side effects)
from . import generate  # noqa: E402,F401  (generator families)

__all__ = [
    "Scenario", "ModelRef", "PAPER_SETTINGS", "register", "get_scenario",
    "list_scenarios", "iter_scenarios", "catalog", "generate",
]
