"""RG-LRU linear recurrence (RecurrentGemma/Griffin) as a Pallas kernel.

Computes h_t = a_t · h_{t-1} + b_t over the sequence axis.

TPU adaptation: instead of a sequential per-step loop (VPU-hostile), a
Hillis–Steele *doubling scan* runs the recurrence in ⌈log2 L⌉ rounds of
full-width vector multiplies on an (L, W) tile:

    (A, h) ← (A · shift(A, k), h + A · shift(h, k)),  k = 1, 2, 4, ...

after which A_t = Π_{s≤t} a_s and h_t is the in-block scan. The carried
cross-block state enters as ``h_t += A_t · h_block_in``.

* grid = (batch, W tiles, T blocks); T innermost/sequential, the (Wb,)
  f32 state carried in VMEM scratch.
* a is passed in log space (a = exp(a_log), a_log ≤ 0) exactly like the
  model's ``_rglru_scan`` oracle; b is the gated input.

Oracle: ``repro.models.rglru._rglru_scan``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _rglru_kernel(alog_ref, b_ref, h_ref, hlast_ref, state_scr, *,
                  block_t: int, n_tblocks: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    a = jnp.exp(alog_ref[0, :, :].astype(jnp.float32))     # (L, Wb)
    h = b_ref[0, :, :].astype(jnp.float32)                 # (L, Wb)
    acc = a
    k = 1
    while k < block_t:                                     # Hillis–Steele
        pad_h = jnp.pad(h, ((k, 0), (0, 0)))[:block_t]          # additive id 0
        pad_a = jnp.pad(acc, ((k, 0), (0, 0)),
                        constant_values=1.0)[:block_t]          # multiplicative id 1
        h = h + acc * pad_h
        acc = acc * pad_a
        k *= 2
    # inject the carried state: h_t += (Π_{s≤t} a_s) · h_in
    h = h + acc * state_scr[...][None, :]
    state_scr[...] = h[-1]
    h_ref[0, :, :] = h.astype(h_ref.dtype)

    @pl.when(it == n_tblocks - 1)
    def _emit():
        hlast_ref[0, :] = h[-1]


@functools.partial(jax.jit, static_argnames=("block_t", "block_w", "interpret"))
def rglru_scan(a_log: jnp.ndarray, b: jnp.ndarray, *, block_t: int = 256,
               block_w: int = 512,
               interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """a_log, b: (B, S, W) → (h (B, S, W) f32, h_last (B, W) f32)."""
    B, S, W = a_log.shape
    bt = min(block_t, S)
    bw = min(block_w, W)
    assert S % bt == 0 and W % bw == 0
    nt, nw = S // bt, W // bw

    kernel = functools.partial(_rglru_kernel, block_t=bt, n_tblocks=nt)
    h, h_last = pl.pallas_call(
        kernel,
        grid=(B, nw, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bw), lambda bi, iw, it: (bi, it, iw)),
            pl.BlockSpec((1, bt, bw), lambda bi, iw, it: (bi, it, iw)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bw), lambda bi, iw, it: (bi, it, iw)),
            pl.BlockSpec((1, bw), lambda bi, iw, it: (bi, iw)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_log, b)
    return h, h_last
