"""Pallas TPU kernels for the serving/training compute hot-spots.

Dora's contribution is planner-level, but the plans it emits execute
real model stages; the four hot-spots below dominate that compute on
the assigned architectures and ship as Pallas kernels with pure-jnp
oracles (``ref.py``) and backend dispatch (``ops.py``):

* ``flash_attention``  — causal/SWA/GQA flash attention (train/prefill)
* ``decode_attention`` — split-KV flash decode vs a 32k cache
* ``ssd_scan``         — Mamba-2 SSD chunked scan (carried state)
* ``rglru_scan``       — RG-LRU linear recurrence (doubling scan)
"""
from .ops import (decode_attention, flash_attention, rglru_scan, ssd_scan,
                  use_pallas)

__all__ = ["decode_attention", "flash_attention", "rglru_scan", "ssd_scan",
           "use_pallas"]
