"""Flash attention (causal / sliding-window / GQA) as a Pallas TPU kernel.

TPU-native adaptation of the standard flash algorithm:

* grid = (batch, q_heads, Q blocks, KV blocks); the KV dimension is the
  innermost, sequential ("arbitrary") axis so the running softmax state
  lives in VMEM scratch across KV steps.
* BlockSpec tiling keeps the working set in VMEM: a (block_q, head_dim)
  query tile, (block_k, head_dim) K/V tiles and a f32 accumulator.
  head_dim is the lane dimension (128 on the assigned models), so the
  MXU sees (block_q × head_dim) @ (head_dim × block_k) matmuls.
* GQA indexes the KV head as ``h // group_size`` in the BlockSpec index
  map — K/V tiles are never materialized per q-head.
* causal + sliding-window masking is applied from block coordinates;
  tiles that are fully masked skip their matmuls via ``pl.when``.

Validated against ``ref.mha_reference`` in interpret mode on CPU
(tests/test_kernels.py sweeps shapes, windows and dtypes).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int,
                  seq_k: int, causal: bool, window: Optional[int],
                  n_kblocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # tile-level reachability: skip tiles fully above the causal diagonal
    # or entirely left of the sliding window
    run = k_start < seq_k
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = kpos < seq_k
        if causal:
            ok = jnp.logical_and(ok, kpos <= qpos)
        if window is not None:
            ok = jnp.logical_and(ok, kpos > qpos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(ik == n_kblocks - 1)
    def _finish():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, :, 0, :] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, S, H, d); k/v: (B, T, KV, d) with H % KV == 0 → (B, S, H, d)."""
    B, S, H, d = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale_v = float(scale) if scale is not None else d ** -0.5

    bq = min(block_q, S)
    bk = min(block_k, T)
    nq = pl.cdiv(S, bq)
    nk = pl.cdiv(T, bk)

    kernel = functools.partial(
        _flash_kernel, scale=scale_v, block_q=bq, block_k=bk,
        seq_k=T, causal=causal, window=window, n_kblocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b, h, iq, ik: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # running max
            pltpu.VMEM((bq,), jnp.float32),        # running sum
            pltpu.VMEM((bq, d), jnp.float32),      # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
