"""Split-KV flash-decode attention as a Pallas TPU kernel.

One new token attends to a (B, T, KV, d) cache. The KV sequence is
split into tiles that stream through VMEM (the whole 32k decode cache
never fits); the running (max, sum, acc) softmax state is carried in
scratch across tiles — the same log-sum-exp rescaling that lets the
sharded serve-path combine per-shard partial attention with a psum.

``cache_len`` (B,) arrives via scalar prefetch so the kernel masks
invalid cache rows (and the ring-buffer window) without host branching.

Grid = (B, H, KV tiles); KV innermost/sequential. GQA maps q-head h to
cache head h // G in the BlockSpec index maps.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

NEG_INF = -2.0e38


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_k: int, window: Optional[int],
                   n_kblocks: int):
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = len_ref[b]
    k_start = ik * block_k
    run = k_start < valid

    @pl.when(run)
    def _step():
        q = q_ref[0, 0, 0, :].astype(jnp.float32)          # (d,)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.sum(k * q[None, :], axis=1) * scale        # (bk,)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        ok = kpos < valid
        if window is not None:
            ok = jnp.logical_and(ok, kpos > valid - 1 - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[0]
        m_cur = jnp.maximum(m_prev, jnp.max(s))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_scr[0] = l_scr[0] * alpha + jnp.sum(p)
        acc_scr[...] = acc_scr[...] * alpha + jnp.sum(
            p[:, None] * v, axis=0, keepdims=True)
        m_scr[0] = m_cur

    @pl.when(ik == n_kblocks - 1)
    def _finish():
        l = l_scr[0]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0, 0, :] = (acc_scr[0] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "block_k", "interpret"))
def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len: jnp.ndarray, *,
                     window: Optional[int] = None,
                     scale: Optional[float] = None, block_k: int = 256,
                     interpret: bool = False) -> jnp.ndarray:
    """q: (B, 1, H, d); k/v cache: (B, T, KV, d); cache_len: (B,) int32.
    Returns (B, 1, H, d)."""
    B, _, H, d = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale_v = float(scale) if scale is not None else d ** -0.5
    bk = min(block_k, T)
    nk = pl.cdiv(T, bk)

    kernel = functools.partial(_decode_kernel, scale=scale_v, block_k=bk,
                               window=window, n_kblocks=nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda b, h, ik, lens: (b, 0, h, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b, h, ik, lens: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b, h, ik, lens: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda b, h, ik, lens: (b, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cache_len.astype(jnp.int32), q, k_cache, v_cache)
