"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These re-export the model-zoo reference implementations so kernels and
models are validated against a single source of truth.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from ..models.attention import decode_attention_ref, gqa_attention
from ..models.rglru import _rglru_scan
from ..models.ssm import ssd_chunked as ssd_scan_ref


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None) -> jnp.ndarray:
    return gqa_attention(q, k, v, causal=causal, window=window, scale=scale)


def rglru_scan_ref(a_log: jnp.ndarray, b: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Matches kernels.rglru_scan's (a_log, b) interface: the oracle's
    gating (b = sqrt(1 - a²)·x) is inverted out by passing xg = b/√(1-a²)."""
    gate = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * a_log), 1e-12))
    h, h_last = _rglru_scan(b / gate, a_log, None)
    return h, h_last


__all__ = ["flash_attention_ref", "decode_attention_ref", "ssd_scan_ref",
           "rglru_scan_ref", "gqa_attention"]
