"""Analytic FLOP counts for the kernel hot-spots.

The calibration microbenchmarks (``repro.calibrate``) time the real
kernel entry points in ``ops.py`` and need a matching analytic count to
turn seconds into an achieved-FLOP/s rate (and from there into a
``ProfiledCosts`` compute factor).  Counts follow the usual 2-FLOPs-per
-MAC convention and only count the dominant contractions — softmax,
masking and elementwise gates are ignored, exactly as the planning
graph's ``graph_builders`` do, so kernel rates and graph rates are
comparable.
"""
from __future__ import annotations


def flash_attention_flops(B: int, S: int, H: int, KV: int, d: int) -> float:
    """Causal flash attention over (B, S, H, d) queries / (B, S, KV, d)
    keys+values: QK^T and PV score contractions (causal halves both)."""
    return 2.0 * 2.0 * B * H * S * S * d * 0.5


def decode_attention_flops(B: int, T: int, H: int, d: int) -> float:
    """One decode step against a T-long KV cache."""
    return 2.0 * 2.0 * B * H * T * d


def ssd_scan_flops(B: int, S: int, H: int, P: int, G: int, N: int) -> float:
    """Mamba-2 SSD chunked scan: per-token state update + output read
    (x·Bᵀ outer product into (P, N) state, C·state read-out)."""
    return 2.0 * 3.0 * B * S * H * P * N


def rglru_scan_flops(B: int, S: int, W: int) -> float:
    """RG-LRU linear recurrence h_t = a_t·h_{t-1} + b_t (one MAC per
    element per step)."""
    return 2.0 * B * S * W


def mlp_block_flops(batch: int, d_model: int, d_ff: int,
                    gated: bool = True) -> float:
    """Gated (3-matmul) or plain (2-matmul) MLP block forward."""
    mats = 3 if gated else 2
    return 2.0 * mats * batch * d_model * d_ff
