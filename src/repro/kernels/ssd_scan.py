"""Mamba-2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060, `ssd_minimal`):

* grid = (batch, heads, chunks); the chunk axis is innermost and
  sequential — the (P, N) recurrent state lives in VMEM scratch and is
  carried across chunk steps (h_{c+1} = decay_c · h_c + states_c).
* per (head, chunk) tile the kernel computes the quadratic *dual form*
  intra-chunk (an (L, L) masked "attention" matmul — MXU work), plus
  the rank-1 inter-chunk contribution from the carried state.
* Per-head tiling keeps VMEM small: x tile (L, P), b/c tiles (L, N),
  the (L, L) decay matrix, and the f32 (P, N) state — ~0.5 MB at
  L=256, P=64, N=128.
* GQA-style B/C groups index as ``h // (H // G)`` in the BlockSpec maps.

Outputs y (B, S, H, P) and the final state (B, H, P, N) — the latter
seeds the O(1) recurrent decode path.

Oracle: ``repro.models.ssm.ssd_chunked`` (pure jnp).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, hfin_ref, state_scr, *,
                chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    a = a_ref[0, :, 0].astype(jnp.float32)              # (L,) log-decay ≤ 0
    x = x_ref[0, :, 0, :].astype(jnp.float32)           # (L, P)
    b = b_ref[0, :, 0, :].astype(jnp.float32)           # (L, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)           # (L, N)

    a_cum = jnp.cumsum(a)                               # (L,)
    # intra-chunk dual form: masked decay "attention"
    seg = a_cum[:, None] - a_cum[None, :]               # sum a over (j, i]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(col <= row, jnp.exp(seg), 0.0)     # (L, L)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (L, L)
    y_diag = jax.lax.dot_general(lmat * cb, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state + state update
    state = state_scr[...]                              # (P, N)
    y_off = jax.lax.dot_general(c, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        * jnp.exp(a_cum)[:, None]                       # (L, P)
    decay_states = jnp.exp(a_cum[-1] - a_cum)           # (L,)
    states_new = jax.lax.dot_general(
        x * decay_states[:, None], b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # (P, N)
    state_scr[...] = jnp.exp(a_cum[-1]) * state + states_new

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        hfin_ref[0, 0, :, :] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, a_log: jnp.ndarray, b: jnp.ndarray,
             c: jnp.ndarray, *, chunk: int = 256,
             interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, H, P) pre-scaled by dt; a_log: (B, S, H); b/c: (B, S, G, N).
    Returns (y (B, S, H, P), final_state (B, H, P, N) f32)."""
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    L = min(chunk, S)
    assert S % L == 0, f"seq {S} % chunk {L} != 0"
    nc = S // L

    kernel = functools.partial(_ssd_kernel, chunk=L, n_chunks=nc)
    y, hfin = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda bi, h, ic: (bi, ic, h, 0)),
            pl.BlockSpec((1, L, 1), lambda bi, h, ic: (bi, ic, h)),
            pl.BlockSpec((1, L, 1, N), lambda bi, h, ic: (bi, ic, h // rep, 0)),
            pl.BlockSpec((1, L, 1, N), lambda bi, h, ic: (bi, ic, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, P), lambda bi, h, ic: (bi, ic, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, ic: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, a_log, b, c)
    return y, hfin
