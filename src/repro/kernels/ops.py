"""Public kernel entry points with backend dispatch.

On a TPU backend the Pallas kernels compile natively; on CPU they run
under ``interpret=True`` (the kernel body executes step-by-step — exact
semantics, no Mosaic) or fall back to the pure-jnp references for bulk
work. Selection:

* ``REPRO_KERNELS=pallas``    — force Pallas (interpret on CPU)
* ``REPRO_KERNELS=ref``       — force the jnp references
* ``REPRO_KERNELS=auto``      — Pallas on TPU, references elsewhere
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import flash_attention as _flash_pallas
from .rglru_scan import rglru_scan as _rglru_pallas
from .ssd_scan import ssd_scan as _ssd_pallas


def _mode() -> str:
    return os.environ.get("REPRO_KERNELS", "auto")


def use_pallas() -> bool:
    m = _mode()
    if m == "pallas":
        return True
    if m == "ref":
        return False
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None) -> jnp.ndarray:
    if use_pallas():
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             scale=scale, interpret=_interpret())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   scale=scale)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: Optional[int] = None,
                     scale: Optional[float] = None) -> jnp.ndarray:
    if use_pallas():
        return _decode_pallas(q, k_cache, v_cache, cache_len, window=window,
                              scale=scale, interpret=_interpret())
    return ref.decode_attention_ref(q, k_cache, v_cache, cache_len,
                                    window=window, scale=scale)


def ssd_scan(x, a_log, b, c, *, chunk: int = 256
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    S = x.shape[1]
    chunk = min(chunk, S)
    if use_pallas() and S % chunk == 0:
        return _ssd_pallas(x, a_log, b, c, chunk=chunk,
                           interpret=_interpret())
    return ref.ssd_scan_ref(x, a_log, b, c, chunk=chunk)


def rglru_scan(a_log, b, *, block_t: int = 256
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    _, S, W = a_log.shape
    bt, bw = min(block_t, S), min(512, W)
    if use_pallas() and S % bt == 0 and W % bw == 0:
        return _rglru_pallas(a_log, b, block_t=bt, block_w=bw,
                             interpret=_interpret())
    return ref.rglru_scan_ref(a_log, b)
