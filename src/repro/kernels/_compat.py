"""Pallas version compatibility shared by all kernels."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names it TPUCompilerParams; ≥0.5 renamed it CompilerParams
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams", None)
if CompilerParams is None:  # pragma: no cover - future jax renames
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; update src/repro/kernels/_compat.py for this "
        "jax version")
