"""Token data pipeline: deterministic synthetic stream or memmapped
binary corpus, sharded placement onto the active mesh, background
prefetch.

The synthetic stream is a Zipf-ish unigram mixture with Markov
structure so small models show a real, decreasing loss (needed by the
end-to-end training example) while remaining fully reproducible.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus_path: Optional[str] = None     # .bin of uint16/uint32 tokens
    prefetch: int = 2


def synthetic_stream(cfg: DataConfig) -> Iterator[np.ndarray]:
    """Yields (global_batch, seq_len+1) int32 token blocks."""
    rng = np.random.default_rng(cfg.seed)
    v = cfg.vocab_size
    # Zipf unigram + first-order Markov "phrases" for learnable structure
    base = 1.0 / np.arange(1, v + 1) ** 1.1
    base /= base.sum()
    shift = rng.integers(1, v - 1)
    while True:
        block = np.empty((cfg.global_batch, cfg.seq_len + 1), np.int32)
        cur = rng.choice(v, size=cfg.global_batch, p=base)
        for t in range(cfg.seq_len + 1):
            block[:, t] = cur
            follow = (cur + shift) % v        # deterministic successor
            pick = rng.random(cfg.global_batch) < 0.65
            cur = np.where(pick, follow, rng.choice(v, size=cfg.global_batch, p=base))
        yield block


def _corpus_stream(cfg: DataConfig) -> Iterator[np.ndarray]:
    data = np.memmap(cfg.corpus_path, dtype=np.uint16, mode="r")
    n_tok = cfg.global_batch * (cfg.seq_len + 1)
    rng = np.random.default_rng(cfg.seed)
    while True:
        starts = rng.integers(0, len(data) - cfg.seq_len - 1, cfg.global_batch)
        block = np.stack([data[s:s + cfg.seq_len + 1] for s in starts])
        yield block.astype(np.int32)


class TokenPipeline:
    """Prefetching iterator of sharded training batches."""

    def __init__(self, cfg: DataConfig, mesh=None,
                 batch_spec: P = P(("pod", "data"), None)):
        self.cfg = cfg
        self.mesh = mesh
        self.batch_spec = batch_spec
        self._stream = _corpus_stream(cfg) if cfg.corpus_path else synthetic_stream(cfg)
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        for block in self._stream:
            if self._stop.is_set():
                return
            self._q.put(block)

    def _place(self, arr: np.ndarray):
        if self.mesh is None:
            return jax.numpy.asarray(arr)
        names = set(self.mesh.axis_names)
        entries = []
        for e in self.batch_spec:
            if isinstance(e, tuple):
                kept = tuple(a for a in e if a in names)
                entries.append(kept if kept else None)
            else:
                entries.append(e if (e is None or e in names) else None)
        sharding = NamedSharding(self.mesh, P(*entries))
        return jax.device_put(arr, sharding)

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        block = self._q.get()
        tokens = self._place(np.ascontiguousarray(block[:, :-1]))
        labels = self._place(np.ascontiguousarray(block[:, 1:]))
        return {"tokens": tokens, "labels": labels}

    def close(self) -> None:
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
