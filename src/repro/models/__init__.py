from .config import ArchConfig
from .registry import Model, build_model, planning_graph
from .transformer import LM
from .encdec import EncDecLM

__all__ = ["ArchConfig", "Model", "build_model", "planning_graph", "LM", "EncDecLM"]
