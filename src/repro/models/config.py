"""Unified architecture config covering every assigned model family."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | ssm | hybrid | moe | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    gated_mlp: bool = True
    act: str = "silu"              # silu | gelu
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # -- attention variants --------------------------------------------------
    window: Optional[int] = None   # sliding-window attention (SWA)
    prefix_len: int = 0            # prefix-LM bidirectional span (VLM)

    # -- MoE ------------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0              # per-expert hidden dim
    n_dense_layers: int = 0        # leading dense layers (DeepSeek-V2: 1)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_groups: int = 0            # dispatch groups (0 = auto; see mlp.py)

    # -- MLA (DeepSeek) ---------------------------------------------------------
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # -- SSM (Mamba2 / SSD) -------------------------------------------------------
    ssm: bool = False
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # -- hybrid (RecurrentGemma) -----------------------------------------------------
    block_pattern: Tuple[str, ...] = ()    # e.g. ("rglru", "rglru", "attn")
    lru_width: Optional[int] = None
    conv_width: int = 4

    # -- encoder-decoder (Whisper) ------------------------------------------------------
    encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500            # conv-frontend output frames (stub input)

    # -- VLM (PaliGemma) -------------------------------------------------------------------
    vision_stub: bool = False
    n_patches: int = 256

    # -- numerics / padding ---------------------------------------------------------------
    dtype: str = "bfloat16"
    vocab_pad: int = 256
    max_seq: int = 8192            # positional table length where applicable
    scan_unroll: bool = False      # unroll layer scans (dry-run cost probes)
    attn_chunk: int = 2048         # query-chunk attention above this seq len

    # -------------------------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return int(math.ceil(self.vocab_size / self.vocab_pad) * self.vocab_pad)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def lru_dim(self) -> int:
        return self.lru_width or self.d_model

    def param_count(self) -> float:
        """Analytic parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.hd
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0.0
        if self.ssm:
            din = self.d_inner
            per_layer = d * (2 * din + 2 * self.ssm_ngroups * self.ssm_state
                             + self.ssm_nheads) + din * d \
                + self.ssm_conv * (din + 2 * self.ssm_ngroups * self.ssm_state) \
                + 2 * self.ssm_nheads
            return emb + self.n_layers * per_layer
        if self.mla:
            attn = (d * self.q_lora_rank
                    + self.q_lora_rank * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        else:
            attn = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
        mats = 3 if self.gated_mlp else 2
        dense_mlp = mats * d * self.d_ff
        if self.n_experts:
            moe_mlp = mats * d * self.moe_d_ff * (self.n_experts + self.n_shared_experts) \
                + d * self.n_experts
            n_moe = self.n_layers - self.n_dense_layers
            total = emb + self.n_layers * attn + self.n_dense_layers * dense_mlp \
                + n_moe * moe_mlp
            return total
        total_layers = self.n_layers + (self.n_enc_layers if self.encdec else 0)
        per = attn + dense_mlp
        if self.encdec:
            per = per  # decoder layers also carry cross-attention
            total = emb + self.n_layers * (attn * 2 + dense_mlp) \
                + self.n_enc_layers * (attn + dense_mlp)
            return total
        if self.block_pattern:
            # hybrid: count recurrent vs attention blocks
            n = self.n_layers
            pat = [self.block_pattern[i % len(self.block_pattern)] for i in range(n)]
            lru = self.lru_dim
            rec = d * lru * 2 + lru * d + 2 * lru * self.conv_width + 4 * lru
            total = emb
            for kind in pat:
                total += dense_mlp + (rec if kind == "rglru" else attn)
            return total
        return emb + self.n_layers * per

    def active_param_count(self) -> float:
        """Active (per-token) parameters — MoE top-k only."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        mats = 3 if self.gated_mlp else 2
        full = self.param_count()
        all_experts = mats * d * self.moe_d_ff * self.n_experts
        active = mats * d * self.moe_d_ff * self.experts_per_token
        n_moe = self.n_layers - self.n_dense_layers
        return full - n_moe * (all_experts - active)
