"""Sharding helpers usable both under a mesh (pjit) and on bare CPU."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def abstract_mesh(axis_sizes, axis_names):
    """Version-agnostic ``AbstractMesh`` construction.

    jax ≥ 0.5 takes ``AbstractMesh(axis_sizes, axis_names)``; jax 0.4.x
    takes a single ``shape_tuple`` of (name, size) pairs. Both expose
    ``axis_names`` / ``axis_sizes`` on the result.
    """
    from jax.sharding import AbstractMesh

    axis_sizes = tuple(axis_sizes)
    axis_names = tuple(axis_names)
    try:
        return AbstractMesh(axis_sizes, axis_names)
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def mesh_axes() -> tuple:
    """Axis names of the ambient mesh ('' tuple when unsharded)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return ()
    if mesh is None or mesh.empty:
        return ()
    return tuple(mesh.axis_names)


def maybe_shard(x: jnp.ndarray, spec: Optional[P]) -> jnp.ndarray:
    """Apply a sharding constraint when a mesh is active; no-op otherwise.

    Axis names in ``spec`` that the ambient mesh lacks are dropped, so the
    same model code runs in smoke tests (1 CPU device), the single-pod
    mesh ('data','model') and the multi-pod mesh ('pod','data','model').
    """
    if spec is None:
        return x
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if mesh is None or mesh.empty:
        return x
    axes = tuple(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.shape.values())) \
        if hasattr(mesh.shape, "values") else dict(mesh.shape)
    cleaned = []
    for i, entry in enumerate(spec):
        if entry is None:
            cleaned.append(None)
            continue
        names = tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)
        kept = tuple(a for a in names if a in axes)
        total = 1
        for a in kept:
            total *= sizes[a]
        # drop constraints that do not divide the dim (batch=1 long-context)
        if not kept or (i < x.ndim and x.shape[i] % total != 0):
            cleaned.append(None)
        else:
            cleaned.append(kept if len(kept) > 1 else kept[0])
    return jax.lax.with_sharding_constraint(x, P(*cleaned))


# canonical logical specs used across the model zoo ----------------------------
BATCH = ("pod", "data")     # batch dim shards over pod+data

def batch_spec(*rest) -> P:
    return P(BATCH, *rest)
