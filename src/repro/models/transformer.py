"""Unified decoder-only language model covering every assigned family.

One ``LM`` object per ``ArchConfig`` exposes:

    init(rng)                          → params
    apply(params, tokens, ...)        → logits          (train / eval)
    loss(params, batch)               → (scalar, aux)
    init_cache(batch, max_len)        → cache pytree
    prefill(params, tokens, cache)    → (logits, cache)
    decode(params, token, cache, pos) → (logits, cache)

Layer stacks run under ``jax.lax.scan`` with stacked parameters (compile
time at 512 devices stays flat in depth); heterogeneous-pattern models
(RecurrentGemma 2:1, DeepSeek dense-first) scan over *pattern units*
with the remainder unrolled.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as attn_lib
from .common import apply_rope, dense_init, dtype_of, embed_init, rms_norm, split_keys
from .config import ArchConfig
from .mlp import apply_mlp, apply_moe, init_mlp, init_moe
from .rglru import apply_rglru, init_rglru, rglru_state_shape
from .sharding_utils import maybe_shard
from .ssm import (apply_mamba2, apply_mamba2_decode, init_mamba2,
                  mamba2_state_shape)


# ==============================================================================
# per-layer init
# ==============================================================================
def init_attn(key, cfg: ArchConfig, dtype) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = split_keys(key, 4)
    p = {"wq": dense_init(ks[0], (d, h, hd), dtype),
         "wk": dense_init(ks[1], (d, kv, hd), dtype),
         "wv": dense_init(ks[2], (d, kv, hd), dtype),
         "wo": dense_init(ks[3], (h, hd, d), dtype, fan_in=h * hd)}
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def init_mla(key, cfg: ArchConfig, dtype) -> Dict:
    d, h = cfg.d_model, cfg.n_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = split_keys(key, 7)
    return {
        "wq_a": dense_init(ks[0], (d, rq), dtype),
        "q_norm": jnp.zeros((rq,), jnp.float32),
        "wq_nope": dense_init(ks[1], (rq, h, dn), dtype, fan_in=rq),
        "wq_rope": dense_init(ks[2], (rq, h, dr), dtype, fan_in=rq),
        "wkv_a": dense_init(ks[3], (d, rkv + dr), dtype),
        "kv_norm": jnp.zeros((rkv,), jnp.float32),
        "wk_nope": dense_init(ks[4], (rkv, h, dn), dtype, fan_in=rkv),
        "wv": dense_init(ks[5], (rkv, h, dv), dtype, fan_in=rkv),
        "wo": dense_init(ks[6], (h, dv, d), dtype, fan_in=h * dv),
    }


def init_block(key, cfg: ArchConfig, kind: str, dtype) -> Dict:
    """kind ∈ {dense, moe, dense_mlp, ssm, rec, local_attn}."""
    ks = split_keys(key, 3)
    p: Dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if kind == "ssm":
        p["mixer"] = init_mamba2(ks[0], cfg, dtype)
        return p
    if kind == "rec":
        p["mixer"] = init_rglru(ks[0], cfg, dtype)
    elif kind in ("dense", "moe", "dense_mlp", "local_attn"):
        p["mixer"] = init_mla(ks[0], cfg, dtype) if cfg.mla \
            else init_attn(ks[0], cfg, dtype)
    p["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if kind == "moe":
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    return p


# ==============================================================================
# per-layer apply (mode: train | prefill | decode)
# ==============================================================================
def _project_qkv(p: Dict, x: jnp.ndarray, cfg: ArchConfig,
                 positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def apply_attn(p: Dict, x: jnp.ndarray, cfg: ArchConfig, *, mode: str,
               cache: Optional[Dict], pos, window: Optional[int],
               prefix_len: int = 0,
               cross_kv: Optional[Tuple] = None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, D = x.shape
    if cross_kv is not None:          # encoder-decoder cross attention
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k, v = cross_kv
        o = attn_lib.gqa_attention(q, k, v, causal=False)
        return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), None

    if mode == "decode":
        positions = pos[:, None] if pos.ndim == 1 else pos
        q, k, v = _project_qkv(p, x, cfg, positions)
        t_buf = cache["k"].shape[1]
        ring = window is not None and t_buf <= window
        slot = pos % t_buf if ring else pos
        kc = _write_cache(cache["k"], k, slot)
        vc = _write_cache(cache["v"], v, slot)
        if ring:
            # ring holds exactly the in-window tokens; no window re-mask
            valid = jnp.minimum(pos + 1, t_buf)
            o = attn_lib.decode_attention(q, kc, vc, valid, window=None)
        else:
            o = attn_lib.decode_attention(q, kc, vc, pos + 1, window=window)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        return out, {"k": kc, "v": vc}

    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions)
    if S > cfg.attn_chunk:
        o = attn_lib.gqa_attention_chunked(q, k, v, causal=True, window=window,
                                           prefix_len=prefix_len,
                                           q_chunk=cfg.attn_chunk // 4)
    else:
        o = attn_lib.gqa_attention(q, k, v, causal=True, window=window,
                                   prefix_len=prefix_len)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    new_cache = None
    if mode == "prefill":
        kc = _fit_cache(cache["k"], k)
        vc = _fit_cache(cache["v"], v)
        new_cache = {"k": kc, "v": vc}
    return out, new_cache


def _write_cache(cache: jnp.ndarray, kv: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """Write (B,1,KV,hd) at per-batch position ``pos`` (uniform scalar)."""
    return jax.lax.dynamic_update_slice_in_dim(cache, kv.astype(cache.dtype),
                                               pos[0], axis=1)


def _fit_cache(cache: jnp.ndarray, kv: jnp.ndarray) -> jnp.ndarray:
    """Place prefill K/V into the cache buffer. When the prefill is longer
    than a (windowed) ring buffer, keep the last T_buf entries laid out at
    their ring slots (slot = absolute_pos % T_buf)."""
    t_buf = cache.shape[1]
    s = kv.shape[1]
    if s <= t_buf:
        return jax.lax.dynamic_update_slice_in_dim(cache, kv.astype(cache.dtype),
                                                   0, axis=1)
    last = kv[:, -t_buf:].astype(cache.dtype)
    return jnp.roll(last, s % t_buf, axis=1)


def apply_mla_block(p: Dict, x: jnp.ndarray, cfg: ArchConfig, *, mode: str,
                    cache: Optional[Dict], pos) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, D = x.shape
    cq = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    kv_a = x @ p["wkv_a"]
    ckv, k_rope = jnp.split(kv_a, [cfg.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    if mode == "decode":
        positions = pos[:, None]
        k_rope_rot = apply_rope(k_rope[:, :, None, :], positions,
                                cfg.rope_theta)[:, :, 0]
        ckv_c = _write_cache(cache["ckv"], ckv, pos)
        kr_c = _write_cache(cache["krope"], k_rope_rot, pos)
        o = attn_lib.mla_decode(cq, ckv_c, kr_c, pos + 1,
                                p["wq_nope"], p["wq_rope"], p["wk_nope"], p["wv"],
                                rope_theta=cfg.rope_theta)
        return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), {"ckv": ckv_c, "krope": kr_c}
    o = attn_lib.mla_prefill(cq, ckv, k_rope, p["wq_nope"], p["wq_rope"],
                             p["wk_nope"], p["wv"], rope_theta=cfg.rope_theta,
                             q_chunk=cfg.attn_chunk // 4 if S > cfg.attn_chunk else None)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    new_cache = None
    if mode == "prefill":
        positions = jnp.arange(S)[None, :]
        k_rope_rot = apply_rope(k_rope[:, :, None, :], positions,
                                cfg.rope_theta)[:, :, 0]
        new_cache = {"ckv": _fit_cache(cache["ckv"], ckv),
                     "krope": _fit_cache(cache["krope"], k_rope_rot)}
    return out, new_cache


def apply_block(p: Dict, x: jnp.ndarray, cfg: ArchConfig, kind: str, *,
                mode: str = "train", cache=None, pos=None,
                prefix_len: int = 0) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "ssm":
        if mode == "decode":
            y, new_cache = apply_mamba2_decode(p["mixer"], h, cfg, cache)
        else:
            y, new_cache = apply_mamba2(p["mixer"], h, cfg,
                                        None if mode == "train" else None)
            new_cache = new_cache if mode == "prefill" else None
        return x + y, new_cache, aux
    if kind == "rec":
        y, new_cache = apply_rglru(p["mixer"], h, cfg,
                                   cache if mode == "decode" else None)
        if mode == "train":
            new_cache = None
    elif cfg.mla and kind in ("dense", "moe", "dense_mlp"):
        y, new_cache = apply_mla_block(p["mixer"], h, cfg, mode=mode,
                                       cache=cache, pos=pos)
    else:
        window = cfg.window if kind in ("dense", "moe", "dense_mlp") else cfg.window
        if kind == "local_attn":
            window = cfg.window or 2048
        y, new_cache = apply_attn(p["mixer"], h, cfg, mode=mode, cache=cache,
                                  pos=pos, window=window, prefix_len=prefix_len)
    x = x + y
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        y2, aux = apply_moe(p["moe"], h2, cfg)
    else:
        y2 = apply_mlp(p["mlp"], h2, cfg.act)
    x = x + y2
    x = maybe_shard(x, P(("pod", "data"), "model", None))
    return x, new_cache, aux


# ==============================================================================
# the LM
# ==============================================================================
@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ArchConfig

    # -- structure ------------------------------------------------------------
    def layer_kinds(self) -> Tuple[str, ...]:
        cfg = self.cfg
        if cfg.ssm:
            return ("ssm",) * cfg.n_layers
        if cfg.block_pattern:
            pat = cfg.block_pattern
            return tuple(pat[i % len(pat)] for i in range(cfg.n_layers))
        if cfg.n_experts:
            return ("dense_mlp",) * cfg.n_dense_layers + \
                ("moe",) * (cfg.n_layers - cfg.n_dense_layers)
        return ("dense",) * cfg.n_layers

    def scan_groups(self) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
        """(unit_pattern, n_units, tail_kinds): layers = unit×n + tail."""
        kinds = self.layer_kinds()
        cfg = self.cfg
        if cfg.block_pattern:
            u = len(cfg.block_pattern)
            n_units = cfg.n_layers // u
            return tuple(cfg.block_pattern), n_units, kinds[n_units * u:]
        if cfg.n_experts and cfg.n_dense_layers:
            nd = cfg.n_dense_layers
            return ("moe",), cfg.n_layers - nd, kinds[:nd]   # tail = leading dense
        return (kinds[0],), cfg.n_layers, ()

    # -- init -------------------------------------------------------------------
    def init(self, rng) -> Dict:
        cfg = self.cfg
        dtype = dtype_of(cfg.dtype)
        unit, n_units, tail = self.scan_groups()
        k_emb, k_stack, k_tail, k_out = jax.random.split(rng, 4)
        params: Dict[str, Any] = {
            "embed": embed_init(k_emb, (cfg.padded_vocab, cfg.d_model), dtype),
            "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = dense_init(k_out, (cfg.d_model, cfg.padded_vocab),
                                           dtype)
        def unit_init(key):
            ks = split_keys(key, len(unit))
            return {f"u{i}": init_block(ks[i], cfg, kind, dtype)
                    for i, kind in enumerate(unit)}
        params["stack"] = jax.vmap(unit_init)(
            jax.random.split(k_stack, n_units))
        if tail:
            ks = split_keys(k_tail, len(tail))
            params["tail"] = {f"t{i}": init_block(ks[i], cfg, kind, dtype)
                              for i, kind in enumerate(tail)}
        return params

    # -- caches -------------------------------------------------------------------
    def _block_cache_shape(self, kind: str, batch: int, max_len: int, dtype):
        cfg = self.cfg
        if kind == "ssm":
            return mamba2_state_shape(cfg, batch, dtype)
        if kind == "rec":
            return rglru_state_shape(cfg, batch, dtype)
        if cfg.mla:
            return {"ckv": ((batch, max_len, cfg.kv_lora_rank), dtype),
                    "krope": ((batch, max_len, cfg.qk_rope_dim), dtype)}
        cache_len = max_len
        if kind == "local_attn" or (cfg.window and not cfg.block_pattern):
            cache_len = min(max_len, (cfg.window or max_len))
        return {"k": ((batch, cache_len, cfg.n_kv_heads, cfg.hd), dtype),
                "v": ((batch, cache_len, cfg.n_kv_heads, cfg.hd), dtype)}

    def init_cache(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        dtype = dtype_of(cfg.dtype)
        unit, n_units, tail = self.scan_groups()
        stack_cache = {}
        for i, kind in enumerate(unit):
            sh = self._block_cache_shape(kind, batch, max_len, dtype)
            stack_cache[f"u{i}"] = jax.tree.map(
                lambda sd: jnp.zeros((n_units,) + sd[0], sd[1]), sh,
                is_leaf=_is_shape_leaf)
        cache: Dict[str, Any] = {"stack": stack_cache}
        if tail:
            cache["tail"] = {
                f"t{i}": zeros_from(self._block_cache_shape(tk, batch, max_len, dtype))
                for i, tk in enumerate(tail)}
        return cache

    # -- forward (train/eval) -------------------------------------------------------
    def apply(self, params: Dict, tokens: jnp.ndarray, *,
              prefix_len: int = 0, extra_embeddings: Optional[jnp.ndarray] = None,
              remat: str = "full") -> Tuple[jnp.ndarray, jnp.ndarray]:
        """tokens (B, S) → (logits (B, S, V), aux_loss). ``extra_embeddings``
        (B, P, D) are prepended (VLM patch / audio frame stubs)."""
        cfg = self.cfg
        x = params["embed"][tokens]
        if extra_embeddings is not None:
            x = jnp.concatenate([extra_embeddings.astype(x.dtype), x], axis=1)
            prefix_len = max(prefix_len, extra_embeddings.shape[1])
        x = maybe_shard(x, P(("pod", "data"), "model", None))
        unit, n_units, tail = self.scan_groups()
        tail_first = bool(cfg.n_experts and cfg.n_dense_layers)

        def run_tail(x, aux):
            kinds = self.layer_kinds()
            tail_kinds = kinds[:len(tail)] if tail_first else kinds[cfg.n_layers - len(tail):]
            for i, kind in enumerate(tail_kinds):
                x, _, a = apply_block(params["tail"][f"t{i}"], x, cfg, kind,
                                      mode="train", prefix_len=prefix_len)
                aux = aux + a
            return x, aux

        aux0 = jnp.zeros((), jnp.float32)
        if tail and tail_first:
            x, aux0 = run_tail(x, aux0)

        block_fn = functools.partial(self._unit_apply, cfg=cfg, unit=unit,
                                     prefix_len=prefix_len)
        if remat == "full":
            block_fn = jax.remat(block_fn)
        elif remat == "dots":
            block_fn = jax.remat(
                block_fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

        def body(carry, unit_params):
            x, aux = carry
            x, a = block_fn(x, unit_params)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["stack"],
                                   unroll=cfg.scan_unroll)
        if tail and not tail_first:
            x, aux = run_tail(x, aux)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = self._head(params, x)
        return logits, aux

    @staticmethod
    def _unit_apply(x, unit_params, *, cfg, unit, prefix_len):
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(unit):
            x, _, a = apply_block(unit_params[f"u{i}"], x, cfg, kind,
                                  mode="train", prefix_len=prefix_len)
            aux = aux + a
        return x, aux

    def _head(self, params, x):
        cfg = self.cfg
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        logits = (x @ w).astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            bias = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                             0.0, attn_lib.NEG_INF)
            logits = logits + bias
        return logits

    # -- loss ----------------------------------------------------------------------
    def loss(self, params: Dict, batch: Dict, *, remat: str = "full"
             ) -> Tuple[jnp.ndarray, Dict]:
        logits, aux = self.apply(params, batch["tokens"], remat=remat,
                                 extra_embeddings=batch.get("extra_embeddings"))
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:      # VLM prefix rows carry no loss
            logits = logits[:, -labels.shape[1]:]
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
        nll = jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return nll + aux, {"nll": nll, "aux": aux}

    # -- prefill / decode -------------------------------------------------------------
    def prefill(self, params: Dict, tokens: jnp.ndarray, cache: Dict, *,
                extra_embeddings: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Dict]:
        return self._serve(params, tokens, cache, mode="prefill",
                           pos=None, extra_embeddings=extra_embeddings)

    def decode(self, params: Dict, token: jnp.ndarray, cache: Dict,
               pos: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
        """token (B, 1); pos (B,) — uniform position of the new token."""
        return self._serve(params, token, cache, mode="decode", pos=pos)

    def _serve(self, params, tokens, cache, *, mode, pos,
               extra_embeddings=None):
        cfg = self.cfg
        x = params["embed"][tokens]
        prefix_len = cfg.prefix_len
        if extra_embeddings is not None:
            x = jnp.concatenate([extra_embeddings.astype(x.dtype), x], axis=1)
            prefix_len = max(prefix_len, extra_embeddings.shape[1])
        unit, n_units, tail = self.scan_groups()
        tail_first = bool(cfg.n_experts and cfg.n_dense_layers)
        kinds = self.layer_kinds()
        tail_kinds = kinds[:len(tail)] if tail_first else \
            (kinds[cfg.n_layers - len(tail):] if tail else ())

        def run_tail(x, cache_tail):
            new_tail = {}
            for i, kind in enumerate(tail_kinds):
                x, nc, _ = apply_block(params["tail"][f"t{i}"], x, cfg, kind,
                                       mode=mode, cache=cache_tail[f"t{i}"],
                                       pos=pos, prefix_len=prefix_len)
                new_tail[f"t{i}"] = nc if nc is not None else cache_tail[f"t{i}"]
            return x, new_tail

        new_cache: Dict[str, Any] = {}
        if tail and tail_first:
            x, new_cache["tail"] = run_tail(x, cache["tail"])

        def body(x, xs):
            unit_params, unit_cache = xs
            new_uc = {}
            for i, kind in enumerate(unit):
                x, nc, _ = apply_block(unit_params[f"u{i}"], x, cfg, kind,
                                       mode=mode, cache=unit_cache[f"u{i}"],
                                       pos=pos, prefix_len=prefix_len)
                new_uc[f"u{i}"] = nc if nc is not None else unit_cache[f"u{i}"]
            return x, new_uc

        x, stack_cache = jax.lax.scan(body, x, (params["stack"], cache["stack"]),
                                      unroll=cfg.scan_unroll)
        new_cache["stack"] = stack_cache
        if tail and not tail_first:
            x, new_cache["tail"] = run_tail(x, cache["tail"])
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = self._head(params, x[:, -1:])
        return logits, new_cache


def _is_shape_leaf(x):
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)


def zeros_from(shapes):
    return jax.tree.map(lambda sd: jnp.zeros(sd[0], sd[1]), shapes,
                        is_leaf=_is_shape_leaf)
