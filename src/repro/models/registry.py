"""Model registry: ArchConfig → model object + planning-graph extractor."""
from __future__ import annotations

from typing import Union

from ..core.graph_builders import GraphSpec, build_lm_graph, build_multimodal_graph
from ..core.planning_graph import ModelGraph
from .config import ArchConfig
from .encdec import EncDecLM
from .transformer import LM

Model = Union[LM, EncDecLM]


def build_model(cfg: ArchConfig) -> Model:
    if cfg.encdec:
        return EncDecLM(cfg)
    return LM(cfg)


def planning_graph(cfg: ArchConfig, seq_len: int) -> ModelGraph:
    """Dora planning graph for any zoo architecture (first-class feature:
    every assigned arch can be planned for edge deployment)."""
    spec = GraphSpec(
        name=cfg.name, n_layers=cfg.n_layers, d_model=cfg.d_model,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        d_ff=cfg.d_ff or cfg.moe_d_ff, vocab=cfg.padded_vocab,
        head_dim=cfg.head_dim, gated_mlp=cfg.gated_mlp, seq_len=seq_len,
        n_experts=cfg.n_experts, experts_per_token=cfg.experts_per_token,
        ssm_state=cfg.ssm_state, attn_free=cfg.ssm)
    if cfg.encdec:
        spec = GraphSpec(**{**spec.__dict__,
                            "branches": (("enc", cfg.n_enc_layers, cfg.d_model),)})
        return build_multimodal_graph(spec, seq_len)
    if cfg.vision_stub:
        spec = GraphSpec(**{**spec.__dict__,
                            "branches": (("vision", 12, 1152),)})
        return build_multimodal_graph(spec, seq_len)
    return build_lm_graph(spec, seq_len)
