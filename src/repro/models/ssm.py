"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD implementation following the paper's ``ssd_minimal``
(quadratic intra-chunk + linear inter-chunk state passing) — this is
also the reference for ``repro.kernels.ssd_scan``. Decode is the O(1)
recurrent update carrying (B, H, P, N) state + a conv tail.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, rms_norm, split_keys
from .config import ArchConfig


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum a[..., j+1..i] (−inf j>i)."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum over (j, i]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, a_log: jnp.ndarray, b: jnp.ndarray,
                c: jnp.ndarray, chunk: int,
                h0: jnp.ndarray | None = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan.

    x: (B, S, H, P) inputs (already multiplied by dt);
    a_log: (B, S, H) per-step log-decay (dt·A, ≤ 0);
    b, c: (B, S, G, N) input/output projections (G groups, H % G == 0);
    Returns (y (B,S,H,P), final state (B,H,P,N)).
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    assert S % chunk == 0, f"seq {S} not divisible by chunk {chunk}"
    nc = S // chunk
    rep = H // G
    xb = x.reshape(B, nc, chunk, H, P)
    ab = a_log.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2)   # (B,H,nc,l)
    bb = b.reshape(B, nc, chunk, G, N)
    cb = c.reshape(B, nc, chunk, G, N)

    a_cum = jnp.cumsum(ab, axis=-1)                             # (B,H,nc,l)
    # intra-chunk (quadratic, "attention-like" dual form)
    Lmat = jnp.exp(_segsum(ab))                                 # (B,H,nc,l,l)
    cb_h = jnp.repeat(cb, rep, axis=3)                          # (B,nc,l,H,N)
    bb_h = jnp.repeat(bb, rep, axis=3)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        cb_h, bb_h, Lmat, xb)
    # chunk-final states (carried in f32 for decode-compatible precision)
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)             # (B,H,nc,l)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", bb_h, decay_states,
                        xb).astype(jnp.float32)
    # inter-chunk recurrence: h_{c+1} = exp(sum a_c) h_c + states_c
    chunk_decay = jnp.exp(a_cum[..., -1])                       # (B,H,nc)

    def comb(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, s2 + a2[..., None, None] * s1

    a_sc = chunk_decay.transpose(0, 2, 1).astype(jnp.float32)   # (B,nc,H)
    init_state = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    # prepend the initial state as a virtual chunk
    a_all = jnp.concatenate([jnp.ones((B, 1, H), jnp.float32), a_sc], axis=1)
    s_all = jnp.concatenate([init_state[:, None], states], axis=1)  # (B,nc+1,H,P,N)
    a_run, s_run = jax.lax.associative_scan(comb, (a_all, s_all), axis=1)
    prev_states = s_run[:, :-1]                                 # state entering chunk c
    final_state = s_run[:, -1]                                  # (B,H,P,N) f32
    # inter-chunk contribution
    state_decay = jnp.exp(a_cum)                                # (B,H,nc,l)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", cb_h, prev_states, state_decay)
    y = (y_diag + y_off).reshape(B, S, H, P).astype(x.dtype)
    return y, final_state


def ssd_scanned(x: jnp.ndarray, a_log: jnp.ndarray, b: jnp.ndarray,
                c: jnp.ndarray, chunk: int,
                h0: jnp.ndarray | None = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential-over-chunks SSD (same math as ``ssd_chunked``, same
    chunk math as the Pallas kernel): the recurrent state is carried
    through a ``lax.scan`` so only ONE chunk's (l, l) decay matrix is
    live at a time — ``ssd_chunked`` materializes all ``nc`` chunks'
    matrices at once, which costs TBs at 32k-token prefill."""
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    assert S % chunk == 0
    nc = S // chunk
    rep = H // G
    xb = x.reshape(B, nc, chunk, H, P).transpose(1, 0, 2, 3, 4)
    ab = a_log.reshape(B, nc, chunk, H).transpose(1, 0, 3, 2)   # (nc,B,H,l)
    bb = b.reshape(B, nc, chunk, G, N).transpose(1, 0, 2, 3, 4)
    cb = c.reshape(B, nc, chunk, G, N).transpose(1, 0, 2, 3, 4)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(state, inputs):
        xc, ac, bc, cc = inputs                     # (B,l,H,P) (B,H,l) ...
        a_cum = jnp.cumsum(ac, axis=-1)             # (B,H,l)
        seg = a_cum[..., :, None] - a_cum[..., None, :]
        lmat = jnp.where(mask, jnp.exp(seg), 0.0)   # (B,H,l,l)
        cb_h = jnp.repeat(cc, rep, axis=2)          # (B,l,H,N)
        bb_h = jnp.repeat(bc, rep, axis=2)
        y_diag = jnp.einsum("blhn,bshn,bhls,bshp->blhp", cb_h, bb_h, lmat, xc)
        y_off = jnp.einsum("blhn,bhpn,bhl->blhp", cb_h, state,
                           jnp.exp(a_cum))
        decay = jnp.exp(a_cum[..., -1:] - a_cum)    # (B,H,l)
        add = jnp.einsum("blhn,bhl,blhp->bhpn", bb_h, decay, xc)
        state = jnp.exp(a_cum[..., -1])[..., None, None] * state + add
        return state, (y_diag + y_off).astype(x.dtype)

    init = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    final, ys = jax.lax.scan(jax.remat(step), init, (xb, ab, bb, cb))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, final


# -- full block ---------------------------------------------------------------------
def init_mamba2(key, cfg: ArchConfig, dtype) -> Dict:
    d, din = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    ks = split_keys(key, 4)
    conv_dim = din + 2 * g * n
    return {
        "in_proj": dense_init(ks[0], (d, 2 * din + 2 * g * n + h), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_dim), dtype,
                             fan_in=cfg.ssm_conv),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(a_log) in [-1, 0)
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.zeros((din,), jnp.float32),
        "out_proj": dense_init(ks[2], (din, d), dtype, fan_in=din),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 tail: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv. x: (B, S, C); w: (K, C); tail: (B, K-1, C)."""
    K = w.shape[0]
    pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype) if tail is None else tail
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    return jax.nn.silu(out)


def apply_mamba2(p: Dict, x: jnp.ndarray, cfg: ArchConfig,
                 state: Dict | None = None) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, S, D) → (out, new_state). ``state`` carries {ssm, conv} for
    decode; None runs the chunked parallel scan from zero state."""
    B, S, D = x.shape
    din, g, n, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    pdim = cfg.ssm_headdim
    proj = x @ p["in_proj"]
    z, xc, bc, cc, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + g * n, 2 * din + 2 * g * n], axis=-1)
    conv_in = jnp.concatenate([xc, bc, cc], axis=-1)
    tail = state["conv"] if state is not None else None
    conv_out = _causal_conv(conv_in, p["conv_w"], tail)
    K = cfg.ssm_conv
    hist = conv_in if tail is None else jnp.concatenate([tail, conv_in], axis=1)
    if hist.shape[1] < K - 1:       # very short prefill: left-pad with zeros
        pad = jnp.zeros((B, K - 1 - hist.shape[1], hist.shape[2]), hist.dtype)
        hist = jnp.concatenate([pad, hist], axis=1)
    new_conv = hist[:, -(K - 1):]
    xc, bc, cc = jnp.split(conv_out, [din, din + g * n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # (B,S,H)
    a = -jnp.exp(p["a_log"])                                          # (H,)
    a_log_steps = dt * a                                              # (B,S,H) ≤ 0
    xh = xc.reshape(B, S, h, pdim)
    xdt = xh * dt[..., None].astype(x.dtype)
    bmat = bc.reshape(B, S, g, n)
    cmat = cc.reshape(B, S, g, n)

    h0 = state["ssm"] if state is not None else None
    chunk = min(cfg.ssm_chunk, S)
    if h0 is None and S % chunk == 0:
        from ..kernels import ops as _kops       # lazy: ref.py imports us
        if _kops.use_pallas():
            y, hfin = _kops.ssd_scan(xdt, a_log_steps, bmat, cmat, chunk=chunk)
        elif S // chunk > 4:
            # long sequences: sequential chunk scan — one (l, l) decay
            # matrix live at a time instead of all nc at once
            y, hfin = ssd_scanned(xdt, a_log_steps, bmat, cmat, chunk, h0)
        else:
            y, hfin = ssd_chunked(xdt, a_log_steps, bmat, cmat, chunk=chunk)
    elif S % chunk == 0 and S // chunk > 4:
        y, hfin = ssd_scanned(xdt, a_log_steps, bmat, cmat, chunk, h0)
    else:
        y, hfin = ssd_chunked(xdt, a_log_steps, bmat, cmat, chunk=chunk, h0=h0)
    y = y + xh * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, S, din)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, {"ssm": hfin, "conv": new_conv}


def apply_mamba2_decode(p: Dict, x: jnp.ndarray, cfg: ArchConfig,
                        state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """Single-token recurrent update. x: (B, 1, D)."""
    B, S, D = x.shape
    din, g, n, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    pdim = cfg.ssm_headdim
    proj = x @ p["in_proj"]
    z, xc, bc, cc, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + g * n, 2 * din + 2 * g * n], axis=-1)
    conv_in = jnp.concatenate([xc, bc, cc], axis=-1)                 # (B,1,C)
    window = jnp.concatenate([state["conv"], conv_in], axis=1)       # (B,K,C)
    w = p["conv_w"]
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w))[:, None]
    new_conv = window[:, 1:]
    xc, bc, cc = jnp.split(conv_out, [din, din + g * n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]   # (B,H)
    a = jnp.exp(dt * -jnp.exp(p["a_log"]))                              # (B,H)
    xh = xc.reshape(B, h, pdim)
    bmat = jnp.repeat(bc.reshape(B, g, n), h // g, axis=1)              # (B,H,N)
    cmat = jnp.repeat(cc.reshape(B, g, n), h // g, axis=1)
    hs = state["ssm"].astype(jnp.float32)
    hs = a[..., None, None] * hs + (dt[..., None] * xh.astype(jnp.float32)
                                    )[..., None] * bmat[:, :, None, :].astype(jnp.float32)
    y = jnp.einsum("bhpn,bhn->bhp", hs, cmat.astype(jnp.float32)).astype(x.dtype)
    y = y + xh * p["d_skip"][None, :, None].astype(x.dtype)
    y = y.reshape(B, 1, din)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    return y @ p["out_proj"], {"ssm": hs.astype(state["ssm"].dtype), "conv": new_conv}


def mamba2_state_shape(cfg: ArchConfig, batch: int, dtype):
    h, pdim, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {"ssm": ((batch, h, pdim, n), jnp.float32),
            "conv": ((batch, cfg.ssm_conv - 1, conv_dim), dtype)}
