"""Shared building blocks: norms, rotary embeddings, initializers."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0
               ) -> jnp.ndarray:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


# -- initializers ----------------------------------------------------------------
def dense_init(key, shape: Tuple[int, ...], dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[0]
    std = fan ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def count_params(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))
