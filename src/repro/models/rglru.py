"""RecurrentGemma / Griffin recurrent block (arXiv:2402.19427).

Temporal mixing = Conv1D(width 4) → RG-LRU, gated by a GeLU branch:

    r_t = σ(W_a x_t + b_a)            (recurrence gate)
    i_t = σ(W_x x_t + b_x)            (input gate)
    a_t = exp(−c · softplus(Λ) · r_t)
    h_t = a_t h_{t−1} + sqrt(1 − a_t²) · (i_t ⊙ x_t)

The sequence form runs via ``jax.lax.associative_scan``; decode is the
O(1) recurrence carrying {lru, conv} state.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, split_keys
from .config import ArchConfig

_C = 8.0


def init_rglru(key, cfg: ArchConfig, dtype) -> Dict:
    d, w = cfg.d_model, cfg.lru_dim
    ks = split_keys(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, w), dtype),          # recurrent branch
        "w_gate_branch": dense_init(ks[1], (d, w), dtype), # GeLU branch
        "conv_w": dense_init(ks[2], (cfg.conv_width, w), dtype, fan_in=cfg.conv_width),
        "wa": dense_init(ks[3], (w, w), dtype),
        "wx": dense_init(ks[4], (w, w), dtype),
        "ba": jnp.zeros((w,), jnp.float32),
        "bx": jnp.zeros((w,), jnp.float32),
        # Λ init so that a ≈ 0.9..0.999 at r = 1 (per the paper)
        "lam": jnp.log(jnp.expm1(-jnp.log(
            jnp.linspace(0.9, 0.999, w, dtype=jnp.float32)) / _C)),
        "w_out": dense_init(ks[5], (w, d), dtype, fan_in=w),
    }


def _conv_causal(x: jnp.ndarray, w: jnp.ndarray,
                 tail: jnp.ndarray | None) -> jnp.ndarray:
    K = w.shape[0]
    pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype) if tail is None else tail
    xp = jnp.concatenate([pad, x], axis=1)
    return sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))


def _rglru_scan(xg: jnp.ndarray, a_log: jnp.ndarray,
                h0: jnp.ndarray | None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t h_{t−1} + b_t over seq axis 1. a_log: log a_t (f32)."""
    a = jnp.exp(a_log)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * a_log), 1e-12)) * xg

    def comb(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    if h0 is not None:
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0[:, None].astype(b.dtype), b], axis=1)
        _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
        h = h[:, 1:]
    else:
        _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    return h, h[:, -1]


def apply_rglru(p: Dict, x: jnp.ndarray, cfg: ArchConfig,
                state: Dict | None = None) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, S, D) → (out, new_state {lru (B,W) f32, conv (B,K−1,W)})."""
    B, S, _ = x.shape
    gate = jax.nn.gelu(x @ p["w_gate_branch"], approximate=True)
    proj = x @ p["w_in"]
    tail = state["conv"] if state is not None else None
    u = _conv_causal(proj, p["conv_w"], tail)
    K = cfg.conv_width
    hist = proj if tail is None else jnp.concatenate([tail, proj], axis=1)
    if hist.shape[1] < K - 1:
        padz = jnp.zeros((B, K - 1 - hist.shape[1], hist.shape[2]), hist.dtype)
        hist = jnp.concatenate([padz, hist], axis=1)
    new_conv = hist[:, -(K - 1):]

    r = jax.nn.sigmoid((u @ p["wa"]).astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid((u @ p["wx"]).astype(jnp.float32) + p["bx"])
    a_log = -_C * jax.nn.softplus(p["lam"]) * r                  # (B,S,W) f32
    xg = i * u.astype(jnp.float32)
    h0 = state["lru"] if state is not None else None
    if h0 is None and S % min(256, S) == 0:
        from ..kernels import ops as _kops       # lazy: ref.py imports us
        if _kops.use_pallas():
            b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * a_log), 1e-12)) * xg
            h, h_last = _kops.rglru_scan(a_log, b, block_t=min(256, S))
        else:
            h, h_last = _rglru_scan(xg, a_log, h0)
    else:
        h, h_last = _rglru_scan(xg, a_log, h0)
    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    return y, {"lru": h_last, "conv": new_conv}


def rglru_state_shape(cfg: ArchConfig, batch: int, dtype):
    w = cfg.lru_dim
    return {"lru": ((batch, w), jnp.float32),
            "conv": ((batch, cfg.conv_width - 1, w), dtype)}
