"""Reference attention implementations (pure jnp, GSPMD-friendly).

These are the oracles for the Pallas kernels in ``repro.kernels`` and
the path used by the 512-device dry-run (Pallas TPU kernels cannot lower
on the CPU backend; ``attn_impl='pallas'`` swaps the kernels in when a
TPU backend is present).

Layouts: q (B, S, H, hd); k/v (B, T, KV, hd). GQA groups are computed
via einsum without materializing repeated K/V.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .sharding_utils import BATCH, maybe_shard

NEG_INF = -2.0e38


def _pallas_ops():
    """Kernel dispatch (lazy import — kernels.ref imports this module)."""
    from ..kernels import ops
    return ops if ops.use_pallas() else None


def _mask_bias(s_len: int, t_len: int, *, causal: bool, window: Optional[int],
               prefix_len: int, offset: int) -> jnp.ndarray:
    """(s_len, t_len) additive bias. ``offset`` = absolute position of the
    first query row (for chunked prefill / decode)."""
    qpos = jnp.arange(s_len)[:, None] + offset
    kpos = jnp.arange(t_len)[None, :]
    ok = jnp.ones((s_len, t_len), bool)
    if causal:
        ok = kpos <= qpos
        if prefix_len > 0:
            ok = ok | (kpos < prefix_len)
    if window is not None:
        ok = ok & (kpos > qpos - window)
    return jnp.where(ok, 0.0, NEG_INF)


def gqa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: Optional[int] = None,
                  prefix_len: int = 0, offset: int = 0,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """Grouped-query attention. Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(B, S, KV, G, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * scale
    bias = _mask_bias(S, k.shape[1], causal=causal, window=window,
                      prefix_len=prefix_len, offset=offset)
    logits = logits + bias[None, None, None]
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, S, H, hd)


def gqa_attention_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                          causal: bool = True, window: Optional[int] = None,
                          prefix_len: int = 0, q_chunk: int = 1024,
                          scale: Optional[float] = None) -> jnp.ndarray:
    """Query-chunked attention: bounds live score memory at
    (B, H, q_chunk, T) — the pure-jnp stand-in for the flash kernel on
    long-sequence prefill/training."""
    if causal and prefix_len == 0:
        ops = _pallas_ops()
        if ops is not None:
            return ops.flash_attention(q, k, v, causal=True, window=window,
                                       scale=scale)
    B, S, H, hd = q.shape
    if S % q_chunk:
        return gqa_attention(q, k, v, causal=causal, window=window,
                             prefix_len=prefix_len, scale=scale)
    nc = S // q_chunk
    qs = q.reshape(B, nc, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)

    # per-chunk remat: the backward pass recomputes each chunk's scores
    # (flash-attention-style) instead of saving (nc, B, H, chunk, T) logits
    @jax.remat
    def chunk_body(qc, i):
        return gqa_attention(qc, k, v, causal=causal, window=window,
                             prefix_len=prefix_len, offset=i * q_chunk,
                             scale=scale)

    def chunk_fn(_, args):
        i, qc = args
        return None, chunk_body(qc, i)

    _, outs = jax.lax.scan(chunk_fn, None, (jnp.arange(nc), qs))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     cache_len: jnp.ndarray, *, window: Optional[int] = None,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """Single-position decode vs a (B, T, KV, hd) cache.

    q: (B, 1, H, hd); ``cache_len``: (B,) int32 — number of valid cache
    entries (the new token's k/v must already be written at
    ``cache_len - 1``). Masked positions are length-masked in f32.
    """
    ops = _pallas_ops()
    if ops is not None:
        return ops.decode_attention(q, k_cache, v_cache, cache_len,
                                    window=window, scale=scale)
    return decode_attention_ref(q, k_cache, v_cache, cache_len,
                                window=window, scale=scale)


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, cache_len: jnp.ndarray, *,
                         window: Optional[int] = None,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """Pure-jnp decode attention (the kernel oracle — never dispatches)."""
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    T = k_cache.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(B, KV, G, hd)
    logits = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache).astype(jnp.float32) * scale
    kpos = jnp.arange(T)[None, :]
    ok = kpos < cache_len[:, None]
    if window is not None:
        ok = ok & (kpos > cache_len[:, None] - 1 - window)
    logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", w, v_cache)
    return out.reshape(B, 1, H, hd)


# -- MLA (DeepSeek-V2 §2.1) --------------------------------------------------------
def mla_prefill(cq: jnp.ndarray, ckv: jnp.ndarray, k_rope: jnp.ndarray,
                wq_nope: jnp.ndarray, wq_rope: jnp.ndarray,
                wk_nope: jnp.ndarray, wv: jnp.ndarray, *,
                rope_theta: float, causal: bool = True,
                q_chunk: Optional[int] = None) -> jnp.ndarray:
    """Multi-head latent attention, materialized (prefill/training) path.

    cq:  (B, S, Rq)      — compressed queries (post q_a + norm)
    ckv: (B, T, Rkv)     — compressed KV latent (post kv_a + norm)
    k_rope: (B, T, dr)   — decoupled RoPE key (shared across heads, pre-rope)
    wq_nope: (Rq, H, dn); wq_rope: (Rq, H, dr)
    wk_nope: (Rkv, H, dn); wv: (Rkv, H, dv)
    Returns (B, S, H, dv). ``q_chunk`` bounds score memory for long S.
    """
    from .common import apply_rope
    B, S, _ = cq.shape
    T = ckv.shape[1]
    k_nope = jnp.einsum("btr,rhd->bthd", ckv, wk_nope)
    v = jnp.einsum("btr,rhd->bthd", ckv, wv)
    k_pos = jnp.arange(T)[None, :]
    k_rope_r = apply_rope(k_rope[:, :, None, :], k_pos, rope_theta)  # (B,T,1,dr)

    def block(cq_blk, offset):
        q_nope = jnp.einsum("bsr,rhd->bshd", cq_blk, wq_nope)
        q_rope = jnp.einsum("bsr,rhd->bshd", cq_blk, wq_rope)
        q_nope = maybe_shard(q_nope, P(BATCH, None, "model", None))
        q_pos = jnp.arange(cq_blk.shape[1])[None, :] + offset
        q_rope = apply_rope(q_rope, q_pos, rope_theta)
        dn, dr = q_nope.shape[-1], q_rope.shape[-1]
        scale = (dn + dr) ** -0.5
        logits = (jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
                  + jnp.einsum("bshd,btxd->bhst", q_rope, k_rope_r)
                  ).astype(jnp.float32) * scale
        logits = maybe_shard(logits, P(BATCH, "model", None, None))
        bias = _mask_bias(cq_blk.shape[1], T, causal=causal, window=None,
                          prefix_len=0, offset=offset)
        w = jax.nn.softmax(logits + bias[None, None], axis=-1).astype(cq.dtype)
        out = jnp.einsum("bhst,bthd->bshd", w, v)
        return maybe_shard(out, P(BATCH, None, "model", None))

    if not q_chunk or S <= q_chunk or S % q_chunk:
        return block(cq, 0)
    nc = S // q_chunk
    cqs = cq.reshape(B, nc, q_chunk, -1).transpose(1, 0, 2, 3)

    rematted = jax.remat(block)          # recompute per-chunk scores in bwd

    def chunk_fn(_, args):
        i, blk = args
        return None, rematted(blk, i * q_chunk)

    _, outs = jax.lax.scan(chunk_fn, None, (jnp.arange(nc), cqs))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, -1, wv.shape[-1])


def mla_decode(cq: jnp.ndarray, ckv_cache: jnp.ndarray, krope_cache: jnp.ndarray,
               cache_len: jnp.ndarray, wq_nope: jnp.ndarray, wq_rope: jnp.ndarray,
               wk_nope: jnp.ndarray, wv: jnp.ndarray, *,
               rope_theta: float) -> jnp.ndarray:
    """Weight-absorbed MLA decode: attention runs in the compressed
    latent space — the cache stays (B, T, Rkv) + (B, T, dr).

    cq: (B, 1, Rq). krope_cache rows are stored *post-rope*. Returns
    (B, 1, H, dv).
    """
    from .common import apply_rope
    B = cq.shape[0]
    q_nope = jnp.einsum("bsr,rhd->bshd", cq, wq_nope)          # (B,1,H,dn)
    q_rope = jnp.einsum("bsr,rhd->bshd", cq, wq_rope)
    q_rope = apply_rope(q_rope, cache_len[:, None] - 1, rope_theta)
    # absorb W_uk: q' = q_nope @ wk_nope^T  -> latent-space query
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wk_nope)      # (B,1,H,Rkv)
    dn, dr = q_nope.shape[-1], q_rope.shape[-1]
    scale = (dn + dr) ** -0.5
    logits = (jnp.einsum("bshr,btr->bhst", q_lat, ckv_cache)
              + jnp.einsum("bshd,btd->bhst", q_rope, krope_cache)
              ).astype(jnp.float32) * scale
    T = ckv_cache.shape[1]
    ok = jnp.arange(T)[None, :] < cache_len[:, None]
    logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(cq.dtype)
    ctx = jnp.einsum("bhst,btr->bshr", w, ckv_cache)           # (B,1,H,Rkv)
    return jnp.einsum("bshr,rhd->bshd", ctx, wv)               # (B,1,H,dv)
