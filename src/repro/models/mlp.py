"""MLP blocks: dense (gated / standard) and Mixture-of-Experts.

MoE uses token-choice top-k routing with static expert capacity and
sort-based dispatch (no dense one-hot dispatch einsum — that costs
O(T·E·C·D) FLOPs and dominates real compute for 160-expert models).
Dropped tokens fall out via scatter ``mode='drop'``; the combine path
unsorts and weight-sums the k expert outputs per token.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import activation, dense_init, split_keys
from .config import ArchConfig
from .sharding_utils import maybe_shard


# -- dense MLP -----------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype) -> Dict:
    ks = split_keys(key, 3)
    p = {"w_up": dense_init(ks[0], (d_model, d_ff), dtype),
         "w_down": dense_init(ks[1], (d_ff, d_model), dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def apply_mlp(p: Dict, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    fn = activation(act)
    h = x @ p["w_up"]
    if "w_gate" in p:
        h = fn(x @ p["w_gate"]) * h
    else:
        h = fn(h)
    return h @ p["w_down"]


# -- MoE -------------------------------------------------------------------------
def init_moe(key, cfg: ArchConfig, dtype) -> Dict:
    d, f = cfg.d_model, cfg.moe_d_ff
    e = cfg.n_experts
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_up": dense_init(ks[1], (e, d, f), dtype),
        "w_gate": dense_init(ks[2], (e, d, f), dtype),
        "w_down": dense_init(ks[3], (e, f, d), dtype, fan_in=f),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, f * cfg.n_shared_experts,
                               cfg.gated_mlp, dtype)
    return p


def moe_capacity(cfg: ArchConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.experts_per_token
              / cfg.n_experts) + 1
    return max(cap, cfg.experts_per_token)


def dispatch_groups(n_tokens: int, cfg: ArchConfig) -> int:
    """Dispatch-group count G: tokens are routed within G independent
    groups whose leading dim is sharded over the batch axes, so the
    sorts/scatters of token-choice routing stay shard-LOCAL (no
    replicated (T·K, D) tensors — that costs ~70 GB/device at 1M-token
    batches). 32 = the widest batch-shard count of the production meshes."""
    if cfg.moe_groups:
        return cfg.moe_groups
    for g in (32, 16, 8, 4, 2):
        if n_tokens % g == 0 and n_tokens // g >= cfg.experts_per_token:
            return g
    return 1


def apply_moe(p: Dict, x: jnp.ndarray, cfg: ArchConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) → (out, aux_loss). Token-choice top-k with capacity.

    Grouped local dispatch: (a) routing/sort/rank arithmetic runs per
    dispatch group (G sharded over ("pod","data")); (b) tokens are
    scattered one routing slot k at a time, so nothing of shape
    (T·K, D) is ever materialized — the scatter/gather working set is
    K × (T, D) reads of the already-live activations.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S
    G = dispatch_groups(T, cfg)
    Tl = T // G
    C = moe_capacity(cfg, Tl)
    fn = activation(cfg.act)

    xg = x.reshape(G, Tl, D)
    xg = maybe_shard(xg, P(("pod", "data"), None, None))
    logits = jnp.einsum("gtd,de->gte", xg,
                        p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                          # (G, Tl, K)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)     # renorm

    # Switch-style load-balance auxiliary loss (global means)
    me = jnp.mean(probs, axis=(0, 1))                             # (E,)
    ce = jnp.mean(jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # ---- per-group sort-based ranking (1-D arrays only) ---------------------
    flat_e = eidx.reshape(G, Tl * K)
    order = jnp.argsort(flat_e, axis=-1, stable=True)             # (G, Tl·K)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    starts = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype)))(
        sorted_e)                                                 # (G, E)
    rank = jnp.arange(Tl * K, dtype=jnp.int32)[None] \
        - jnp.take_along_axis(starts, sorted_e, axis=-1).astype(jnp.int32)
    dest_sorted = jnp.where(rank < C,
                            sorted_e.astype(jnp.int32) * C + rank,
                            E * C)                                # E*C = drop
    inv = jnp.argsort(order, axis=-1)
    dest = jnp.take_along_axis(dest_sorted, inv, axis=-1) \
        .reshape(G, Tl, K)                                        # per (t, k)

    # ---- dispatch: one scatter of (G, Tl, D) per routing slot ----------------
    # the scatter's row dim is data-dependent (unshardable) but its D dim
    # is free: keep buf D-sharded so dispatch stays local, then reshard to
    # expert-parallel (E on the model axis) for the expert matmuls — the
    # EP all-to-all happens exactly once, here
    buf = maybe_shard(jnp.zeros((G, E * C, D), x.dtype),
                      P(("pod", "data"), None, "model"))
    xg_d = maybe_shard(xg, P(("pod", "data"), None, "model"))
    scatter1 = jax.vmap(lambda b, d, v: b.at[d].set(v, mode="drop"))
    for k in range(K):
        buf = scatter1(buf, dest[:, :, k], xg_d)
    h = buf.reshape(G, E, C, D)
    h = maybe_shard(h, P(("pod", "data"), "model", None, None))
    up = jnp.einsum("gecd,edf->gecf", h, p["w_up"])
    gt = jnp.einsum("gecd,edf->gecf", h, p["w_gate"])
    y = jnp.einsum("gecf,efd->gecd", fn(gt) * up, p["w_down"])
    y = maybe_shard(y, P(("pod", "data"), "model", None, None))
    yf = maybe_shard(y.reshape(G, E * C, D),
                     P(("pod", "data"), None, "model"))

    # ---- combine: one gather of (G, Tl, D) per routing slot ------------------
    gather1 = jax.vmap(lambda y, d: y[d])       # 1-D row gather per group
    out = jnp.zeros((G, Tl, D), x.dtype)
    for k in range(K):
        dk = dest[:, :, k]
        live = (dk < E * C)
        safe = jnp.where(live, dk, 0)
        vals = gather1(yf, safe)                                  # (G, Tl, D)
        w = (gate[:, :, k] * live).astype(x.dtype)[..., None]
        out = out + vals * w

    if "shared" in p:
        out = out + apply_mlp(p["shared"], xg.reshape(T, D), cfg.act) \
            .reshape(G, Tl, D)
    return out.reshape(B, S, D), aux
