"""Parameter / cache / batch sharding rules for the production meshes.

Baseline ("megatron+fsdp") layout — the hybrid-parallel plan a Dora-style
planner emits for a homogeneous pod:

* batch over ``("pod","data")``;
* tensor parallelism over ``"model"``: attention heads (when divisible),
  MLP hidden dim, expert dim for MoE, recurrent width for RG-LRU;
* FSDP (ZeRO-3-style) over ``("pod","data")`` on a second weight dim;
* KV caches: batch-sharded; sequence dim over ``"model"`` (split-KV
  decode) when the batch axis can't cover the mesh.

Rules are *path-based* on the parameter pytree so every family shares
one rule set; non-divisible dims fall back to replication (whisper's 12
heads, paligemma's 8 heads — see DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from .config import ArchConfig

FSDP = ("pod", "data")
TP = "model"


def _axis_sizes(mesh) -> Dict[str, int]:
    return dict(mesh.shape)     # works for Mesh and AbstractMesh alike


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


class ShardingRules:
    def __init__(self, cfg: ArchConfig, mesh):
        self.cfg = cfg
        self.mesh = mesh
        sizes = _axis_sizes(mesh)
        self.tp = sizes.get("model", 1)
        self.fsdp = sizes.get("data", 1) * sizes.get("pod", 1)
        self.batch_axes = tuple(a for a in ("pod", "data") if a in sizes)

    # -- helpers ------------------------------------------------------------------
    def _p(self, *entries) -> P:
        """Build a spec, dropping axes absent from the mesh."""
        names = set(self.mesh.axis_names)
        out = []
        for e in entries:
            if e is None:
                out.append(None)
            elif isinstance(e, tuple):
                kept = tuple(a for a in e if a in names)
                out.append(kept if kept else None)
            else:
                out.append(e if e in names else None)
        return P(*out)

    def _fsdp_ok(self, dim: int) -> bool:
        return _div(dim, self.fsdp)

    def _tp_ok(self, dim: int) -> bool:
        return _div(dim, self.tp)

    # -- parameter rules ---------------------------------------------------------------
    def param_spec(self, path: str, shape) -> P:
        """path: '/'-joined key path (without vmap-stacked leading dim
        handling — we detect stacking by ndim vs rule arity)."""
        cfg = self.cfg
        nd = len(shape)
        leaf = path.split("/")[-1]

        def wrap(*entries):
            """Prepend None for the stacked layer dim when present."""
            base = len(entries)
            spec = list(entries)
            while len(spec) < nd:
                spec.insert(0, None)
            if len(spec) > nd:
                spec = spec[-nd:]
            return self._p(*spec)

        fs = FSDP
        # embeddings / head
        if leaf in ("embed", "unembed"):
            v_dim, d_dim = (0, 1) if leaf == "embed" else (1, 0)
            spec = [None, None]
            if self._tp_ok(shape[v_dim]):
                spec[v_dim] = TP
            if self._fsdp_ok(shape[d_dim]):
                spec[d_dim] = fs
            return self._p(*spec)
        if leaf == "enc_pos":
            return wrap(None, None)
        # norms / scalars / gates
        if nd - self._stack_depth(path) <= 1 or leaf in (
                "ln1", "ln2", "ln_x", "ln_f", "ln_enc", "q_norm", "k_norm",
                "kv_norm", "norm_scale", "a_log", "dt_bias", "d_skip",
                "ba", "bx", "lam"):
            return self._p(*([None] * nd))
        # attention projections
        if leaf == "wq":
            h = shape[-2]
            return wrap(fs if self._fsdp_ok(shape[-3]) else None,
                        TP if self._tp_ok(h) else None, None)
        if leaf in ("wk", "wv"):
            kv = shape[-2]
            return wrap(fs if self._fsdp_ok(shape[-3]) else None,
                        TP if self._tp_ok(kv) else None, None)
        if leaf == "wo":
            h = shape[-3]
            return wrap(TP if self._tp_ok(h) else None, None,
                        fs if self._fsdp_ok(shape[-1]) else None)
        # MLA
        if leaf == "wq_a":
            return wrap(fs if self._fsdp_ok(shape[-2]) else None,
                        TP if self._tp_ok(shape[-1]) else None)
        if leaf == "wkv_a":
            return wrap(fs if self._fsdp_ok(shape[-2]) else None, None)
        if leaf in ("wq_nope", "wq_rope", "wk_nope"):
            return wrap(fs if self._fsdp_ok(shape[-3]) else None,
                        TP if self._tp_ok(shape[-2]) else None, None)
        # MoE
        if "moe" in path:
            if leaf == "router":
                # (d_model, E) f32 — stacked over layers this is hundreds
                # of MB; FSDP-shard the d_model dim
                return wrap(fs if self._fsdp_ok(shape[-2]) else None, None)
            if leaf in ("w_up", "w_gate") and nd - self._stack_depth(path) == 3:
                return wrap(TP if self._tp_ok(shape[-3]) else None,
                            fs if self._fsdp_ok(shape[-2]) else None, None)
            if leaf == "w_down" and nd - self._stack_depth(path) == 3:
                return wrap(TP if self._tp_ok(shape[-3]) else None, None,
                            fs if self._fsdp_ok(shape[-1]) else None)
        # wv in MLA context (Rkv, H, dv) handled above via wk_nope? keep:
        if leaf == "wv" and cfg.mla:
            return wrap(fs if self._fsdp_ok(shape[-3]) else None,
                        TP if self._tp_ok(shape[-2]) else None, None)
        # dense MLP (also MoE shared expert)
        if leaf in ("w_up", "w_gate"):
            return wrap(fs if self._fsdp_ok(shape[-2]) else None,
                        TP if self._tp_ok(shape[-1]) else None)
        if leaf == "w_down":
            return wrap(TP if self._tp_ok(shape[-2]) else None,
                        fs if self._fsdp_ok(shape[-1]) else None)
        # Mamba2
        if leaf == "in_proj":
            return wrap(fs if self._fsdp_ok(shape[-2]) else None, None)
        if leaf == "out_proj":
            return wrap(TP if self._tp_ok(shape[-2]) else None,
                        fs if self._fsdp_ok(shape[-1]) else None)
        if leaf == "conv_w":
            return wrap(None, TP if self._tp_ok(shape[-1]) else None)
        # RG-LRU
        if leaf in ("w_in", "w_gate_branch"):
            return wrap(fs if self._fsdp_ok(shape[-2]) else None,
                        TP if self._tp_ok(shape[-1]) else None)
        if leaf in ("wa", "wx"):
            return wrap(fs if self._fsdp_ok(shape[-2]) else None,
                        TP if self._tp_ok(shape[-1]) else None)
        if leaf == "w_out":
            return wrap(TP if self._tp_ok(shape[-2]) else None,
                        fs if self._fsdp_ok(shape[-1]) else None)
        return self._p(*([None] * nd))

    def _stack_depth(self, path: str) -> int:
        """1 when the param lives under a vmapped stack ('stack/...')."""
        return 1 if path.startswith("stack/") or "/enc/" in path \
            or path.startswith("enc/") or path.startswith("dec/") else 0

    # -- trees --------------------------------------------------------------------------
    def param_specs(self, params_shape) -> Any:
        def fn(kp, leaf):
            path = "/".join(_key_str(k) for k in kp)
            return self.param_spec(path, leaf.shape)
        return jax.tree_util.tree_map_with_path(fn, params_shape)

    def cache_specs(self, cache_shape, global_batch: int) -> Any:
        """KV/state caches: batch over (pod,data) when divisible; the
        cache sequence dim goes over 'model' (split-KV decode); for
        batch=1 long-context it takes every mesh axis instead."""
        dp = 1
        for a in self.batch_axes:
            dp *= _axis_sizes(self.mesh)[a]
        batch_ok = _div(global_batch, dp)

        def fn(kp, leaf):
            path = "/".join(_key_str(k) for k in kp)
            name = path.split("/")[-1]
            shape = leaf.shape
            nd = len(shape)
            stacked = 1 if any(path.startswith(s) for s in
                               ("stack", "self", "cross")) else 0
            spec = [None] * nd
            b_idx = stacked            # (L, B, ...) or (B, ...)
            if nd > b_idx and batch_ok and shape[b_idx] == global_batch:
                spec[b_idx] = FSDP
            # sequence dim of attention caches: (L?, B, T, KV, hd) / (L?, B, T, R)
            t_idx = b_idx + 1
            if name in ("k", "v", "ckv", "krope") and nd >= t_idx + 2:
                if not batch_ok and _div(shape[t_idx], self.fsdp * self.tp):
                    spec[t_idx] = tuple(self.batch_axes) + (TP,)
                elif self._tp_ok(shape[t_idx]):
                    spec[t_idx] = TP
            return self._p(*spec)
        return jax.tree_util.tree_map_with_path(fn, cache_shape)

    def batch_specs(self, batch_shape, global_batch: int) -> Any:
        dp = 1
        for a in self.batch_axes:
            dp *= _axis_sizes(self.mesh)[a]
        batch_ok = _div(global_batch, dp)

        def fn(_kp, leaf):
            nd = len(leaf.shape)
            spec = [None] * nd
            if batch_ok and nd >= 1 and leaf.shape[0] == global_batch:
                spec[0] = FSDP
            return self._p(*spec)
        return jax.tree_util.tree_map_with_path(fn, batch_shape)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)
