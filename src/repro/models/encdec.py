"""Encoder-decoder transformer (Whisper-style backbone).

The conv audio frontend is a STUB per the assignment: ``input_specs()``
feeds precomputed frame embeddings (B, enc_seq, D). Norms are RMSNorm
for substrate uniformity (noted in DESIGN.md §assumption changes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as attn_lib
from .common import dense_init, dtype_of, embed_init, rms_norm, split_keys
from .config import ArchConfig
from .mlp import apply_mlp, init_mlp
from .sharding_utils import maybe_shard
from .transformer import _fit_cache, _write_cache, init_attn


def _init_enc_layer(key, cfg: ArchConfig, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
            "attn": init_attn(k1, cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)}


def _init_dec_layer(key, cfg: ArchConfig, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
            "self_attn": init_attn(k1, cfg, dtype),
            "ln_x": jnp.zeros((cfg.d_model,), jnp.float32),
            "cross_attn": init_attn(k2, cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
            "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)}


def _attn_noncausal(p, x, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    o = attn_lib.gqa_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _cross_kv(p, enc_out):
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"])
    return k, v


def _cross_attn(p, x, k, v):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    o = attn_lib.gqa_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _self_attn(p, x, cfg, *, mode, cache, pos):
    from .common import apply_rope
    B, S, _ = x.shape
    positions = pos[:, None] if mode == "decode" else jnp.arange(S)[None, :]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if mode == "decode":
        kc = _write_cache(cache["k"], k, pos)
        vc = _write_cache(cache["v"], v, pos)
        o = attn_lib.decode_attention(q, kc, vc, pos + 1)
        return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), {"k": kc, "v": vc}
    if S > cfg.attn_chunk:
        # long prefill: never materialize the (S, S) score matrix
        o = attn_lib.gqa_attention_chunked(q, k, v, causal=True,
                                           q_chunk=cfg.attn_chunk // 4)
    else:
        o = attn_lib.gqa_attention(q, k, v, causal=True)
    new_cache = None
    if mode == "prefill":
        new_cache = {"k": _fit_cache(cache["k"], k), "v": _fit_cache(cache["v"], v)}
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), new_cache


@dataclasses.dataclass(frozen=True)
class EncDecLM:
    cfg: ArchConfig

    def init(self, rng) -> Dict:
        cfg = self.cfg
        dtype = dtype_of(cfg.dtype)
        ks = split_keys(rng, 5)
        return {
            "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype),
            "enc_pos": embed_init(ks[1], (cfg.enc_seq, cfg.d_model), dtype),
            "enc": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
                jax.random.split(ks[2], cfg.n_enc_layers)),
            "dec": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(
                jax.random.split(ks[3], cfg.n_layers)),
            "ln_enc": jnp.zeros((cfg.d_model,), jnp.float32),
            "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
        }

    # -- encoder ------------------------------------------------------------------
    def encode(self, params: Dict, frames: jnp.ndarray, remat: str = "full"
               ) -> jnp.ndarray:
        cfg = self.cfg
        x = frames + params["enc_pos"][None, : frames.shape[1]].astype(frames.dtype)
        x = maybe_shard(x, P(("pod", "data"), "model", None))

        def layer(x, p):
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            x = x + _attn_noncausal(p["attn"], h, cfg)
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            return x + apply_mlp(p["mlp"], h, cfg.act)

        fn = jax.remat(layer) if remat == "full" else layer
        x, _ = jax.lax.scan(lambda c, p: (fn(c, p), None), x, params["enc"],
                            unroll=cfg.scan_unroll)
        return rms_norm(x, params["ln_enc"], cfg.norm_eps)

    # -- decoder (train) --------------------------------------------------------------
    def apply(self, params: Dict, tokens: jnp.ndarray, *,
              encoder_frames: jnp.ndarray, remat: str = "full",
              **_ignored) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        enc_out = self.encode(params, encoder_frames, remat)
        x = params["embed"][tokens]
        x = maybe_shard(x, P(("pod", "data"), "model", None))

        def layer(x, p):
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            y, _ = _self_attn(p["self_attn"], h, cfg, mode="train",
                              cache=None, pos=None)
            x = x + y
            h = rms_norm(x, p["ln_x"], cfg.norm_eps)
            k, v = _cross_kv(p["cross_attn"], enc_out)
            x = x + _cross_attn(p["cross_attn"], h, k, v)
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            return x + apply_mlp(p["mlp"], h, cfg.act)

        fn = jax.remat(layer) if remat == "full" else layer
        x, _ = jax.lax.scan(lambda c, p: (fn(c, p), None), x, params["dec"],
                            unroll=cfg.scan_unroll)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = (x @ params["embed"].T).astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            logits = logits + jnp.where(
                jnp.arange(cfg.padded_vocab) < cfg.vocab_size, 0.0, attn_lib.NEG_INF)
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params: Dict, batch: Dict, remat: str = "full"):
        logits, aux = self.apply(params, batch["tokens"],
                                 encoder_frames=batch["encoder_frames"],
                                 remat=remat)
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        nll = jnp.mean(lse - ll)
        return nll, {"nll": nll, "aux": aux}

    # -- serving --------------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        dtype = dtype_of(cfg.dtype)
        L, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        z = lambda *s: jnp.zeros(s, dtype)
        return {"self": {"k": z(L, batch, max_len, kv, hd),
                         "v": z(L, batch, max_len, kv, hd)},
                "cross": {"k": z(L, batch, cfg.enc_seq, kv, hd),
                          "v": z(L, batch, cfg.enc_seq, kv, hd)}}

    def prefill(self, params: Dict, tokens: jnp.ndarray, cache: Dict, *,
                encoder_frames: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        enc_out = self.encode(params, encoder_frames, remat="none")

        def layer(x, xs):
            p, sc = xs
            k, v = _cross_kv(p["cross_attn"], enc_out)
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            y, nc = _self_attn(p["self_attn"], h, cfg, mode="prefill",
                               cache=sc, pos=None)
            x = x + y
            h = rms_norm(x, p["ln_x"], cfg.norm_eps)
            x = x + _cross_attn(p["cross_attn"], h, k, v)
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + apply_mlp(p["mlp"], h, cfg.act)
            x = maybe_shard(x, P(("pod", "data"), "model", None))
            return x, (nc, {"k": k, "v": v})

        x = params["embed"][tokens]
        x = maybe_shard(x, P(("pod", "data"), "model", None))
        x, (self_c, cross_c) = jax.lax.scan(layer, x, (params["dec"], cache["self"]),
                                            unroll=cfg.scan_unroll)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = (x[:, -1:] @ params["embed"].T).astype(jnp.float32)
        return logits, {"self": self_c, "cross": cross_c}

    def decode(self, params: Dict, token: jnp.ndarray, cache: Dict,
               pos: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg

        def layer(x, xs):
            p, sc, cc = xs
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            y, nc = _self_attn(p["self_attn"], h, cfg, mode="decode",
                               cache=sc, pos=pos)
            x = x + y
            h = rms_norm(x, p["ln_x"], cfg.norm_eps)
            x = x + _cross_attn(p["cross_attn"], h, cc["k"], cc["v"])
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + apply_mlp(p["mlp"], h, cfg.act)
            x = maybe_shard(x, P(("pod", "data"), None, None))
            return x, nc

        x = params["embed"][token]
        x = maybe_shard(x, P(("pod", "data"), None, None))
        x, self_c = jax.lax.scan(layer, x, (params["dec"], cache["self"], cache["cross"]),
                                 unroll=cfg.scan_unroll)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = (x @ params["embed"].T).astype(jnp.float32)
        return logits, {"self": self_c, "cross": cache["cross"]}
