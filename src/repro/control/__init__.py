"""``repro.control`` — the real-time control plane (§4.3 unified).

One place reacts to runtime dynamics: :class:`ControlPlane` (single
session), :class:`FleetControlPlane` (multi-tenant) and
:class:`StaticPlane` (non-adaptive baselines).  ``ServeSession``,
``FleetSession``, the fallback ladder and the chaos engine are thin
adapters over these.  :class:`ControlConfig` switches the within-plan
mechanisms (priority preemption, battery SoC, streamed migration);
everything defaults off, and the off-path is bit-identical to the
pre-control-plane runtime.
"""
from .battery import SOC_CHECK_LABEL, BatteryTracker
from .plane import (ControlConfig, ControlPlane, FleetControlPlane,
                    StaticPlane, _remap_plan, react_once)

__all__ = [
    "BatteryTracker", "ControlConfig", "ControlPlane", "FleetControlPlane",
    "SOC_CHECK_LABEL", "StaticPlane", "react_once", "_remap_plan",
]
