"""The real-time control plane — Dora's *single* dynamics-reaction layer.

Before this module existed, the §4.3 adapter logic was smeared across
four layers (``core/adapter.py``, ``dora.py``, ``fleet/session.py`` and
``resilience/engine.py``), each re-implementing state accumulation,
replan triggering and migration-stall billing slightly differently.
The control plane collapses those paths into one place:

* :class:`ControlPlane` owns one :class:`~repro.dora.ServeSession`'s
  cumulative :class:`~repro.core.adapter.RuntimeState`, plan arming,
  replan/fallback decisions and migration pricing.  ``ServeSession``,
  the fallback ladder and the chaos kernel are thin adapters over it.
* :class:`FleetControlPlane` does the same for a multi-tenant
  :class:`~repro.fleet.session.FleetSession` (event routing, rebalance,
  fallback adoption).
* :class:`StaticPlane` is the believed-state accumulator for
  *non-adaptive* baseline strategies (shared by the plain serving
  simulator and the chaos engine).
* :func:`react_once` is the session-less single-event reaction the
  standalone :meth:`RuntimeAdapter.on_dynamics` delegates to.

On top of the unified plane sit the three within-plan mechanisms the
replan-only adapter could not express, switched by
:class:`ControlConfig`: stage-level priority preemption (kernel-side,
:class:`repro.core.events.PreemptionSpec`), battery state-of-charge
(:mod:`repro.control.battery` + :meth:`ControlPlane.on_soc`) and
DEFER-style streamed migration
(``AdapterConfig.streamed_migration`` — overlap next-plan weight
transfer with current-plan execution).  With every mechanism at its
default-off setting the plane is bit-identical to the pre-refactor
per-session reaction paths.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

from ..core.adapter import (DynamicsEvent, RuntimeState, cold_load_stall)
from ..core.planner import DoraPlanner
from ..core.plans import ParallelismPlan

__all__ = [
    "ControlConfig", "ControlPlane", "FleetControlPlane", "StaticPlane",
    "react_once", "_remap_plan",
]


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    """Which control-plane mechanisms are armed, and their knobs.

    Everything defaults *off*: a session served without a config (or
    with ``ControlConfig()``) behaves bit-identically to the
    pre-control-plane runtime.
    """

    #: interactive :class:`~repro.core.events.RequestClass` requests
    #: (``priority > 0``) preempt queued batch admissions at the
    #: bottleneck stage (per-class Lindley recurrence in the kernel)
    preemption: bool = False
    #: pipeline-state save/restore overhead one preemption costs the
    #: displaced batch request (seconds)
    preempt_overhead_s: float = 0.005
    #: track per-device battery state of charge (``Device.battery_j``)
    #: and kill devices whose battery empties mid-run
    battery: bool = False
    #: proactively evacuate (announced leave-churn, async switch) a
    #: device *before* its projected battery death, instead of paying
    #: the unannounced synchronous switch at death
    battery_aware: bool = False
    #: how often the serving simulator checkpoints SoC (seconds)
    soc_check_interval_s: float = 5.0
    #: evacuate when projected time-to-death < margin × check interval
    soc_margin: float = 3.0
    #: DEFER-style streamed migration: overlap next-plan weight
    #: transfer with current-plan execution on the synchronous switch
    #: path (see ``AdapterConfig.streamed_migration``)
    streamed_migration: bool = False
    #: fraction of link bandwidth the stream may steal from serving
    stream_bw_fraction: float = 0.5


def _remap_plan(plan: ParallelismPlan,
                mapping: Dict[int, int]) -> Optional[ParallelismPlan]:
    """Project a plan into a re-indexed fleet (for delta-switch pricing
    across churn): stages keep only surviving devices, re-numbered via
    ``mapping``. Returns ``None`` when no stage survives at all."""
    stages = []
    for s in plan.stages:
        devs = [mapping[d] for d in s.devices if d in mapping]
        if not devs:
            continue
        split = {mapping[d]: s.microbatch_split[d]
                 for d in s.devices if d in mapping}
        stages.append(dataclasses.replace(s, devices=devs,
                                          microbatch_split=split))
    if not stages:
        return None
    return dataclasses.replace(plan, stages=stages)


def react_once(adapter, current: ParallelismPlan, event: DynamicsEvent,
               replan_fn=None, state: Optional[RuntimeState] = None
               ) -> Tuple[ParallelismPlan, str, float]:
    """Session-less single-event reaction (the legacy
    ``RuntimeAdapter.on_dynamics`` semantics): merge the event into the
    accumulated ``state`` (or take it as the complete picture) and let
    the adapter's pricing primitive react to the merged conditions."""
    prior = state if state is not None else RuntimeState()
    return adapter.react(current, prior.apply(event), prior.delta(event),
                         replan_fn)


class ControlPlane:
    """One ``ServeSession``'s reaction layer: cumulative state, plan
    arming, replan/fallback decisions and migration pricing.

    The plane mutates the session it serves (``state``, ``current``,
    ``adapter``, ``active``, ``plan_fleet``, ``plans``, ``degraded``)
    exactly as the pre-refactor per-session paths did — the session's
    public fields remain the single source of truth, so existing
    callers observe identical behavior.
    """

    def __init__(self, session, config: Optional[ControlConfig] = None):
        self.session = session
        self.config = config or ControlConfig()

    # -- state translation -------------------------------------------------------
    def translate(self, state: RuntimeState) -> RuntimeState:
        """Original-index conditions → plan-fleet index space.
        Bandwidth entries for links that left with their devices are
        filtered out (they come back into force on rejoin)."""
        session = self.session
        if session.plan_fleet == tuple(range(session.report.topology.n)):
            return state
        mapping = {orig: pos for pos, orig in enumerate(session.plan_fleet)}
        alive = session.adapter.topo.resources
        return RuntimeState(
            compute_speed={mapping[d]: v
                           for d, v in state.compute_speed.items()
                           if d in mapping},
            bandwidth_scale={k: v for k, v in state.bandwidth_scale.items()
                             if k in alive})

    # -- the single reaction path ------------------------------------------------
    def on_dynamics(self, event: DynamicsEvent,
                    replan: bool = True) -> Tuple[ParallelismPlan, str, float]:
        """Feed one runtime event to the adapter; track the active plan.

        Returns (new plan, action taken, reaction seconds).  ``replan``
        permits full replanning on large shifts; small fluctuations are
        absorbed with network-only rescheduling either way.  Device
        ``leave``/``join`` churn always replans (the fleet changed).
        The event is merged into the session's cumulative ``state``, so
        successive partial events compound instead of overwriting each
        other.
        """
        session = self.session
        if event.is_churn:
            return self.churn(event)
        if event.is_fault and not event.is_announced:
            # silent fault: the session cannot observe it (that is the
            # point of unannounced faults) — the resilience engine
            # reacts on *detection*, never on onset
            return session.current, "unobserved", 0.0
        if session.degraded:
            # no servable plan for the surviving fleet: absorb the
            # conditions into state so a recovery replan sees them
            session.state = session.state.apply(event)
            return session.current, "degraded", 0.0
        prior = session.state
        merged = prior.apply(event)
        replan_fn = (lambda: list(session.plans)) if replan else None
        new, action, react = session.adapter.react(
            session.current, self.translate(merged), prior.delta(event),
            replan_fn)
        session.state = merged
        session.current = new
        return new, action, react

    def churn(self, event: DynamicsEvent
              ) -> Tuple[ParallelismPlan, str, float]:
        """Devices left/joined: replan from scratch on the new fleet."""
        session = self.session
        t0 = time.perf_counter()
        full = session.report.topology
        bad = [d for d in (*event.leave, *event.join)
               if not (0 <= d < full.n)]
        if bad:
            raise ValueError(f"churn references unknown devices {bad} "
                             f"(deployment has {full.n})")
        fleet = (set(session.active) - set(event.leave)) | set(event.join)
        if not fleet:
            raise ValueError("churn event would remove every device")
        merged = session.state.apply(event)
        keep = tuple(sorted(fleet))
        try:
            sub, mapping = full.subset(keep)
            # ``full`` is the session's calibrated topology, so the
            # default (identity) cost provider is correct here —
            # re-passing the original CostProvider would calibrate twice
            planner = DoraPlanner(session.report.graph, sub,
                                  session.report.qoe,
                                  partitioner_config=session.partitioner_config,
                                  scheduler_config=session.scheduler_config,
                                  adapter_config=session.adapter.config)
            # plan-fleet device -> new-fleet device (drops leavers)
            trans = {pos: mapping[orig]
                     for pos, orig in enumerate(session.plan_fleet)
                     if orig in mapping}
            if session.warm_replan and not event.join:
                # device-LEAVE churn is the latency-critical replan
                # (capacity dropped mid-service): warm-start from the
                # surviving candidate pool (§4.3 — steady-state replans
                # are ~pool-sized), falling back to the fresh DP when
                # nothing survives QoE-feasibly.  JOIN churn always runs
                # the full search — surviving candidates place no work
                # on the new device, so only a fresh DP can reclaim its
                # capacity, and the old plan keeps serving meanwhile.
                result = planner.replan(session.report.workload,
                                        session.plans, mapping=trans)
            else:
                result = planner.plan(session.report.workload)
        except (ValueError, RuntimeError):
            # survivors disconnect the routed topology (Topology.subset)
            # or admit no plan at all: go QoE-infeasible for this
            # segment instead of crashing. ``plan_fleet`` keeps the old
            # indexing so a later rejoin replans from it and recovers.
            session.active = keep
            session.state = merged
            session.degraded = True
            return session.current, "degraded", time.perf_counter() - t0
        adapter = planner.make_adapter(result)
        new = result.best
        cond = RuntimeState(
            compute_speed={mapping[d]: v
                           for d, v in merged.compute_speed.items()
                           if d in mapping},
            bandwidth_scale={k: v
                             for k, v in merged.bandwidth_scale.items()
                             if k in planner.topo.resources})
        if cond.compute_speed or cond.bandwidth_scale:
            new = adapter.scheduler.refine(
                new, compute_speed=dict(cond.compute_speed),
                bandwidth_scale=dict(cond.bandwidth_scale))
        # migration stall: the old plan re-indexed into the new fleet
        # prices delta switching (layers already resident stay put)
        proxy = _remap_plan(session.current, trans)
        if proxy is not None:
            stall = adapter.switch_cost(proxy, new)
        else:   # nothing survives: cold-load the whole new plan
            stall = cold_load_stall(new, sub, adapter.config)
        new.meta["switch_stall_s"] = stall
        new.meta["fleet"] = list(keep)
        new.meta["warm_replan"] = result.warm_start
        session.adapter = adapter
        session.active = keep
        session.plan_fleet = keep
        session.degraded = False
        session.state = merged
        session.plans = list(result.candidates)
        session.current = new
        return new, "replan", time.perf_counter() - t0

    # -- fallback adoption (resilience ladder) -----------------------------------
    def adopt_fallback(self, entry) -> float:
        """Switch the session to a precomputed
        :class:`~repro.resilience.ladder.LadderEntry`.  Returns the
        stall (drain only — fallback weights are prestaged).  Mirrors
        :meth:`churn`'s bookkeeping."""
        session = self.session
        adapter = entry.planner.make_adapter(entry.result)
        new = entry.result.best
        merged = session.state
        cond = RuntimeState(
            compute_speed={entry.mapping[d]: v
                           for d, v in merged.compute_speed.items()
                           if d in entry.mapping},
            bandwidth_scale={k: v for k, v in merged.bandwidth_scale.items()
                             if k in entry.planner.topo.resources})
        if cond.compute_speed or cond.bandwidth_scale:
            new = adapter.scheduler.refine(
                new, compute_speed=dict(cond.compute_speed),
                bandwidth_scale=dict(cond.bandwidth_scale))
        stall = adapter.config.switch_drain_s
        new.meta["switch_stall_s"] = stall
        new.meta["fleet"] = list(entry.keep)
        new.meta["fallback"] = True
        session.adapter = adapter
        session.active = entry.keep
        session.plan_fleet = entry.keep
        session.degraded = False
        session.plans = list(entry.result.candidates)
        session.current = new
        return stall

    # -- detection reactions (chaos engine) --------------------------------------
    def on_detection(self, rec: Dict[str, object], *, config,
                     ladder=None) -> Tuple[str, float, float]:
        """React to one *detected* fault (the chaos engine's recovery
        path).  ``rec`` is the engine's fault record, ``config`` a
        :class:`~repro.resilience.ResilienceConfig`.  Returns
        (action, react_s, stall_s)."""
        session = self.session
        kind, tgt = rec["kind"], rec["target"]
        if kind == "crash":
            if tgt not in session.active:
                return "unobserved", 0.0, 0.0
            t0 = time.perf_counter()
            if ladder is not None:
                stall = ladder.apply({tgt})
                if stall is not None:
                    ladder.build()       # background refresh of scopes
                    return "fallback", time.perf_counter() - t0, stall
            # naive replan-on-detect: the dead pipeline cannot overlap
            # the prefetch (async) nor stream ahead of the switch, so
            # the migration is priced fully synchronously
            cfg = session.adapter.config
            prev_async = cfg.async_switching
            prev_stream = cfg.streamed_migration
            cfg.async_switching = False
            cfg.streamed_migration = False
            try:
                new, act, react = self.on_dynamics(
                    DynamicsEvent(t=rec["t"], leave=(tgt,)))
            finally:
                session.adapter.config.async_switching = prev_async
                session.adapter.config.streamed_migration = prev_stream
                cfg.async_switching = prev_async
                cfg.streamed_migration = prev_stream
            stall = (float(new.meta.get("switch_stall_s", 0.0))
                     if act == "replan" else 0.0)
            if ladder is not None:
                ladder.build()
            return act, react, stall
        if kind in ("link_down", "link_up"):
            scale = (config.link_down_scale if kind == "link_down" else 1.0)
            ev = DynamicsEvent(t=rec["t"] + config.detection_window_s,
                               bandwidth_scale={tgt: scale})
            new, act, react = self.on_dynamics(ev)
            stall = (float(new.meta.get("switch_stall_s", 0.0))
                     if act == "replan" else 0.0)
            return act, react, stall
        # straggler (or its recovery): the believed speed realigns
        ev = DynamicsEvent(t=rec["t"] + config.detection_window_s,
                           compute_speed={tgt: rec.get("factor", 1.0)})
        new, act, react = self.on_dynamics(ev)
        stall = (float(new.meta.get("switch_stall_s", 0.0))
                 if act == "replan" else 0.0)
        return act, react, stall

    # -- battery state of charge (mechanism 2) -----------------------------------
    def on_soc(self, t: float, tracker, newly_dead=(), *,
               config: Optional[ControlConfig] = None
               ) -> List[Tuple[str, DynamicsEvent, str, float, float]]:
        """One SoC checkpoint: react to battery deaths, and (when
        ``battery_aware``) evacuate devices *before* their projected
        death.  Returns ``[(label, event, action, react_s, stall_s)]``
        — one row per churn the plane initiated (the serving simulator
        books presence/stalls from these).  ``config`` overrides the
        plane's own for this checkpoint (the serving simulator passes
        its per-run ``control=``)."""
        session = self.session
        cc = config if config is not None else self.config
        out: List[Tuple[str, DynamicsEvent, str, float, float]] = []
        for d in sorted(newly_dead):
            if d not in session.active:
                continue
            # unannounced death: the dead pipeline can neither overlap
            # the prefetch nor stream ahead — fully synchronous switch
            ev = DynamicsEvent(t=t, leave=(d,))
            cfg = session.adapter.config
            prev_async = cfg.async_switching
            prev_stream = cfg.streamed_migration
            cfg.async_switching = False
            cfg.streamed_migration = False
            try:
                new, act, react = self.on_dynamics(ev)
            finally:
                session.adapter.config.async_switching = prev_async
                session.adapter.config.streamed_migration = prev_stream
                cfg.async_switching = prev_async
                cfg.streamed_migration = prev_stream
            stall = (float(new.meta.get("switch_stall_s", 0.0))
                     if act == "replan" else 0.0)
            out.append((f"battery dead: device {d}", ev, act, react, stall))
        if not cc.battery_aware:
            return out
        horizon = cc.soc_margin * cc.soc_check_interval_s
        for d in sorted(set(session.active) & set(tracker.capacity)):
            if session.degraded or len(session.active) <= 1:
                break
            if d in tracker.dead:
                continue
            ttd = tracker.time_to_death(d)
            if ttd is None or ttd >= horizon:
                continue
            # announced evacuation: the device is still serving, so the
            # replacement plan's weights prefetch asynchronously — the
            # priced stall is the drain, not a dead-pipeline reload
            ev = DynamicsEvent(t=t, leave=(d,))
            new, act, react = self.on_dynamics(ev)
            stall = (float(new.meta.get("switch_stall_s", 0.0))
                     if act == "replan" else 0.0)
            out.append((f"battery low: evacuating device {d} "
                        f"(t_dead~{ttd:.0f}s)", ev, act, react, stall))
        return out


class StaticPlane:
    """Believed-state accumulator for a *non-adaptive* strategy: the
    merged conditions plus fleet membership.  A static plan never
    reroutes, so it is alive iff every device it placed layers on is
    still in the fleet; repricing under the merged conditions stays
    with the caller (it owns the scheduler)."""

    def __init__(self, n_devices: int, plan_devices):
        self.state = RuntimeState()
        self.fleet = set(range(n_devices))
        self.devices = set(plan_devices)

    def apply(self, event: DynamicsEvent) -> bool:
        """Merge one event; returns whether the static plan still has
        all its devices."""
        self.state = self.state.apply(event)
        self.fleet.difference_update(event.leave)
        self.fleet.update(event.join)
        return self.alive

    @property
    def alive(self) -> bool:
        return self.devices <= self.fleet


class FleetControlPlane:
    """One ``FleetSession``'s reaction layer: event routing to tenant
    planes, cross-tenant rebalancing and fleet fallback adoption."""

    def __init__(self, session, config: Optional[ControlConfig] = None):
        self.session = session
        self.config = config or ControlConfig()

    def on_dynamics(self, event: DynamicsEvent) -> list:
        """Feed one fleet-space runtime event to every affected tenant.

        Churn always rebalances; condition shifts route to the owning
        tenants' adapters, then trigger a rebalance if some tenant is
        left QoE-infeasible (and ``FleetConfig.rebalance_on_load``).
        Returns the actions taken, one per affected tenant.
        """
        from ..fleet.session import TenantAction

        session = self.session
        if event.is_churn:
            return self.rebalance(event)
        merged = session.state.apply(event)
        actions: List[TenantAction] = []
        for name, tp in session.plan.tenants.items():
            local = session._local_event(tp, event)
            if local is None:
                continue
            sess = session.sessions[name]
            new, act, react = sess.on_dynamics(local)
            stall = (float(new.meta.get("switch_stall_s", 0.0))
                     if act == "replan" else 0.0)
            actions.append(TenantAction(tenant=name, action=act,
                                        react_s=react, stall_s=stall,
                                        latency_after=new.latency,
                                        allotment=tp.allotment))
        session.state = merged
        if (session.planner.config.rebalance_on_load
                and any(not s.meets_qoe for s in session.sessions.values())):
            actions += self.rebalance(None)
        return actions

    def rebalance(self, event: Optional[DynamicsEvent]) -> list:
        """Re-run the assignment search on the surviving fleet and move
        devices between tenants; no-op when the incumbent assignment is
        still the joint winner."""
        from ..fleet.session import TenantAction, _orig_placement

        session = self.session
        t0 = time.perf_counter()
        if event is not None:
            full_n = session.planner.topo.n
            bad = [d for d in (*event.leave, *event.join)
                   if not (0 <= d < full_n)]
            if bad:
                raise ValueError(f"churn references unknown devices {bad} "
                                 f"(fleet has {full_n})")
            fleet = (set(session.active) - set(event.leave)) \
                | set(event.join)
            if len(fleet) < len(session.planner.tenants):
                raise ValueError(
                    f"churn leaves {sorted(fleet)}: not enough devices for "
                    f"{len(session.planner.tenants)} exclusive tenants")
            merged = session.state.apply(event)
        else:
            fleet = set(session.active)
            merged = session.state
        warm = {name: (list(sess.plans),
                       session.plan.tenants[name].allotment)
                for name, sess in session.sessions.items()}
        conditions = merged if (merged.compute_speed
                                or merged.bandwidth_scale) else None
        new_plan = session.planner.plan(devices=sorted(fleet), warm=warm,
                                        conditions=conditions,
                                        include=[session.plan.assignments])
        if (event is None
                and new_plan.assignments == session.plan.assignments):
            # load-shift probe: moving devices doesn't help — stay put
            return []
        actions: List[TenantAction] = []
        old_plan = session.plan
        # a kept session is only valid if its shared-link pricing is
        # unchanged too — another tenant's move can change the medium's
        # user count and with it this tenant's fair share
        shares_of = session.planner.link_shares
        old_shares = shares_of(list(old_plan.assignments.values()))
        new_shares = shares_of(list(new_plan.assignments.values()))
        new_sessions: Dict[str, object] = {}
        for name, tp in new_plan.tenants.items():
            old_tp = old_plan.tenants.get(name)
            if (old_tp is not None and old_tp.allotment == tp.allotment
                    and session.planner._factors_key(tp.allotment,
                                                     old_shares)
                    == session.planner._factors_key(tp.allotment,
                                                    new_shares)):
                # same allotment, same link shares: keep the tenant's
                # adapted session (pareto pool and cumulative state are
                # already right) — but a churn event can carry condition
                # shifts too, and those must still reach the tenant
                sess = session.sessions[name]
                local = session._local_event(tp, event) \
                    if event is not None else None
                if local is not None:
                    new, act, react = sess.on_dynamics(local)
                    actions.append(TenantAction(
                        tenant=name, action=act, react_s=react,
                        stall_s=(float(new.meta.get("switch_stall_s", 0.0))
                                 if act == "replan" else 0.0),
                        latency_after=new.latency,
                        allotment=tp.allotment))
                new_sessions[name] = sess
                continue
            sess = session._arm_tenant(tp,
                                       state=session._local_state(tp, merged))
            stall = 0.0
            if old_tp is not None:
                old_current = session.sessions[name].current
                if (_orig_placement(old_current, old_tp)
                        != _orig_placement(sess.current, tp)):
                    # only a placement that actually moved pays migration
                    stall = session._migration_stall(
                        old_current, old_tp, tp, sess)
            sess.current.meta["switch_stall_s"] = stall
            sess.current.meta["fleet"] = list(tp.allotment)
            new_sessions[name] = sess
            actions.append(TenantAction(
                tenant=name, action="rebalance",
                react_s=time.perf_counter() - t0, stall_s=stall,
                latency_after=sess.current.latency,
                allotment=tp.allotment))
        session.plan = new_plan
        session.sessions = new_sessions
        session.active = tuple(sorted(fleet))
        session.state = merged
        session.rebalances += 1
        if event is not None and not actions:
            # churn that didn't move any allotment still reacted
            actions.append(TenantAction(
                tenant="*", action="rebalance",
                react_s=time.perf_counter() - t0, stall_s=0.0,
                latency_after=math.nan, allotment=session.active))
        return actions

    def adopt_fallback(self, lost, new_plan) -> list:
        """Adopt a precomputed fleet fallback plan for the loss scope
        ``lost``: mirrors :meth:`rebalance` adoption, but every moved
        tenant pays only the drain (fallback weights are prestaged).
        Returns the tenant actions."""
        from ..fleet.session import TenantAction, _orig_placement

        session = self.session
        old_plan = session.plan
        shares_of = session.planner.link_shares
        old_shares = shares_of(list(old_plan.assignments.values()))
        new_shares = shares_of(list(new_plan.assignments.values()))
        actions: List[TenantAction] = []
        new_sessions: Dict[str, object] = {}
        for name, tp in new_plan.tenants.items():
            old_tp = old_plan.tenants.get(name)
            if (old_tp is not None and old_tp.allotment == tp.allotment
                    and session.planner._factors_key(tp.allotment,
                                                     old_shares)
                    == session.planner._factors_key(tp.allotment,
                                                    new_shares)):
                new_sessions[name] = session.sessions[name]
                continue
            sess = session._arm_tenant(
                tp, state=session._local_state(tp, session.state))
            stall = 0.0
            if old_tp is not None:
                old_current = session.sessions[name].current
                if (_orig_placement(old_current, old_tp)
                        != _orig_placement(sess.current, tp)):
                    # prestaged: drain only, no weight load
                    stall = sess.adapter.config.switch_drain_s
            sess.current.meta["switch_stall_s"] = stall
            sess.current.meta["fleet"] = list(tp.allotment)
            sess.current.meta["fallback"] = True
            new_sessions[name] = sess
            actions.append(TenantAction(
                tenant=name, action="fallback", react_s=0.0, stall_s=stall,
                latency_after=sess.current.latency, allotment=tp.allotment))
        session.plan = new_plan
        session.sessions = new_sessions
        session.active = tuple(sorted(
            set(session.active) - frozenset(lost)))
        session.rebalances += 1
        return actions
