"""Battery state of charge as a depletable per-device resource.

Edge fleets are not wall-powered: a phone or battery-backed board has a
finite energy budget (``DeviceProfile.battery_j``), and a plan that
looks QoE-optimal on paper dies mid-horizon when the device it leans on
empties.  :class:`BatteryTracker` integrates the serving kernel's
per-device energy attribution (idle draw over presence + the per-request
service energy the kernel already books) against those budgets, so the
control plane can re-cost and re-rank plans *before* the battery event
(:meth:`repro.control.plane.ControlPlane.on_soc`) instead of reacting
to a dead device after the fact.

The tracker is deliberately simulator-side: it consumes the same
``stream.service_energy`` dictionary every trace already reports, so
battery accounting and trace energy accounting can never diverge.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set

__all__ = ["SOC_CHECK_LABEL", "BatteryTracker"]

#: timeline label marking an injected SoC checkpoint; the serving
#: simulator intercepts it before the (content-free) event would reach
#: the session's reaction path
SOC_CHECK_LABEL = "__soc_check__"


class BatteryTracker:
    """Integrates per-device drain against finite battery capacities.

    Only devices with ``battery_j is not None`` are tracked; everything
    else is treated as wall-powered.  ``advance`` bills idle draw for
    present devices over the elapsed interval and absorbs the kernel's
    cumulative service-energy attribution as deltas, then reports which
    devices crossed their capacity.
    """

    def __init__(self, devices: Sequence) -> None:
        self.capacity: Dict[int, float] = {
            d: float(dev.battery_j) for d, dev in enumerate(devices)
            if getattr(dev, "battery_j", None) is not None}
        self.p_idle: Dict[int, float] = {
            d: devices[d].p_idle for d in self.capacity}
        self.drained: Dict[int, float] = {d: 0.0 for d in self.capacity}
        self._seen: Dict[int, float] = {d: 0.0 for d in self.capacity}
        self._rate: Dict[int, float] = {d: 0.0 for d in self.capacity}
        self.dead: Set[int] = set()
        self.last_t = 0.0

    def advance(self, t: float, service_energy: Dict[int, float],
                present) -> List[int]:
        """Integrate drain up to ``t``; returns devices that just died.

        ``service_energy`` is the kernel stream's cumulative per-device
        service energy (original device ids); ``present`` the set of
        devices currently in the fleet (absent devices stop draining).
        """
        dt = max(float(t) - self.last_t, 0.0)
        newly: List[int] = []
        for d in self.capacity:
            if d in self.dead:
                continue
            before = self.drained[d]
            if d in present and dt > 0.0:
                self.drained[d] += self.p_idle[d] * dt
            se = float(service_energy.get(d, 0.0))
            if se > self._seen[d]:
                self.drained[d] += se - self._seen[d]
                self._seen[d] = se
            if dt > 0.0:
                inst = (self.drained[d] - before) / dt
                prev = self._rate[d]
                # EMA-smoothed: service energy arrives in bursts, and a
                # raw per-interval rate makes the death projection
                # flap between checkpoints
                self._rate[d] = inst if prev <= 0.0 \
                    else 0.5 * inst + 0.5 * prev
            if self.drained[d] >= self.capacity[d]:
                self.dead.add(d)
                newly.append(d)
        self.last_t = float(t)
        return newly

    def remaining(self, d: int) -> float:
        return max(self.capacity[d] - self.drained[d], 0.0)

    def time_to_death(self, d: int) -> Optional[float]:
        """Projected seconds until ``d`` empties at its last observed
        drain rate; ``None`` when no drain has been observed yet."""
        if d in self.dead:
            return 0.0
        rate = self._rate.get(d, 0.0)
        if rate <= 0.0:
            return None
        return self.remaining(d) / rate

    def soc(self, d: int) -> float:
        """State of charge in [0, 1]."""
        return self.remaining(d) / self.capacity[d]
