"""The ``PlannerStrategy`` protocol and its decorator-based registry.

Dora's headline numbers are *comparative* — they only mean something
against other planners.  This module makes every planner (Dora itself,
the paper's baselines, new split heuristics) a first-class, swappable
citizen behind one protocol::

    class PlannerStrategy(Protocol):
        name: str
        contention_aware: bool
        def plan(graph, topology, qoe, workload, costs=None) -> PlanningResult

``contention_aware`` declares whether the strategy prices its plans on
the real shared medium itself (Dora's Phase 2) — oblivious strategies
must return plans already *executed* under fluid-fair contention, which
is what a contention-oblivious plan actually suffers (Fig. 2); the
``fair_executed`` helper does exactly that.

Strategies register with the :func:`register_strategy` class decorator
and are resolved by name through :func:`get_strategy`, which also
forwards constructor keywords (``get_strategy("brute_force",
shortlist=150)``).  Consumers: ``dora.plan(scenario, strategy=...)``,
``dora.compare``, ``sim.runner.compare_planners``, the fig-benchmarks
and ``python -m repro.scenarios --strategy/--compare``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Protocol, Sequence, Type, Union, \
    runtime_checkable

from ..core.adapter import pareto_filter
from ..core.cost_model import CostProvider, Workload
from ..core.device import Topology
from ..core.planner import PlanningResult
from ..core.planning_graph import ModelGraph
from ..core.plans import ParallelismPlan
from ..core.qoe import QoESpec
from ..core.scheduler import NetworkScheduler


class StrategyError(RuntimeError):
    """Strategy could not produce a valid plan (e.g. EdgeShard OOM)."""


@runtime_checkable
class PlannerStrategy(Protocol):
    """One hybrid-parallelism planner behind a uniform entry point."""

    name: str
    contention_aware: bool

    def plan(self, graph: ModelGraph, topology: Topology, qoe: QoESpec,
             workload: Workload,
             costs: Optional[CostProvider] = None) -> PlanningResult:
        """Plan ``graph`` on ``topology`` for ``workload`` under ``qoe``.

        Returned latencies/energies must be real-topology numbers:
        contention-aware strategies price contention themselves,
        oblivious ones report what their plan suffers under fluid-fair
        sharing (``fair_executed``). Raises :class:`StrategyError` when
        no valid plan exists."""
        ...


_REGISTRY: Dict[str, Type] = {}

StrategyRef = Union[str, PlannerStrategy]


def register_strategy(cls: Type) -> Type:
    """Class decorator: register ``cls`` under its ``name`` attribute."""
    name = getattr(cls, "name", None)
    if not isinstance(name, str) or not name:
        raise ValueError(f"{cls!r} needs a non-empty string `name` attribute")
    if name in _REGISTRY:
        raise ValueError(f"planner strategy {name!r} already registered")
    _REGISTRY[name] = cls
    return cls


def list_strategies() -> List[str]:
    """Names of all registered strategies, sorted."""
    return sorted(_REGISTRY)


def get_strategy(ref: StrategyRef, **params) -> PlannerStrategy:
    """Resolve a strategy name to a fresh instance (or pass through an
    already-constructed strategy object).  ``params`` are forwarded to
    the strategy constructor when resolving by name."""
    if isinstance(ref, str):
        try:
            cls = _REGISTRY[ref]
        except KeyError:
            known = ", ".join(sorted(_REGISTRY))
            raise ValueError(f"unknown planner strategy {ref!r}; "
                             f"registered: {known}") from None
        return cls(**params)
    if params:
        raise ValueError("constructor params only apply when resolving a "
                         "strategy by name")
    return ref


# ----------------------------------------------------------------------------
# shared helpers for strategy implementations
# ----------------------------------------------------------------------------
def fair_executed(plan: ParallelismPlan, topo: Topology,
                  qoe: QoESpec) -> ParallelismPlan:
    """Price one plan under real fluid-shared contention (what a
    contention-oblivious plan actually experiences, Fig. 2)."""
    return NetworkScheduler(topo, qoe).evaluate_fair(plan)


def as_result(plans: Sequence[ParallelismPlan], phase1_s: float,
              phase2_s: float) -> PlanningResult:
    """Wrap already-priced plans into a :class:`PlanningResult` (ranked
    best-first by objective, Pareto frontier attached)."""
    if not plans:
        raise StrategyError("strategy produced no plan")
    ranked = sorted(plans, key=lambda p: p.objective)
    return PlanningResult(best=ranked[0], candidates=ranked,
                          pareto=pareto_filter(ranked),
                          phase1_s=phase1_s, phase2_s=phase2_s)


class _Stopwatch:
    """Tiny helper: ``lap()`` returns seconds since the previous lap."""

    def __init__(self):
        self._t = time.perf_counter()

    def lap(self) -> float:
        now = time.perf_counter()
        dt, self._t = now - self._t, now
        return dt
