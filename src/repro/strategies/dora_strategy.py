"""The ``dora`` strategy — Algorithm 1 behind the registry protocol.

A thin, transformation-free wrapper over :class:`core.planner.DoraPlanner`:
given the same configuration, ``get_strategy("dora").plan(...)`` returns
exactly what calling ``DoraPlanner`` directly returns (tests assert the
plans are byte-identical).  Convenience knobs ``top_k``/
``sweep_microbatch`` build the richer search configuration the
benchmark harnesses use (``sim.runner.dora_plan``).
"""
from __future__ import annotations

from typing import Optional

from ..core.adapter import AdapterConfig
from ..core.cost_model import CostProvider, Workload
from ..core.device import Topology
from ..core.partitioner import PartitionerConfig
from ..core.planner import DoraPlanner, PlanningResult
from ..core.planning_graph import ModelGraph
from ..core.qoe import QoESpec
from ..core.scheduler import SchedulerConfig
from .base import register_strategy
from .baselines import _mb_sweep


@register_strategy
class DoraStrategy:
    """QoE-aware three-phase planning (partition → schedule → Pareto)."""

    name = "dora"
    contention_aware = True

    def __init__(self,
                 partitioner_config: Optional[PartitionerConfig] = None,
                 scheduler_config: Optional[SchedulerConfig] = None,
                 adapter_config: Optional[AdapterConfig] = None,
                 top_k: Optional[int] = None,
                 sweep_microbatch: bool = False):
        if partitioner_config is not None and (top_k or sweep_microbatch):
            raise ValueError("pass either partitioner_config or the "
                             "top_k/sweep_microbatch shorthands, not both")
        self.partitioner_config = partitioner_config
        self.scheduler_config = scheduler_config
        self.adapter_config = adapter_config
        self.top_k = top_k
        self.sweep_microbatch = sweep_microbatch

    def _partitioner_config(self, wl: Workload) -> Optional[PartitionerConfig]:
        if self.partitioner_config is not None:
            return self.partitioner_config
        if self.top_k is None and not self.sweep_microbatch:
            return None                      # DoraPlanner defaults
        return PartitionerConfig(
            top_k=self.top_k or 10,
            microbatch_sizes=_mb_sweep(wl) if self.sweep_microbatch else ())

    def planner(self, graph: ModelGraph, topology: Topology, qoe: QoESpec,
                workload: Workload,
                costs: Optional[CostProvider] = None) -> DoraPlanner:
        """The configured raw planner (for callers that also want the
        adapter, e.g. ``dora.serve``)."""
        return DoraPlanner(graph, topology, qoe,
                           partitioner_config=self._partitioner_config(workload),
                           scheduler_config=self.scheduler_config,
                           adapter_config=self.adapter_config,
                           costs=costs)

    def plan(self, graph: ModelGraph, topology: Topology, qoe: QoESpec,
             workload: Workload,
             costs: Optional[CostProvider] = None) -> PlanningResult:
        return self.planner(graph, topology, qoe, workload,
                            costs=costs).plan(workload)
