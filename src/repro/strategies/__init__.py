"""``repro.strategies`` — pluggable planner strategies, one registry.

Every hybrid-parallelism planner in this repo — Dora itself, the paper's
§6.1 baselines, and split heuristics from related work — implements the
:class:`PlannerStrategy` protocol and registers under a name:

========================  ====================================================
``dora``                  Algorithm 1: partition → schedule → Pareto (QoE-aware)
``throughput_max``        rate-optimal planning on the real topology
``chain_split``           DistrEdge-style speed-balanced layer chaining
``memory_balanced``       chain split balanced on device memory
``pareto_split``          "Where to Split?" split-point Pareto analysis
``edgeshard``             even layer chain, memory-oblivious (EdgeShard-like)
``asteroid``              throughput-max under idealized D2D (Asteroid-like)
``alpa``                  homogeneous-cluster automation (Alpa-like)
``metis``                 balanced compute, uniform network (Metis-like)
``brute_force``           exhaustive split search, contention-priced shortlist
========================  ====================================================

Resolve with :func:`get_strategy` (constructor keywords forwarded), list
with :func:`list_strategies`, and add your own planner with::

    from repro.strategies import register_strategy

    @register_strategy
    class MyStrategy:
        name = "my_planner"
        contention_aware = False
        def plan(self, graph, topology, qoe, workload, costs=None):
            ...

Cost fidelity is orthogonal: every ``plan`` accepts a ``costs=``
:class:`repro.core.cost_model.CostProvider` (analytic rooflines by
default, measurement-calibrated with
:class:`repro.core.profiler.ProfiledCosts`).
"""
from __future__ import annotations

from ..core.cost_model import ANALYTIC_COSTS, AnalyticCosts, CostProvider, \
    resolve_costs
from ..core.profiler import ProfiledCosts
from .base import PlannerStrategy, StrategyError, StrategyRef, as_result, \
    fair_executed, get_strategy, list_strategies, register_strategy

# Importing these modules registers the built-in strategies.
from . import baselines  # noqa: E402,F401  (registration side effects)
from . import dora_strategy  # noqa: E402,F401
from . import splits  # noqa: E402,F401

from .baselines import BaselineError  # noqa: E402
from .dora_strategy import DoraStrategy  # noqa: E402

__all__ = [
    "PlannerStrategy", "StrategyError", "StrategyRef", "BaselineError",
    "register_strategy", "get_strategy", "list_strategies",
    "as_result", "fair_executed", "DoraStrategy",
    "CostProvider", "AnalyticCosts", "ANALYTIC_COSTS", "ProfiledCosts",
    "resolve_costs",
]
