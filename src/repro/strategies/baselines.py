"""Baseline hybrid-parallelism planners (§6.1) + brute-force optimal.

Each baseline reproduces the *planning assumptions* of the cited system;
the strategy wrappers then price every plan on the REAL topology under
fluid-shared contention (what a contention-oblivious plan actually
suffers, Fig. 2):

* ``edgeshard`` — pipeline-only, even layer split, one device per
  stage, memory-oblivious (EdgeShard [33]; OOMs in Traffic Monitor).
* ``asteroid``  — heterogeneity-aware hybrid PP+DP maximizing raw
  throughput under idealized contention-free D2D links (Asteroid [30]).
* ``alpa``      — DP/PP/TP automation assuming HOMOGENEOUS devices
  and uniform bandwidth (Alpa [38]): stages balanced for the mean
  device, uniform microbatch split.
* ``metis``     — heterogeneity-aware load balancing (Metis [26])
  but with a uniform, contention-free network model.
* ``brute_force`` — exhaustive search over (contiguous stage splits ×
  ordered device groupings), each shortlisted candidate executed under
  the real contention model ("Optimal" in Fig. 2).

The plain ``*_plan`` functions remain importable (``repro.sim`` keeps
re-exporting them), but all benchmark/facade resolution goes through the
strategy registry (:mod:`repro.strategies.base`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from ..core.cost_model import CostModel, CostProvider, Workload, resolve_costs
from ..core.device import DeviceProfile, LinkResource, Topology
from ..core.partitioner import ModelPartitioner, PartitionerConfig
from ..core.planner import PlanningResult
from ..core.planning_graph import ModelGraph
from ..core.plans import ParallelismPlan, Stage
from ..core.qoe import QoESpec
from .base import StrategyError, _Stopwatch, as_result, fair_executed, \
    register_strategy

LATENCY_ONLY = QoESpec(t_qoe=0.0, lam=1e15)   # objective ≈ pure latency

#: Back-compat alias — ``repro.sim`` has always raised ``BaselineError``.
BaselineError = StrategyError


# ----------------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------------
def _uniform_split(devices: Sequence[int]) -> Dict[int, float]:
    return {d: 1.0 / len(devices) for d in devices}


def reprice_stage(cm: CostModel, st: Stage, topo: Topology) -> Stage:
    """Recompute stage times under the REAL device speeds for the stage's
    (possibly non-proportional) microbatch split: a replica group finishes
    when its slowest member does. Includes the weight-streaming roofline
    term (every replica reads the stage weights once per microbatch)."""
    t_f = t_b = 0.0
    w_read = st.param_bytes / max(st.tp_degree, 1)
    for d in st.devices:
        dev = topo.devices[d]
        share = st.microbatch_split[d]
        f = dev.effective_flops(st.tp_degree)
        t_f = max(t_f, st.flops_fwd * share / f, w_read / dev.mem_bw)
        if st.flops_bwd > 0:
            t_b = max(t_b, st.flops_bwd * share / f, 2.0 * w_read / dev.mem_bw)
    return dataclasses.replace(st, fwd_time=t_f, bwd_time=t_b)


def _contiguous_splits(n_items: int, n_parts: int) -> Iterable[Tuple[int, ...]]:
    """Yield sizes of contiguous partitions of n_items into n_parts ≥1 parts."""
    if n_parts == 1:
        yield (n_items,)
        return
    for first in range(1, n_items - n_parts + 2):
        for rest in _contiguous_splits(n_items - first, n_parts - 1):
            yield (first,) + rest


def _chain_nodes(graph: ModelGraph) -> List[int]:
    """Serialized node order (baselines treat the model as a chain)."""
    return graph.topological_order()


def _balance_boundaries(costs: Sequence[float], weights: Sequence[float]
                        ) -> List[int]:
    """Split ``costs`` into len(weights) contiguous groups with group cost
    ≈ proportional to ``weights`` (prefix-sum walk)."""
    total = sum(costs)
    targets = [w / sum(weights) * total for w in weights]
    sizes: List[int] = []
    i = 0
    for s, tgt in enumerate(targets):
        remaining_parts = len(targets) - s - 1
        acc = 0.0
        j = i
        # leave at least one node per remaining part
        while j < len(costs) - remaining_parts and (acc < tgt or j == i):
            nxt = acc + costs[j]
            if acc >= tgt * 0.5 and nxt > tgt * 1.5 and j > i:
                break
            acc = nxt
            j += 1
        sizes.append(j - i)
        i = j
    if i < len(costs):
        sizes[-1] += len(costs) - i
    return sizes


def _make_plan(graph: ModelGraph, topo: Topology, wl: Workload, qoe: QoESpec,
               groups: Sequence[Sequence[int]],
               device_groups: Sequence[Sequence[int]],
               uniform_split: bool = False,
               schedule: str = "1f1b") -> ParallelismPlan:
    cm = CostModel(graph, topo, wl)
    stages: List[Stage] = []
    for node_ids, devs in zip(groups, device_groups):
        st = cm.make_stage(list(node_ids), list(devs))
        if uniform_split:
            st = dataclasses.replace(st, microbatch_split=_uniform_split(devs))
            st = reprice_stage(cm, st, topo)
        stages.append(st)
    return cm.evaluate(stages, qoe, schedule)


def plan_memory_ok(plan: ParallelismPlan, topo: Topology
                   ) -> Tuple[bool, Optional[str]]:
    """True memory check against the plan's evaluated per-device usage
    (the evaluating schedule — GPipe vs 1F1B — already determined the
    in-flight activation count baked into ``per_device_memory``)."""
    for idx, (d, used) in enumerate(plan.per_device_memory.items()):
        if used > topo.devices[d].memory:
            return False, (f"device {d} ({topo.devices[d].name}) needs "
                           f"{used / 1e9:.1f} GB > {topo.devices[d].memory / 1e9:.1f} GB")
    return True, None


# ----------------------------------------------------------------------------
# EdgeShard — pipeline-only, even layer split, memory-oblivious
# ----------------------------------------------------------------------------
def edgeshard_plan(graph: ModelGraph, topo: Topology, wl: Workload,
                   n_stages: Optional[int] = None) -> ParallelismPlan:
    g = graph.compress(0.02)
    order = _chain_nodes(g)
    S = n_stages or topo.n
    S = min(S, len(order))
    sizes = [len(order) // S + (1 if i < len(order) % S else 0) for i in range(S)]
    groups, i = [], 0
    for sz in sizes:
        groups.append(order[i:i + sz])
        i += sz
    devs = [[d] for d in range(topo.n)][:S]
    # EdgeShard uses GPipe-style all-forward-then-backward microbatching:
    # stage 0 accumulates every in-flight activation.
    plan = _make_plan(g, topo, wl, LATENCY_ONLY, groups, devs,
                      schedule="gpipe")
    plan.meta["planner"] = "edgeshard"
    plan.meta["graph"] = g
    ok, why = plan_memory_ok(plan, topo)
    if not ok:
        raise BaselineError(f"EdgeShard plan OOM: {why}")
    return plan


# ----------------------------------------------------------------------------
# Asteroid — hybrid PP+DP, throughput-optimal under idealized D2D links
# ----------------------------------------------------------------------------
def _mb_sweep(wl: Workload) -> Tuple[int, ...]:
    """Microbatch candidates every planner may tune over."""
    out = {wl.microbatch_size} | {m for m in (1, 2, 4, 8, 16)
                                  if wl.global_batch % m == 0}
    return tuple(sorted(out))


def _zero_latency(topo: Topology) -> Topology:
    """The cited planners model link *bandwidth* only — per-message MAC/
    RTT latency is absent from their cost models."""
    res = [dataclasses.replace(r, latency=0.0) for r in topo.resources.values()]
    return Topology(topo.devices, res, topo._p2p)


def asteroid_plan(graph: ModelGraph, topo: Topology, wl: Workload,
                  top_k: int = 1) -> ParallelismPlan:
    cfg = PartitionerConfig(top_k=max(top_k, 1), delta=0.05,
                            microbatch_sizes=_mb_sweep(wl),
                            objective_mode="throughput")
    ideal_topo = _zero_latency(topo)      # idealized D2D view (§2.2, Fig. 2)
    part = ModelPartitioner(graph, ideal_topo, LATENCY_ONLY, cfg)
    cands = part.plan(wl)
    if not cands:
        raise BaselineError("Asteroid found no feasible plan")
    best = cands[0]
    best.meta["planner"] = "asteroid"
    best.meta["graph"] = part.graph
    return best


# ----------------------------------------------------------------------------
# Alpa — homogeneous-cluster automation (mean device, uniform bandwidth)
# ----------------------------------------------------------------------------
def _homogenized(topo: Topology) -> Topology:
    mean_flops = sum(d.flops for d in topo.devices) / topo.n
    mean_mem = sum(d.memory for d in topo.devices) / topo.n
    mean_eff = sum(d.compute_efficiency for d in topo.devices) / topo.n
    devs = [dataclasses.replace(d, flops=mean_flops, memory=mean_mem,
                                compute_efficiency=mean_eff)
            for d in topo.devices]
    return _uniform_net(devs, topo)


def _uniform_net(devs: Sequence[DeviceProfile], topo: Topology) -> Topology:
    """Every pair gets a dedicated link at the mean peak bandwidth —
    the 'uniform contention-free D2D' network model."""
    n = len(devs)
    caps = [topo.peak_bandwidth(i, j) for i in range(n) for j in range(n) if i != j]
    mean_bw = sum(caps) / len(caps) if caps else math.inf
    resources, p2p = [], {}
    for i in range(n):
        for j in range(i + 1, n):
            name = f"u{i}-{j}"
            resources.append(LinkResource(name, mean_bw, frozenset((i, j)),
                                          shared=False))
            p2p[(i, j)] = [name]
            p2p[(j, i)] = [name]
    return Topology(list(devs), resources, p2p)


def alpa_plan(graph: ModelGraph, topo: Topology, wl: Workload) -> ParallelismPlan:
    homo = _homogenized(topo)
    cfg = PartitionerConfig(top_k=1, delta=0.05,
                            microbatch_sizes=_mb_sweep(wl),
                            objective_mode="throughput")
    part = ModelPartitioner(graph, homo, LATENCY_ONLY, cfg)
    cands = part.plan(wl)
    if not cands:
        raise BaselineError("Alpa found no feasible plan")
    ideal = cands[0]
    # map back onto the REAL devices with a UNIFORM microbatch split (the
    # homogeneity assumption) and reprice under true speeds
    groups = [list(s.node_ids) for s in ideal.stages]
    dev_groups = [list(s.devices) for s in ideal.stages]
    wl = dataclasses.replace(wl, microbatch_size=ideal.microbatch_size)
    plan = _make_plan(part.graph, topo, wl, LATENCY_ONLY, groups, dev_groups,
                      uniform_split=True)
    plan.meta["planner"] = "alpa"
    plan.meta["graph"] = part.graph
    return plan


# ----------------------------------------------------------------------------
# Metis — heterogeneity-aware compute balance, uniform network model
# ----------------------------------------------------------------------------
def metis_plan(graph: ModelGraph, topo: Topology, wl: Workload) -> ParallelismPlan:
    uniform = _uniform_net(topo.devices, topo)
    cfg = PartitionerConfig(top_k=1, delta=0.05,
                            microbatch_sizes=_mb_sweep(wl),
                            objective_mode="throughput")
    part = ModelPartitioner(graph, uniform, LATENCY_ONLY, cfg)
    cands = part.plan(wl)
    if not cands:
        raise BaselineError("Metis found no feasible plan")
    ideal = cands[0]
    groups = [list(s.node_ids) for s in ideal.stages]
    dev_groups = [list(s.devices) for s in ideal.stages]
    wl = dataclasses.replace(wl, microbatch_size=ideal.microbatch_size)
    plan = _make_plan(part.graph, topo, wl, LATENCY_ONLY, groups, dev_groups)
    plan.meta["planner"] = "metis"
    plan.meta["graph"] = part.graph
    return plan


# ----------------------------------------------------------------------------
# Brute-force optimal (small settings; Fig. 2's "Optimal")
# ----------------------------------------------------------------------------
def _ordered_groupings(devices: List[int], n_groups: int
                       ) -> Iterable[List[List[int]]]:
    """Ordered partitions of a *speed-sorted* device list into contiguous
    groups (sufficient in practice: an optimal stage never benefits from
    pairing the fastest and slowest device when a middle one is free)."""
    for sizes in _contiguous_splits(len(devices), n_groups):
        out, i = [], 0
        for sz in sizes:
            out.append(devices[i:i + sz])
            i += sz
        yield out


def brute_force_optimal(graph: ModelGraph, topo: Topology, wl: Workload,
                        evaluate, max_stages: Optional[int] = None,
                        delta: float = 0.08, shortlist: int = 300
                        ) -> ParallelismPlan:
    """Exhaustive two-phase search ("Optimal" in Fig. 2).

    Enumerates (contiguous stage splits × ordered device groupings over
    speed-sorted devices), ranks all candidates by the cheap analytic
    latency, then REAL-evaluates the best ``shortlist`` with
    ``evaluate(plan) -> float`` (the contention-aware simulator) and
    returns the true winner.
    """
    g = graph.compress(delta)
    order = _chain_nodes(g)
    cands: List[ParallelismPlan] = []
    by_speed = sorted(range(topo.n),
                      key=lambda d: topo.devices[d].effective_flops(), reverse=True)
    dev_orders = [by_speed, list(reversed(by_speed))]
    S_cap = min(max_stages or topo.n, len(order), topo.n)
    for S in range(1, S_cap + 1):
        for sizes in _contiguous_splits(len(order), S):
            groups, i = [], 0
            for sz in sizes:
                groups.append(order[i:i + sz])
                i += sz
            seen_dg = set()
            for dev_order in dev_orders:
                for dgs in _ordered_groupings(dev_order, S):
                    key = tuple(tuple(sorted(dg)) for dg in dgs)
                    if key in seen_dg:
                        continue
                    seen_dg.add(key)
                    try:
                        plan = _make_plan(g, topo, wl, LATENCY_ONLY,
                                          groups, dgs)
                    except Exception:
                        continue
                    ok, _ = plan_memory_ok(plan, topo)
                    if not ok:
                        continue
                    plan.meta["graph"] = g
                    cands.append(plan)
    if not cands:
        raise BaselineError("brute force found no feasible plan")
    cands.sort(key=lambda p: p.latency)          # cheap analytic rank
    best: Optional[ParallelismPlan] = None
    best_lat = math.inf
    for plan in cands[:shortlist]:
        lat = evaluate(plan)
        if lat < best_lat:
            best_lat = lat
            plan.latency = lat
            plan.meta["planner"] = "optimal"
            best = plan
    assert best is not None
    return best


# ----------------------------------------------------------------------------
# strategy wrappers — the registry entries
# ----------------------------------------------------------------------------
class _SinglePlanBaseline:
    """Shared shape: run one ``*_plan`` function, price the result under
    fluid-fair contention on the calibrated real topology."""

    name = "abstract"
    contention_aware = False

    def _raw_plan(self, graph: ModelGraph, topo: Topology,
                  wl: Workload) -> ParallelismPlan:
        raise NotImplementedError

    def plan(self, graph: ModelGraph, topology: Topology, qoe: QoESpec,
             workload: Workload,
             costs: Optional[CostProvider] = None) -> PlanningResult:
        topo = resolve_costs(costs).calibrate(topology)
        watch = _Stopwatch()
        raw = self._raw_plan(graph, topo, workload)
        phase1_s = watch.lap()
        executed = fair_executed(raw, topo, qoe)
        return as_result([executed], phase1_s, watch.lap())


@register_strategy
class EdgeShardStrategy(_SinglePlanBaseline):
    """EdgeShard-like: pipeline-only even layer chain.

    The raw ``edgeshard_plan`` is memory-oblivious (the paper's reported
    failure mode); the registered strategy degrades like the real system
    would — if the full-fleet even split OOMs it retries with fewer
    stages and only raises when no even split fits at all.  Pass
    ``n_stages=`` to pin the stage count (no fallback)."""

    name = "edgeshard"

    def __init__(self, n_stages: Optional[int] = None):
        self.n_stages = n_stages

    def _raw_plan(self, graph, topo, wl):
        if self.n_stages is not None:
            return edgeshard_plan(graph, topo, wl, n_stages=self.n_stages)
        first_err: Optional[StrategyError] = None
        for S in range(topo.n, 0, -1):
            try:
                plan = edgeshard_plan(graph, topo, wl, n_stages=S)
            except StrategyError as e:
                first_err = first_err or e
                continue
            if S < topo.n:
                plan.meta["fallback_stages"] = S
            return plan
        raise first_err or StrategyError("edgeshard: no feasible even split")


@register_strategy
class AsteroidStrategy(_SinglePlanBaseline):
    """Asteroid-like: throughput-max hybrid PP+DP, idealized D2D links."""

    name = "asteroid"

    def __init__(self, top_k: int = 1):
        self.top_k = top_k

    def _raw_plan(self, graph, topo, wl):
        return asteroid_plan(graph, topo, wl, top_k=self.top_k)


@register_strategy
class AlpaStrategy(_SinglePlanBaseline):
    """Alpa-like: homogeneous-cluster automation, uniform split."""

    name = "alpa"

    def _raw_plan(self, graph, topo, wl):
        return alpa_plan(graph, topo, wl)


@register_strategy
class MetisStrategy(_SinglePlanBaseline):
    """Metis-like: heterogeneity-aware balance, uniform network model."""

    name = "metis"

    def _raw_plan(self, graph, topo, wl):
        return metis_plan(graph, topo, wl)


@register_strategy
class BruteForceStrategy:
    """Exhaustive split search, shortlisted candidates priced on the real
    contended medium ("Optimal" in Fig. 2).  ``evaluate`` defaults to the
    fluid-fair simulator; pass a callable to search under a different
    execution model."""

    name = "brute_force"
    contention_aware = True     # the shortlist IS evaluated under contention

    def __init__(self, max_stages: Optional[int] = None, delta: float = 0.08,
                 shortlist: int = 300,
                 evaluate: Optional[Callable[[ParallelismPlan], float]] = None):
        self.max_stages = max_stages
        self.delta = delta
        self.shortlist = shortlist
        self.evaluate = evaluate

    def plan(self, graph: ModelGraph, topology: Topology, qoe: QoESpec,
             workload: Workload,
             costs: Optional[CostProvider] = None) -> PlanningResult:
        topo = resolve_costs(costs).calibrate(topology)
        evaluate = self.evaluate or (
            lambda p: fair_executed(p, topo, qoe).latency)
        watch = _Stopwatch()
        best = brute_force_optimal(graph, topo, workload, evaluate,
                                   max_stages=self.max_stages,
                                   delta=self.delta, shortlist=self.shortlist)
        phase1_s = watch.lap()
        if self.evaluate is None:
            # fills energy/objective/schedule under the same fair model
            # the shortlist was ranked with
            best = fair_executed(best, topo, qoe)
        else:
            # honor the caller's execution model: keep its latency,
            # just refresh the objective for the comparison qoe
            best.objective = qoe.objective(best.energy, best.latency)
        return as_result([best], phase1_s, watch.lap())
