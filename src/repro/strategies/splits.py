"""Split-centric planner strategies beyond the paper's §6.1 baselines.

* ``throughput_max``   — rate-optimal planning on the *real* topology
  (the cloud-planner objective without Asteroid's idealized-D2D twist):
  heterogeneity-aware, but blind to QoE, energy, pipeline fill/drain and
  contention.
* ``chain_split``      — DistrEdge-style layer chaining (arXiv:2202.01699):
  one device per stage in speed order, boundaries balanced proportional
  to device compute rates; falls back to memory-capacity balancing when
  the speed balance does not fit.
* ``memory_balanced``  — the same chain with boundaries proportional to
  device memory: the safe choice for memory-starved fleets, usually
  compute-imbalanced.
* ``pareto_split``     — "Where to Split?"-style analysis
  (arXiv:2601.08025): enumerate device prefixes × contiguous device
  groupings × balanced layer boundaries × microbatch sizes, price each
  candidate in (latency, energy), keep the Pareto front and pick the
  QoE-objective winner from it.

All four are contention-oblivious planners; their plans are priced under
fluid-fair contention on the real medium before being returned.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from ..core.adapter import pareto_filter
from ..core.cost_model import CostModel, CostProvider, Workload, resolve_costs
from ..core.device import Topology
from ..core.partitioner import ModelPartitioner, PartitionerConfig
from ..core.planner import PlanningResult
from ..core.planning_graph import ModelGraph
from ..core.plans import ParallelismPlan
from ..core.qoe import QoESpec
from .base import StrategyError, _Stopwatch, as_result, fair_executed, \
    register_strategy
from .baselines import LATENCY_ONLY, _balance_boundaries, _chain_nodes, \
    _contiguous_splits, _make_plan, _mb_sweep, plan_memory_ok


@register_strategy
class ThroughputMaxStrategy:
    """Throughput-only planning on the real topology: bottleneck-stage
    rate is the whole objective (no QoE, no energy, no contention)."""

    name = "throughput_max"
    contention_aware = False

    def __init__(self, top_k: int = 1, delta: float = 0.05):
        self.top_k = top_k
        self.delta = delta

    def plan(self, graph: ModelGraph, topology: Topology, qoe: QoESpec,
             workload: Workload,
             costs: Optional[CostProvider] = None) -> PlanningResult:
        topo = resolve_costs(costs).calibrate(topology)
        watch = _Stopwatch()
        cfg = PartitionerConfig(top_k=max(self.top_k, 1), delta=self.delta,
                                microbatch_sizes=_mb_sweep(workload),
                                objective_mode="throughput")
        part = ModelPartitioner(graph, topo, LATENCY_ONLY, cfg)
        cands = part.plan(workload)
        if not cands:
            raise StrategyError("throughput_max found no feasible plan")
        for p in cands:
            p.meta["planner"] = self.name
            p.meta["graph"] = part.graph
        phase1_s = watch.lap()
        executed = [fair_executed(p, topo, qoe) for p in cands]
        return as_result(executed, phase1_s, watch.lap())


def _chain_plan(graph: ModelGraph, topo: Topology, wl: Workload,
                weights: Sequence[float], dev_order: Sequence[int],
                delta: float) -> ParallelismPlan:
    """One chain split: contiguous layer groups balanced ∝ ``weights``,
    one device (in ``dev_order``) per stage."""
    g = graph.compress(delta)
    order = _chain_nodes(g)
    S = min(len(dev_order), len(order))
    devs = list(dev_order)[:S]
    node_costs = [g.nodes[i].flops_fwd + g.nodes[i].flops_bwd for i in order]
    sizes = _balance_boundaries(node_costs, list(weights)[:S])
    groups, i = [], 0
    for sz in sizes:
        groups.append(order[i:i + sz])
        i += sz
    plan = _make_plan(g, topo, wl, LATENCY_ONLY, groups, [[d] for d in devs])
    plan.meta["graph"] = g
    return plan


class _ChainBaseline:
    """Shared chain-split machinery for chain_split / memory_balanced."""

    name = "abstract"
    contention_aware = False
    delta = 0.05

    def _weights(self, topo: Topology, dev_order: Sequence[int]
                 ) -> List[float]:
        raise NotImplementedError

    def _order(self, topo: Topology) -> List[int]:
        raise NotImplementedError

    def _fallback_weights(self, topo: Topology, dev_order: Sequence[int]
                          ) -> Optional[List[float]]:
        """Second-chance weights when the primary balance OOMs (None ->
        no distinct fallback exists, fail straight away)."""
        return None

    def plan(self, graph: ModelGraph, topology: Topology, qoe: QoESpec,
             workload: Workload,
             costs: Optional[CostProvider] = None) -> PlanningResult:
        topo = resolve_costs(costs).calibrate(topology)
        watch = _Stopwatch()
        dev_order = self._order(topo)
        plan = _chain_plan(graph, topo, workload,
                           self._weights(topo, dev_order), dev_order,
                           self.delta)
        ok, why = plan_memory_ok(plan, topo)
        if not ok:
            fallback = self._fallback_weights(topo, dev_order)
            if fallback is None:
                raise StrategyError(f"{self.name} plan OOM: {why}")
            plan = _chain_plan(graph, topo, workload, fallback, dev_order,
                               self.delta)
            ok, why = plan_memory_ok(plan, topo)
            if not ok:
                raise StrategyError(f"{self.name} plan OOM: {why}")
        plan.meta["planner"] = self.name
        phase1_s = watch.lap()
        executed = fair_executed(plan, topo, qoe)
        return as_result([executed], phase1_s, watch.lap())


@register_strategy
class ChainSplitStrategy(_ChainBaseline):
    """DistrEdge-style chaining: fast devices first, compute-balanced."""

    name = "chain_split"

    def _order(self, topo: Topology) -> List[int]:
        return sorted(range(topo.n),
                      key=lambda d: topo.devices[d].effective_flops(),
                      reverse=True)

    def _weights(self, topo: Topology, dev_order: Sequence[int]) -> List[float]:
        return [topo.devices[d].effective_flops() for d in dev_order]

    def _fallback_weights(self, topo: Topology, dev_order: Sequence[int]
                          ) -> Optional[List[float]]:
        # speed balance OOMed: retry balanced on memory capacity
        return [topo.devices[d].memory for d in dev_order]


@register_strategy
class MemoryBalancedStrategy(_ChainBaseline):
    """Chain split with layer counts proportional to device memory."""

    name = "memory_balanced"

    def _order(self, topo: Topology) -> List[int]:
        return sorted(range(topo.n),
                      key=lambda d: topo.devices[d].memory, reverse=True)

    def _weights(self, topo: Topology, dev_order: Sequence[int]) -> List[float]:
        return [topo.devices[d].memory for d in dev_order]


@register_strategy
class ParetoSplitStrategy:
    """Split-point Pareto analysis ("Where to Split?").

    Enumerates (device-prefix length × contiguous device groupings ×
    speed-balanced layer boundaries × microbatch sizes) over fast-first
    and slow-first device orderings, prices every candidate analytically
    in (latency, energy), keeps the Pareto front, fair-executes the
    front on the real medium and returns the QoE-objective winner."""

    name = "pareto_split"
    contention_aware = False

    def __init__(self, delta: float = 0.05, max_front: int = 12):
        self.delta = delta
        self.max_front = max_front

    def _candidates(self, graph: ModelGraph, topo: Topology, qoe: QoESpec,
                    wl: Workload) -> List[ParallelismPlan]:
        g = graph.compress(self.delta)
        order = _chain_nodes(g)
        node_costs = [g.nodes[i].flops_fwd + g.nodes[i].flops_bwd
                      for i in order]
        by_speed = sorted(range(topo.n),
                          key=lambda d: topo.devices[d].effective_flops(),
                          reverse=True)
        out: List[ParallelismPlan] = []
        seen = set()
        for mb in _mb_sweep(wl):
            if wl.global_batch % mb:
                continue
            wl_mb = dataclasses.replace(wl, microbatch_size=mb)
            cm = CostModel(g, topo, wl_mb)
            for dev_order in (by_speed, list(reversed(by_speed))):
                for used in range(1, topo.n + 1):
                    prefix = dev_order[:used]
                    for S in range(1, min(used, len(order)) + 1):
                        for dev_sizes in _contiguous_splits(used, S):
                            dgs, i = [], 0
                            for sz in dev_sizes:
                                dgs.append(prefix[i:i + sz])
                                i += sz
                            weights = [sum(topo.devices[d].effective_flops()
                                           for d in dg) for dg in dgs]
                            sizes = _balance_boundaries(node_costs, weights)
                            groups, i = [], 0
                            for sz in sizes:
                                groups.append(order[i:i + sz])
                                i += sz
                            key = (mb, tuple(tuple(dg) for dg in dgs),
                                   tuple(sizes))
                            if key in seen:
                                continue
                            seen.add(key)
                            try:
                                stages = [cm.make_stage(list(nids), list(dg))
                                          for nids, dg in zip(groups, dgs)]
                            except Exception:
                                continue
                            if not all(cm.memory_feasible(st, qoe,
                                                          n_stages_hint=S)
                                       for st in stages):
                                continue
                            plan = cm.evaluate(stages, qoe, "1f1b")
                            plan.meta["planner"] = self.name
                            plan.meta["graph"] = g
                            out.append(plan)
        return out

    def plan(self, graph: ModelGraph, topology: Topology, qoe: QoESpec,
             workload: Workload,
             costs: Optional[CostProvider] = None) -> PlanningResult:
        topo = resolve_costs(costs).calibrate(topology)
        watch = _Stopwatch()
        cands = self._candidates(graph, topo, qoe, workload)
        if not cands:
            raise StrategyError("pareto_split found no feasible split")
        front = pareto_filter(cands)[: self.max_front]
        phase1_s = watch.lap()
        executed = [fair_executed(p, topo, qoe) for p in front]
        return as_result(executed, phase1_s, watch.lap())
