"""Sharded, async, atomic checkpointing with elastic restore.

Layout (tensorstore-style, one object per (leaf, shard)):

    <dir>/step_000123.tmp/              — written first
        MANIFEST.json                   — treedef, shapes, dtypes, specs
        <leaf_id>.<shard_idx>.npy       — one file per addressable shard
    <dir>/step_000123/                  — atomic rename on completion
        COMMIT                          — marker: checkpoint is complete

Restore targets may live on a *different* mesh (elastic restart after
node loss): ``restore`` reassembles each leaf from its saved shards via
``jax.make_array_from_callback`` against the new sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _leaf_id(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return ".".join(out)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            commit = os.path.join(directory, name, "COMMIT")
            if os.path.exists(commit):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, wait: bool = False) -> None:
        """Snapshot leaves to host (cheap) then write in the background."""
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        snap: List[Tuple[str, List[Tuple[int, np.ndarray]], tuple, str]] = []
        for path, leaf in leaves:
            lid = _leaf_id(path)
            shards = []
            arr = leaf
            if isinstance(arr, jax.Array):
                for i, s in enumerate(arr.addressable_shards):
                    shards.append((s.index, np.asarray(s.data)))
            else:
                shards.append(((slice(None),), np.asarray(arr)))
            snap.append((lid, shards, tuple(leaf.shape), str(leaf.dtype)))
        treedef = jax.tree_util.tree_structure(tree)

        self.wait()
        if self.async_save and not wait:
            self._thread = threading.Thread(
                target=self._write, args=(step, snap, str(treedef)), daemon=True)
            self._thread.start()
        else:
            self._write(step, snap, str(treedef))

    def _write(self, step: int, snap, treedef_str: str) -> None:
        final = os.path.join(self.dir, f"step_{step:06d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest: Dict[str, Any] = {"step": step, "treedef": treedef_str,
                                    "leaves": {}}
        for lid, shards, shape, dtype in snap:
            manifest["leaves"][lid] = {
                "shape": list(shape), "dtype": dtype,
                "shards": [_index_to_json(idx) for idx, _ in shards]}
            for i, (_idx, data) in enumerate(shards):
                if data.dtype == _np_dtype("bfloat16"):
                    data = data.view(np.uint16)   # npy-portable bf16 encoding
                np.save(os.path.join(tmp, f"{lid}.{i}.npy"), data)
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        with open(os.path.join(final, "COMMIT"), "w") as f:
            f.write("ok")
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(s for s in (latest_step(self.dir),) if s is not None)
        all_steps = sorted(int(n.split("_")[1]) for n in os.listdir(self.dir)
                           if n.startswith("step_") and not n.endswith(".tmp"))
        for s in all_steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:06d}"),
                          ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def restore(self, step: int, target: Any) -> Any:
        """``target``: pytree of jax.Arrays or ShapeDtypeStructs (possibly
        on a different mesh than the checkpoint was saved from)."""
        self.wait()
        d = os.path.join(self.dir, f"step_{step:06d}")
        if not os.path.exists(os.path.join(d, "COMMIT")):
            raise FileNotFoundError(f"no committed checkpoint at {d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)

        def load_leaf(path, leaf):
            lid = _leaf_id(path)
            meta = manifest["leaves"][lid]
            shape = tuple(meta["shape"])
            dt = np.dtype(_np_dtype(meta["dtype"]))
            full = np.zeros(shape, dtype=dt)
            for i, idx_json in enumerate(meta["shards"]):
                data = np.load(os.path.join(d, f"{lid}.{i}.npy"))
                if meta["dtype"] == "bfloat16":
                    data = data.view(dt)          # undo the uint16 encoding
                full[_json_to_index(idx_json)] = data
            sharding = getattr(leaf, "sharding", None)
            if sharding is not None:
                # np.asarray keeps 0-d shapes (ascontiguousarray promotes
                # scalars to (1,), which JAX rejects)
                return jax.make_array_from_callback(
                    shape, sharding,
                    lambda idx: np.asarray(full[idx], order="C"))
            return jax.numpy.asarray(full)

        return jax.tree_util.tree_map_with_path(load_leaf, target)


def _index_to_json(idx) -> List:
    out = []
    for s in idx:
        if isinstance(s, slice):
            out.append([s.start, s.stop, s.step])
        else:
            out.append(s)
    return out


def _json_to_index(idx_json) -> tuple:
    return tuple(slice(*s) if isinstance(s, list) else s for s in idx_json)


def _np_dtype(name: str):
    if name == "bfloat16":
        import ml_dtypes
        return ml_dtypes.bfloat16
    return name
