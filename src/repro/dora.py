"""``repro.dora`` — the one-call facade over Dora's planning stack.

The three paper mechanisms (model partitioner §4.1, contention-aware
network scheduler §4.2, runtime adapter §4.3) are wired behind three
verbs, each taking a scenario name (or an ad-hoc
:class:`repro.scenarios.Scenario`):

    from repro import dora

    report  = dora.plan("smart_home_2")          # -> PlanReport
    session = dora.serve("traffic_monitor")      # -> ServeSession (adapter)
    trace   = dora.simulate("vehicle_platoon")   # -> SimulationTrace

``plan`` runs Algorithm 1 end to end (partition → schedule → Pareto
filter); ``serve`` additionally arms the runtime adapter for dynamics;
``simulate`` replays a timeline of :class:`DynamicsEvent`\\ s through the
adapter and records every reaction.  Every knob of the underlying stack
remains reachable through keyword overrides (``workload=``, ``qoe=``,
``graph=``, ``topology=``, ``partitioner_config=``, ...), so the facade
never forces a drop back down to hand-wiring ``DoraPlanner``.

Planners themselves are pluggable: ``plan`` takes a ``strategy=`` from
the ``repro.strategies`` registry (``"dora"``, ``"throughput_max"``,
``"chain_split"``, ``"pareto_split"``, the §6.1 baselines, ...), and
``compare`` runs several strategies on one scenario and tabulates
latency/energy/QoE with speedup-vs-baseline columns::

    cmp = dora.compare("smart_home_2",
                       strategies=["dora", "throughput_max", "chain_split"])
    print(cmp.summary()); cmp.to_json("compare.json")

Cost fidelity is pluggable too: every verb accepts ``costs=`` — a
``CostProvider`` instance, the string ``"analytic"`` (datasheet
rooflines, the default), or ``"profiled:<path>"`` to load a committed
:class:`repro.core.profiler.ProfiledCosts` calibration artifact::

    report = dora.plan("smart_home_2", costs="profiled:calibration/host_cpu.json")

``dora.calibrate()`` produces such artifacts by microbenchmarking the
local host (see :mod:`repro.calibrate`).

This module is deliberately jax-free: planning is analytic, so importing
``repro.dora`` never initializes an accelerator backend.
"""
from __future__ import annotations

import copy as _copy
import dataclasses
import json
import math
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .control.plane import ControlConfig, ControlPlane
from .core.adapter import (AdapterConfig, DynamicsEvent, RuntimeAdapter,
                           RuntimeState)
from .core.cost_model import CostProvider, Workload
from .core.device import Topology
from .core.partitioner import PartitionerConfig
from .core.planner import DoraPlanner, PlanningResult
from .core.planning_graph import ModelGraph
from .core.plans import ParallelismPlan
from .core.qoe import QoESpec
from .core.scheduler import SchedulerConfig
from .scenarios import Scenario, get_scenario
from .strategies import StrategyRef, get_strategy

ScenarioRef = Union[str, Scenario]

# (label, event) or bare event — both accepted by simulate().
TimelineItem = Union[DynamicsEvent, Tuple[str, DynamicsEvent]]

#: Default strategy line-up for ``dora.compare``.
DEFAULT_COMPARISON = ("dora", "throughput_max", "chain_split", "pareto_split")


# JSON-safe number coercion lives with the serving kernel now; the
# old name stays importable from here (several modules and tests do).
from .core.events import _json_num  # noqa: E402,F401


def _plan_dict(plan: ParallelismPlan) -> Dict[str, object]:
    """Machine-readable summary of one plan (JSON-safe)."""
    return {
        "latency_s": _json_num(plan.latency),
        "energy_j": _json_num(plan.energy),
        "objective": _json_num(plan.objective),
        "microbatch_size": plan.microbatch_size,
        "n_microbatches": plan.n_microbatches,
        "training": plan.training,
        "stages": [{
            "n_nodes": len(s.node_ids),
            "devices": list(s.devices),
            "dp_degree": s.dp_degree,
            "tp_degree": s.tp_degree,
        } for s in plan.stages],
        "per_device_energy_j":
            {str(d): _json_num(e) for d, e in plan.per_device_energy.items()},
        "per_device_memory_gb":
            {str(d): _json_num(m / 1e9)
             for d, m in plan.per_device_memory.items()},
    }


@dataclasses.dataclass
class PlanReport:
    """Everything ``dora.plan`` produced for one scenario, in one object."""

    scenario: Scenario
    topology: Topology
    graph: ModelGraph
    workload: Workload
    qoe: QoESpec
    result: PlanningResult
    strategy: str = "dora"

    @property
    def best(self) -> ParallelismPlan:
        return self.result.best

    @property
    def candidates(self) -> List[ParallelismPlan]:
        return self.result.candidates

    @property
    def pareto(self) -> List[ParallelismPlan]:
        return self.result.pareto

    @property
    def latency(self) -> float:
        return self.result.best.latency

    @property
    def energy(self) -> float:
        return self.result.best.energy

    @property
    def meets_qoe(self) -> bool:
        """Full QoE verdict (latency target AND energy/memory budgets)."""
        return self.qoe.satisfied(self.result.best)

    @property
    def planning_seconds(self) -> float:
        return self.result.total_s

    def to_dict(self) -> Dict[str, object]:
        """Machine-readable report (strict-JSON-safe) for ``--json``
        artifacts and future ``BENCH_*.json`` trajectories."""
        return {
            "scenario": self.scenario.name,
            "mode": self.scenario.mode,
            "model": self.scenario.model_name,
            "strategy": self.strategy,
            "devices": self.topology.n,
            "qoe": {"t_qoe_s": _json_num(self.qoe.t_qoe),
                    "e_qoe_j": _json_num(self.qoe.e_qoe),
                    "lam": _json_num(self.qoe.lam)},
            "latency_s": _json_num(self.latency),
            "energy_j": _json_num(self.energy),
            "meets_qoe": self.meets_qoe,
            "planning_s": _json_num(self.planning_seconds),
            "best": _plan_dict(self.best),
            "pareto": [{"latency_s": _json_num(p.latency),
                        "energy_j": _json_num(p.energy)}
                       for p in self.pareto],
        }

    def summary(self) -> str:
        lines = [
            f"scenario {self.scenario.name} [{self.scenario.mode}] "
            f"model={self.scenario.model_name} devices={self.topology.n} "
            f"strategy={self.strategy}",
            f"planned in {self.result.total_s:.2f}s "
            f"(phase1 {self.result.phase1_s:.2f}s + "
            f"phase2 {self.result.phase2_s:.2f}s)",
            f"best: {self.best.summary()}",
            f"QoE target {self.qoe.t_qoe:g}s: "
            f"{'MET' if self.meets_qoe else 'VIOLATED'} "
            f"({self.latency:.3f}s, {self.energy:.1f} J)",
            f"pareto frontier ({len(self.pareto)} plans for runtime mixing):",
        ]
        for p in self.pareto:
            lines.append(f"  lat={p.latency * 1e3:9.1f} ms  "
                         f"energy={p.energy:9.1f} J  "
                         f"stages={p.n_stages} mb={p.microbatch_size}")
        return "\n".join(lines)


def _resolve(scenario: ScenarioRef,
             topology: Optional[Topology],
             graph: Optional[ModelGraph],
             workload: Optional[Workload],
             qoe: Optional[QoESpec],
             seq_len: Optional[int]
             ) -> Tuple[Scenario, Topology, ModelGraph, Workload, QoESpec]:
    sc = get_scenario(scenario)
    topo = topology if topology is not None else sc.build_topology()
    wl = workload if workload is not None else sc.workload
    q = qoe if qoe is not None else sc.qoe
    g = graph if graph is not None else sc.build_graph(seq_len=seq_len)
    return sc, topo, g, wl, q


def planner_for(scenario: ScenarioRef, *,
                topology: Optional[Topology] = None,
                graph: Optional[ModelGraph] = None,
                workload: Optional[Workload] = None,
                qoe: Optional[QoESpec] = None,
                seq_len: Optional[int] = None,
                partitioner_config: Optional[PartitionerConfig] = None,
                scheduler_config: Optional[SchedulerConfig] = None,
                adapter_config: Optional[AdapterConfig] = None,
                costs: Optional[CostProvider] = None
                ) -> Tuple[DoraPlanner, Scenario, Workload]:
    """Construct (planner, scenario, workload) without running it —
    the escape hatch for callers that sweep planner configurations."""
    sc, topo, g, wl, q = _resolve(scenario, topology, graph, workload, qoe,
                                  seq_len)
    planner = DoraPlanner(g, topo, q,
                          partitioner_config=partitioner_config,
                          scheduler_config=scheduler_config,
                          adapter_config=adapter_config,
                          costs=costs)
    return planner, sc, wl


def plan(scenario: ScenarioRef, strategy: StrategyRef = "dora",
         **overrides) -> PlanReport:
    """Plan one scenario with any registered planner strategy.

    ``dora.plan("smart_home_2")`` runs Algorithm 1 end to end for the
    registered deployment; keyword overrides swap any ingredient
    (``workload=``, ``qoe=``, ``graph=``, ``topology=``, ``seq_len=``,
    ``partitioner_config=``, ``scheduler_config=``, ``costs=``).

    ``strategy=`` selects a different planner from the
    ``repro.strategies`` registry (name or instance), e.g.
    ``dora.plan("smart_home_2", strategy="chain_split")``; planner
    configuration then goes through
    ``get_strategy(name, **params)`` rather than the DoraPlanner
    config overrides.
    """
    if strategy == "dora":
        planner, sc, wl = planner_for(scenario, **overrides)
        result = planner.plan(wl)
        return PlanReport(scenario=sc, topology=planner.topo,
                          graph=planner.graph, workload=wl, qoe=planner.qoe,
                          result=result)
    strat = get_strategy(strategy)
    bad = {k for k in ("partitioner_config", "scheduler_config",
                       "adapter_config") if overrides.get(k) is not None}
    if bad:
        raise ValueError(f"{sorted(bad)} only apply to the 'dora' strategy; "
                         f"configure {strat.name!r} via "
                         f"get_strategy(name, **params) and pass the instance")
    costs = overrides.pop("costs", None)
    for k in ("partitioner_config", "scheduler_config", "adapter_config"):
        overrides.pop(k, None)
    sc, topo, g, wl, q = _resolve(scenario,
                                  overrides.pop("topology", None),
                                  overrides.pop("graph", None),
                                  overrides.pop("workload", None),
                                  overrides.pop("qoe", None),
                                  overrides.pop("seq_len", None))
    if overrides:
        raise TypeError(f"unexpected overrides: {sorted(overrides)}")
    result = strat.plan(g, topo, q, wl, costs=costs)
    return PlanReport(scenario=sc, topology=topo, graph=g, workload=wl,
                      qoe=q, result=result, strategy=strat.name)


@dataclasses.dataclass
class StrategyOutcome:
    """One strategy's run inside a :class:`ComparisonReport`."""

    strategy: str
    result: Optional[PlanningResult] = None
    planning_s: float = 0.0
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None and self.result is not None

    @property
    def latency(self) -> float:
        return self.result.best.latency if self.ok else math.inf

    @property
    def energy(self) -> float:
        return self.result.best.energy if self.ok else math.inf


@dataclasses.dataclass
class ComparisonReport:
    """Several planner strategies on one scenario, side by side.

    Latency/energy are real-topology numbers (contention-aware
    strategies price contention themselves; oblivious ones are executed
    under fluid-fair sharing).  ``reference`` (normally ``"dora"``)
    anchors the speedup / energy-savings columns.
    """

    scenario: Scenario
    qoe: QoESpec
    reference: str
    outcomes: Dict[str, StrategyOutcome]

    def __getitem__(self, name: str) -> StrategyOutcome:
        return self.outcomes[name]

    @property
    def strategies(self) -> List[str]:
        return list(self.outcomes)

    def meets_qoe(self, name: str) -> bool:
        out = self.outcomes[name]
        return out.ok and self.qoe.satisfied(out.result.best)

    def speedup(self, name: str) -> float:
        """How many times faster the reference is than ``name``
        (>1 means the reference wins)."""
        ref = self.outcomes[self.reference]
        out = self.outcomes[name]
        if not (ref.ok and out.ok):
            return math.nan
        return out.latency / ref.latency

    def energy_savings(self, name: str) -> float:
        """Fraction of ``name``'s energy the reference saves (0.21 =
        21% less energy than that baseline)."""
        ref = self.outcomes[self.reference]
        out = self.outcomes[name]
        if not (ref.ok and out.ok) or out.energy <= 0.0:
            return math.nan
        return 1.0 - ref.energy / out.energy

    def best_baseline(self) -> Tuple[str, StrategyOutcome]:
        """Fastest successful non-reference strategy."""
        ok = {k: v for k, v in self.outcomes.items()
              if k != self.reference and v.ok}
        if not ok:
            raise RuntimeError("no baseline strategy produced a valid plan")
        name = min(ok, key=lambda k: ok[k].latency)
        return name, ok[name]

    def to_dict(self) -> Dict[str, object]:
        rows = {}
        for name, out in self.outcomes.items():
            rows[name] = {
                "ok": out.ok,
                "error": out.error,
                "latency_s": _json_num(out.latency),
                "energy_j": _json_num(out.energy),
                "meets_qoe": self.meets_qoe(name),
                "planning_s": _json_num(out.planning_s),
                "speedup_vs_reference": _json_num(self.speedup(name))
                    if out.ok else None,
                "reference_energy_savings": _json_num(self.energy_savings(name))
                    if out.ok else None,
                "best": _plan_dict(out.result.best) if out.ok else None,
            }
        return {
            "scenario": self.scenario.name,
            "mode": self.scenario.mode,
            "model": self.scenario.model_name,
            "reference": self.reference,
            "qoe": {"t_qoe_s": _json_num(self.qoe.t_qoe),
                    "e_qoe_j": _json_num(self.qoe.e_qoe),
                    "lam": _json_num(self.qoe.lam)},
            "strategies": rows,
        }

    def to_json(self, path: Optional[str] = None, indent: int = 2) -> str:
        """Serialize to strict JSON; optionally also write to ``path``."""
        text = json.dumps(self.to_dict(), indent=indent, allow_nan=False)
        if path is not None:
            with open(path, "w", encoding="utf-8") as f:
                f.write(text + "\n")
        return text

    def summary(self) -> str:
        headers = ("strategy", "lat (ms)", "energy (J)", "QoE", "plan (s)",
                   f"vs {self.reference}")
        rows: List[Tuple[str, ...]] = []
        for name, out in self.outcomes.items():
            if not out.ok:
                rows.append((name, "ERROR", out.error or "?", "-", "-", "-"))
                continue
            sp = self.speedup(name)
            sv = self.energy_savings(name)
            vs = ("(reference)" if name == self.reference else
                  f"{sp:.2f}x lat, {sv:+.0%} E")
            rows.append((name, f"{out.latency * 1e3:.1f}",
                         f"{out.energy:.1f}",
                         "MET" if self.meets_qoe(name) else "MISS",
                         f"{out.planning_s:.2f}", vs))
        widths = [max(len(h), *(len(r[i]) for r in rows))
                  for i, h in enumerate(headers)]
        lines = [f"strategy comparison — scenario {self.scenario.name} "
                 f"[{self.scenario.mode}] model={self.scenario.model_name}",
                 "  ".join(h.ljust(w) for h, w in zip(headers, widths)),
                 "  ".join("-" * w for w in widths)]
        for r in rows:
            lines.append("  ".join(v.ljust(w) for v, w in zip(r, widths)))
        return "\n".join(lines)


def compare(scenario: ScenarioRef,
            strategies: Sequence[StrategyRef] = DEFAULT_COMPARISON, *,
            costs: Optional[CostProvider] = None,
            topology: Optional[Topology] = None,
            graph: Optional[ModelGraph] = None,
            workload: Optional[Workload] = None,
            qoe: Optional[QoESpec] = None,
            seq_len: Optional[int] = None) -> ComparisonReport:
    """Run several planner strategies on one scenario and tabulate them.

    Strategies resolve through the ``repro.strategies`` registry (names
    or instances); ``"dora"`` gets the benchmark-grade search
    (``top_k=10`` + microbatch sweep) so the comparison matches the
    Fig. 8/9 harnesses.  A strategy that fails (e.g. EdgeShard OOM)
    becomes an error row, not an exception — the failure is the finding.
    """
    if not strategies:
        raise ValueError("compare needs at least one strategy "
                         f"(e.g. {list(DEFAULT_COMPARISON)})")
    sc, topo, g, wl, q = _resolve(scenario, topology, graph, workload, qoe,
                                  seq_len)
    outcomes: Dict[str, StrategyOutcome] = {}
    for ref in strategies:
        strat = (get_strategy(ref, top_k=10, sweep_microbatch=True)
                 if ref == "dora" else get_strategy(ref))
        t0 = time.perf_counter()
        try:
            result = strat.plan(g, topo, q, wl, costs=costs)
            outcomes[strat.name] = StrategyOutcome(
                strategy=strat.name, result=result,
                planning_s=result.total_s)
        except Exception as e:  # noqa: BLE001 — the failure is the finding
            outcomes[strat.name] = StrategyOutcome(
                strategy=strat.name, planning_s=time.perf_counter() - t0,
                error=f"{type(e).__name__}: {e}")
    reference = "dora" if "dora" in outcomes else next(iter(outcomes))
    return ComparisonReport(scenario=sc, qoe=q, reference=reference,
                            outcomes=outcomes)


@dataclasses.dataclass
class ServeSession:
    """A planned deployment with its runtime adapter armed (§4.3).

    The session carries the *cumulative* runtime picture across events:

    * ``state`` — the merge of every ``DynamicsEvent`` so far (a
      bandwidth drop at t=10 stays in force when a compute-speed event
      arrives at t=20); every adapter reaction sees the merged state.
    * ``active`` — which devices of the original deployment topology
      are currently in the fleet; ``leave``/``join`` churn events
      shrink/grow it and force a full replan on the surviving fleet
      (``Topology.subset``), with the migration stall priced by the
      adapter's delta-switching model.
    * ``plans`` — the current candidate pool replanning draws from
      (the planner's candidates, refreshed on churn).

    ``current`` (and the plans in ``plans``) are indexed in the *active*
    fleet's device space; ``active[i]`` maps stage device ``i`` back to
    the original topology index.
    """

    report: PlanReport
    adapter: RuntimeAdapter
    current: ParallelismPlan
    state: RuntimeState = dataclasses.field(default_factory=RuntimeState)
    active: Tuple[int, ...] = ()
    plans: List[ParallelismPlan] = dataclasses.field(default_factory=list)
    #: the fleet (original ids) ``current`` is indexed in; equal to
    #: ``active`` except during degraded segments, where churn shrank
    #: the fleet but no plan could be built on the survivors
    plan_fleet: Tuple[int, ...] = ()
    #: True while the surviving fleet has no servable plan (e.g. churn
    #: disconnected the routed topology, or nothing QoE-feasible
    #: remains); cleared by the next successful churn replan (rejoin)
    degraded: bool = False
    # planner knobs carried across churn replans (report.topology is
    # already cost-calibrated, so churn planners must NOT re-apply a
    # CostProvider — only the search/scheduler configs carry over)
    partitioner_config: Optional[PartitionerConfig] = None
    scheduler_config: Optional[SchedulerConfig] = None
    #: churn replans warm-start from the surviving candidate pool
    #: (``DoraPlanner.replan``) instead of re-running the full DP; the
    #: fresh search still runs whenever no surviving candidate is
    #: QoE-feasible on the new fleet
    warm_replan: bool = True
    #: control-plane mechanism switches (priority preemption, battery
    #: SoC, streamed migration); ``None`` = everything off
    control: Optional[ControlConfig] = None

    def __post_init__(self) -> None:
        if not self.active:
            self.active = tuple(range(self.report.topology.n))
        if not self.plan_fleet:
            self.plan_fleet = self.active
        if not self.plans:
            self.plans = list(self.report.candidates)
        #: the session's reaction layer — every dynamics decision
        #: (state accumulation, replan/fallback, migration pricing)
        #: lives there; the methods below are thin adapters over it
        self.plane = ControlPlane(self, self.control)

    def _translate(self, state: RuntimeState) -> RuntimeState:
        """Original-index conditions → plan-fleet index space (adapter
        over :meth:`ControlPlane.translate`)."""
        return self.plane.translate(state)

    def on_dynamics(self, event: DynamicsEvent,
                    replan: bool = True) -> Tuple[ParallelismPlan, str, float]:
        """Feed one runtime event to the control plane; track the
        active plan.

        Returns (new plan, action taken, reaction seconds).  ``replan``
        permits full replanning on large shifts; small fluctuations are
        absorbed with network-only rescheduling either way.  Device
        ``leave``/``join`` churn always replans (the fleet changed).
        The event is merged into the session's cumulative ``state``, so
        successive partial events compound instead of overwriting each
        other.  (Thin adapter over :meth:`ControlPlane.on_dynamics` —
        the single reaction implementation.)
        """
        return self.plane.on_dynamics(event, replan=replan)

    def _on_churn(self, event: DynamicsEvent
                  ) -> Tuple[ParallelismPlan, str, float]:
        """Devices left/joined: replan from scratch on the new fleet
        (adapter over :meth:`ControlPlane.churn`)."""
        return self.plane.churn(event)

    @property
    def meets_qoe(self) -> bool:
        """Full QoE verdict for the active plan: latency target AND
        energy/memory budgets (``QoESpec.satisfied``). A degraded
        session (no servable plan for the surviving fleet) never
        meets QoE."""
        if self.degraded:
            return False
        return self.report.qoe.satisfied(self.current)


def serve(scenario: ScenarioRef, *, warm_replan: bool = True,
          control: Optional[ControlConfig] = None,
          **overrides) -> ServeSession:
    """Plan a scenario and arm the runtime adapter over its Pareto set.

    ``warm_replan=False`` forces churn events through the full fresh DP
    (the pre-warm-start behavior) — the planner benchmark uses it to
    price cold vs. warm replans.

    ``control=`` arms control-plane mechanisms
    (:class:`repro.control.ControlConfig`): priority preemption,
    battery state of charge and DEFER-style streamed migration.  With
    the default ``None`` every mechanism is off and the session behaves
    exactly as before."""
    planner, sc, wl = planner_for(scenario, **overrides)
    result = planner.plan(wl)
    report = PlanReport(scenario=sc, topology=planner.topo,
                        graph=planner.graph, workload=wl, qoe=planner.qoe,
                        result=result)
    adapter = planner.make_adapter(result)
    if control is not None and control.streamed_migration:
        # the streamed-migration switch lives on the AdapterConfig so
        # it survives churn replans (the config object is carried over)
        adapter.config.streamed_migration = True
        adapter.config.stream_bw_fraction = control.stream_bw_fraction
    return ServeSession(report=report, adapter=adapter, current=result.best,
                        partitioner_config=planner.partitioner.config,
                        scheduler_config=planner.scheduler.config,
                        warm_replan=warm_replan, control=control)


def calibrate(scenario: Optional[ScenarioRef] = None, *, quick: bool = True,
              path: Optional[str] = None, cache=None):
    """Microbenchmark this host and return a ``ProfiledCosts`` provider.

    The only facade verb that touches jax: it runs the
    :mod:`repro.calibrate` measurement suite (matmul peak, memory
    bandwidth, timed zoo steps, contended stage rate) on the local
    backend and converts the measured-vs-analytic gaps into cost
    factors.

    Without ``scenario`` this returns the host fleet's own per-device
    calibration (devices ``host0..hostN``) — what the fidelity bench
    plans with.  With a ``scenario``, the host factors are applied as a
    *global* correction (``default_compute`` / ``default_bandwidth``)
    so they reach the scenario's differently-named devices::

        costs = dora.calibrate("smart_home_2", path="calibration/home.json")
        report = dora.plan("smart_home_2", costs=costs)
        # or later, from the committed artifact:
        report = dora.plan("smart_home_2", costs="profiled:calibration/home.json")

    ``path`` also writes the artifact as JSON; ``cache`` is a
    :class:`repro.calibrate.MeasurementCache` (defaults to the on-disk
    cache, pass ``MeasurementCache(path=None)`` to force fresh
    measurements).
    """
    from .calibrate.host import calibrate_host
    from .core.profiler import ProfiledCosts
    host = calibrate_host(cache, quick=quick,
                          path=None if scenario is not None else path)
    if scenario is None:
        return host
    sc = get_scenario(scenario)
    cf = list(host.compute_factor.values())
    bf = list(host.bandwidth_factor.values())
    out = ProfiledCosts(
        default_compute=sum(cf) / len(cf) if cf else 1.0,
        default_bandwidth=sum(bf) / len(bf) if bf else 1.0,
        name=f"profiled-host/{sc.name}",
        provenance={**dict(host.provenance),
                    "applied_as": "global host-measured correction "
                                  f"for scenario {sc.name}"})
    if path is not None:
        out.to_json(path)
    return out


# -- multi-tenant fleets --------------------------------------------------------
def plan_fleet(fleet, *, topology=None,
               strategy="dora",
               fleet_config=None,
               partitioner_config: Optional[PartitionerConfig] = None,
               scheduler_config: Optional[SchedulerConfig] = None,
               adapter_config: Optional[AdapterConfig] = None,
               costs: Optional[CostProvider] = None):
    """Co-plan several workloads on one shared fleet.

    ``fleet`` is a registered fleet-scenario name (``python -m
    repro.scenarios --list --fleet``), a
    :class:`~repro.fleet.FleetScenario`, or a plain list of tenant
    scenario refs (then ``topology`` — or the first tenant's — is the
    shared fleet).  Devices are assigned *exclusively* per tenant and
    shared links are priced at their fluid-fair cross-tenant share; the
    assignment search keeps every tenant QoE-feasible first, then
    minimizes total energy (see :class:`repro.fleet.FleetPlanner`).
    ``strategy`` is one name for all tenants or a ``{tenant: name}``
    dict.  Returns a :class:`repro.fleet.FleetPlan`.
    """
    from .fleet import FleetPlanner, resolve_fleet
    fs = resolve_fleet(fleet, topology=topology)
    planner = FleetPlanner(fs.build_topology(), fs.tenants, name=fs.name,
                           strategy=strategy, config=fleet_config,
                           partitioner_config=partitioner_config,
                           scheduler_config=scheduler_config,
                           adapter_config=adapter_config, costs=costs)
    return planner.plan()


def serve_fleet(fleet, *, topology=None, strategy="dora",
                fleet_config=None,
                partitioner_config: Optional[PartitionerConfig] = None,
                scheduler_config: Optional[SchedulerConfig] = None,
                adapter_config: Optional[AdapterConfig] = None,
                costs: Optional[CostProvider] = None):
    """Co-plan a fleet and arm every tenant's runtime adapter plus the
    cross-tenant rebalancer.  Returns a
    :class:`repro.fleet.FleetSession` whose ``on_dynamics`` routes
    events to the owning tenants and moves devices between tenants on
    churn or QoE-breaking load shifts."""
    from .fleet import FleetPlanner, FleetSession, resolve_fleet
    fs = resolve_fleet(fleet, topology=topology)
    planner = FleetPlanner(fs.build_topology(), fs.tenants, name=fs.name,
                           strategy=strategy, config=fleet_config,
                           partitioner_config=partitioner_config,
                           scheduler_config=scheduler_config,
                           adapter_config=adapter_config, costs=costs)
    return FleetSession(planner, planner.plan(), scenario=fs)


@dataclasses.dataclass(frozen=True)
class SimulationStep:
    t: float
    label: str
    action: str                 # "reschedule" | "replan"
    react_seconds: float
    latency: float
    qoe_ok: bool


@dataclasses.dataclass
class SimulationTrace:
    report: PlanReport
    steps: List[SimulationStep]

    @property
    def qoe_violations(self) -> int:
        return sum(1 for s in self.steps if not s.qoe_ok)

    def to_dict(self) -> Dict[str, object]:
        return {
            "scenario": self.report.scenario.name,
            "baseline_latency_s": _json_num(self.report.latency),
            "qoe_violations": self.qoe_violations,
            "steps": [{
                "t": s.t, "label": s.label, "action": s.action,
                "react_s": _json_num(s.react_seconds),
                "latency_s": _json_num(s.latency), "qoe_ok": s.qoe_ok,
            } for s in self.steps],
        }

    def summary(self) -> str:
        lines = [f"baseline latency {self.report.latency * 1e3:.1f} ms "
                 f"(QoE target {self.report.qoe.t_qoe:g}s)"]
        for s in self.steps:
            lines.append(
                f"t={s.t:6.1f}s  {s.label:52s} -> {s.action:10s} "
                f"({s.react_seconds * 1e3:.0f} ms) latency "
                f"{s.latency * 1e3:8.1f} ms "
                f"{'[QoE OK]' if s.qoe_ok else '[QoE MISS]'}")
        lines.append(f"{len(self.steps)} events, "
                     f"{self.qoe_violations} QoE violations")
        return "\n".join(lines)


def simulate(scenario: ScenarioRef,
             events: Optional[Sequence[TimelineItem]] = None,
             session: Optional[ServeSession] = None,
             copy: bool = False,
             mode: str = "events",
             **overrides) -> Union[SimulationTrace, "ServingTrace"]:
    """Replay a dynamics timeline through the runtime adapter.

    ``events`` defaults to the scenario's registered timeline; each item
    is a ``DynamicsEvent`` or a ``(label, event)`` pair.  Every event's
    adapter reaction (reschedule vs replan, reaction time, post-event
    latency) is recorded in the returned trace.  Pass an existing
    ``session`` (from ``dora.serve`` of the *same* scenario) to reuse
    its plan instead of re-running the planner.

    ``mode="events"`` (default) replays the timeline event-by-event and
    returns a :class:`SimulationTrace`.  ``mode="requests"`` runs the
    request-level serving simulator (``repro.sim.serving``): open-loop
    arrivals at the scenario's registered request rate queue through
    the active plan's pipeline while the timeline (bandwidth/compute
    shifts AND device join/leave churn) plays out; returns a
    :class:`repro.sim.serving.ServingTrace` with p50/p95/p99 latency,
    SLO attainment, per-device energy (idle draw included) and every
    adapter action.  Extra knobs for that mode: ``load=`` (a
    ``ServingLoad``), ``strategy=`` (simulate a non-adaptive baseline
    strategy instead of dora's adapter).

    ``mode="fleet"`` runs the *multi-tenant* serving simulator
    (``repro.sim.fleet``): ``scenario`` is then a fleet-scenario name /
    :class:`repro.fleet.FleetScenario` / tenant list, every tenant gets
    its own concurrent request stream on its exclusive device
    allotment, and the fleet timeline flows through the cross-tenant
    rebalancer; returns a :class:`repro.sim.fleet.FleetTrace`.  Extra
    knobs: ``loads=`` ({tenant: ServingLoad}), ``span_s=``, ``seed=``;
    ``session=`` takes a :class:`repro.fleet.FleetSession` from
    ``dora.serve_fleet``.

    **Mutation contract:** replaying events *advances the session* —
    ``session.current`` tracks the adapter's latest plan (after churn,
    re-indexed to the surviving fleet with ``session.active`` mapping
    back to original device ids) and the adapter's internal Pareto set
    is re-evaluated under the final event's conditions, exactly as a
    live deployment would be left.  Pass ``copy=True`` to deep-copy the
    session (adapter state included) first and replay against the copy,
    leaving the caller's session untouched; the returned trace then
    references the copy's report.
    """
    if mode == "requests":
        from .sim.serving import simulate_requests
        if copy and session is not None:
            session = _copy.deepcopy(session)
        return simulate_requests(scenario, events=events, session=session,
                                 **overrides)
    if mode == "fleet":
        from .sim.fleet import simulate_fleet
        if copy and session is not None:
            session = _copy.deepcopy(session)
        return simulate_fleet(scenario, events=events, session=session,
                              **overrides)
    if mode != "events":
        raise ValueError(f"unknown mode {mode!r}: expected 'events', "
                         f"'requests' or 'fleet'")
    if session is None:
        session = serve(scenario, **overrides)
    else:
        want = get_scenario(scenario).name
        have = session.report.scenario.name
        if want != have:
            raise ValueError(f"session was served for scenario {have!r}, "
                             f"not {want!r}")
        if overrides:
            raise ValueError("overrides are ignored when reusing a session; "
                             "pass them to dora.serve instead")
        if copy:
            session = _copy.deepcopy(session)
    from .core.events import normalize_timeline
    timeline = normalize_timeline(
        events if events is not None else session.report.scenario.timeline)
    steps: List[SimulationStep] = []
    for label, ev in timeline:
        new, action, react = session.on_dynamics(ev)
        steps.append(SimulationStep(t=ev.t, label=label, action=action,
                                    react_seconds=react, latency=new.latency,
                                    qoe_ok=session.meets_qoe))
    return SimulationTrace(report=session.report, steps=steps)


#: moved internals kept importable with a DeprecationWarning (the
#: reaction layer now lives in ``repro.control``)
_MOVED = {
    "_remap_plan": "_remap_plan",
}


def __getattr__(name: str):
    target = _MOVED.get(name)
    if target is not None:
        warnings.warn(
            f"repro.dora.{name} moved to repro.control.plane.{target}; "
            f"import it from there",
            DeprecationWarning, stacklevel=2)
        from .control import plane as _plane
        return getattr(_plane, target)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "PlanReport", "ServeSession", "SimulationStep", "SimulationTrace",
    "StrategyOutcome", "ComparisonReport", "DEFAULT_COMPARISON",
    "ControlConfig", "RuntimeState", "calibrate", "plan", "planner_for",
    "serve", "simulate", "compare", "plan_fleet", "serve_fleet",
]
