"""``repro.dora`` — the one-call facade over Dora's planning stack.

The three paper mechanisms (model partitioner §4.1, contention-aware
network scheduler §4.2, runtime adapter §4.3) are wired behind three
verbs, each taking a scenario name (or an ad-hoc
:class:`repro.scenarios.Scenario`):

    from repro import dora

    report  = dora.plan("smart_home_2")          # -> PlanReport
    session = dora.serve("traffic_monitor")      # -> ServeSession (adapter)
    trace   = dora.simulate("vehicle_platoon")   # -> SimulationTrace

``plan`` runs Algorithm 1 end to end (partition → schedule → Pareto
filter); ``serve`` additionally arms the runtime adapter for dynamics;
``simulate`` replays a timeline of :class:`DynamicsEvent`\\ s through the
adapter and records every reaction.  Every knob of the underlying stack
remains reachable through keyword overrides (``workload=``, ``qoe=``,
``graph=``, ``topology=``, ``partitioner_config=``, ...), so the facade
never forces a drop back down to hand-wiring ``DoraPlanner``.

This module is deliberately jax-free: planning is analytic, so importing
``repro.dora`` never initializes an accelerator backend.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .core.adapter import AdapterConfig, DynamicsEvent, RuntimeAdapter
from .core.cost_model import Workload
from .core.device import Topology
from .core.partitioner import PartitionerConfig
from .core.planner import DoraPlanner, PlanningResult
from .core.planning_graph import ModelGraph
from .core.plans import ParallelismPlan
from .core.qoe import QoESpec
from .core.scheduler import SchedulerConfig
from .scenarios import Scenario, get_scenario

ScenarioRef = Union[str, Scenario]

# (label, event) or bare event — both accepted by simulate().
TimelineItem = Union[DynamicsEvent, Tuple[str, DynamicsEvent]]


@dataclasses.dataclass
class PlanReport:
    """Everything ``dora.plan`` produced for one scenario, in one object."""

    scenario: Scenario
    topology: Topology
    graph: ModelGraph
    workload: Workload
    qoe: QoESpec
    result: PlanningResult

    @property
    def best(self) -> ParallelismPlan:
        return self.result.best

    @property
    def candidates(self) -> List[ParallelismPlan]:
        return self.result.candidates

    @property
    def pareto(self) -> List[ParallelismPlan]:
        return self.result.pareto

    @property
    def latency(self) -> float:
        return self.result.best.latency

    @property
    def energy(self) -> float:
        return self.result.best.energy

    @property
    def meets_qoe(self) -> bool:
        return self.result.best.latency <= self.qoe.t_qoe

    @property
    def planning_seconds(self) -> float:
        return self.result.total_s

    def summary(self) -> str:
        lines = [
            f"scenario {self.scenario.name} [{self.scenario.mode}] "
            f"model={self.scenario.model_name} devices={self.topology.n}",
            f"planned in {self.result.total_s:.2f}s "
            f"(phase1 {self.result.phase1_s:.2f}s + "
            f"phase2 {self.result.phase2_s:.2f}s)",
            f"best: {self.best.summary()}",
            f"QoE target {self.qoe.t_qoe:g}s: "
            f"{'MET' if self.meets_qoe else 'VIOLATED'} "
            f"({self.latency:.3f}s, {self.energy:.1f} J)",
            f"pareto frontier ({len(self.pareto)} plans for runtime mixing):",
        ]
        for p in self.pareto:
            lines.append(f"  lat={p.latency * 1e3:9.1f} ms  "
                         f"energy={p.energy:9.1f} J  "
                         f"stages={p.n_stages} mb={p.microbatch_size}")
        return "\n".join(lines)


def _resolve(scenario: ScenarioRef,
             topology: Optional[Topology],
             graph: Optional[ModelGraph],
             workload: Optional[Workload],
             qoe: Optional[QoESpec],
             seq_len: Optional[int]
             ) -> Tuple[Scenario, Topology, ModelGraph, Workload, QoESpec]:
    sc = get_scenario(scenario)
    topo = topology if topology is not None else sc.build_topology()
    wl = workload if workload is not None else sc.workload
    q = qoe if qoe is not None else sc.qoe
    g = graph if graph is not None else sc.build_graph(seq_len=seq_len)
    return sc, topo, g, wl, q


def planner_for(scenario: ScenarioRef, *,
                topology: Optional[Topology] = None,
                graph: Optional[ModelGraph] = None,
                workload: Optional[Workload] = None,
                qoe: Optional[QoESpec] = None,
                seq_len: Optional[int] = None,
                partitioner_config: Optional[PartitionerConfig] = None,
                scheduler_config: Optional[SchedulerConfig] = None,
                adapter_config: Optional[AdapterConfig] = None
                ) -> Tuple[DoraPlanner, Scenario, Workload]:
    """Construct (planner, scenario, workload) without running it —
    the escape hatch for callers that sweep planner configurations."""
    sc, topo, g, wl, q = _resolve(scenario, topology, graph, workload, qoe,
                                  seq_len)
    planner = DoraPlanner(g, topo, q,
                          partitioner_config=partitioner_config,
                          scheduler_config=scheduler_config,
                          adapter_config=adapter_config)
    return planner, sc, wl


def plan(scenario: ScenarioRef, **overrides) -> PlanReport:
    """Run Algorithm 1 end to end for one scenario.

    ``dora.plan("smart_home_2")`` plans the registered deployment as-is;
    keyword overrides swap any ingredient (``workload=``, ``qoe=``,
    ``graph=``, ``topology=``, ``seq_len=``, ``partitioner_config=``,
    ``scheduler_config=``).
    """
    planner, sc, wl = planner_for(scenario, **overrides)
    result = planner.plan(wl)
    return PlanReport(scenario=sc, topology=planner.topo, graph=planner.graph,
                      workload=wl, qoe=planner.qoe, result=result)


@dataclasses.dataclass
class ServeSession:
    """A planned deployment with its runtime adapter armed (§4.3)."""

    report: PlanReport
    adapter: RuntimeAdapter
    current: ParallelismPlan

    def on_dynamics(self, event: DynamicsEvent,
                    replan: bool = True) -> Tuple[ParallelismPlan, str, float]:
        """Feed one runtime event to the adapter; track the active plan.

        Returns (new plan, action taken, reaction seconds).  ``replan``
        permits full replanning on large shifts; small fluctuations are
        absorbed with network-only rescheduling either way.
        """
        replan_fn = (lambda: list(self.report.candidates)) if replan else None
        new, action, react = self.adapter.on_dynamics(self.current, event,
                                                      replan_fn=replan_fn)
        self.current = new
        return new, action, react

    @property
    def meets_qoe(self) -> bool:
        return self.current.latency <= self.report.qoe.t_qoe


def serve(scenario: ScenarioRef, **overrides) -> ServeSession:
    """Plan a scenario and arm the runtime adapter over its Pareto set."""
    planner, sc, wl = planner_for(scenario, **overrides)
    result = planner.plan(wl)
    report = PlanReport(scenario=sc, topology=planner.topo,
                        graph=planner.graph, workload=wl, qoe=planner.qoe,
                        result=result)
    adapter = planner.make_adapter(result)
    return ServeSession(report=report, adapter=adapter, current=result.best)


@dataclasses.dataclass(frozen=True)
class SimulationStep:
    t: float
    label: str
    action: str                 # "reschedule" | "replan"
    react_seconds: float
    latency: float
    qoe_ok: bool


@dataclasses.dataclass
class SimulationTrace:
    report: PlanReport
    steps: List[SimulationStep]

    @property
    def qoe_violations(self) -> int:
        return sum(1 for s in self.steps if not s.qoe_ok)

    def summary(self) -> str:
        lines = [f"baseline latency {self.report.latency * 1e3:.1f} ms "
                 f"(QoE target {self.report.qoe.t_qoe:g}s)"]
        for s in self.steps:
            lines.append(
                f"t={s.t:6.1f}s  {s.label:52s} -> {s.action:10s} "
                f"({s.react_seconds * 1e3:.0f} ms) latency "
                f"{s.latency * 1e3:8.1f} ms "
                f"{'[QoE OK]' if s.qoe_ok else '[QoE MISS]'}")
        lines.append(f"{len(self.steps)} events, "
                     f"{self.qoe_violations} QoE violations")
        return "\n".join(lines)


def simulate(scenario: ScenarioRef,
             events: Optional[Sequence[TimelineItem]] = None,
             session: Optional[ServeSession] = None,
             **overrides) -> SimulationTrace:
    """Replay a dynamics timeline through the runtime adapter.

    ``events`` defaults to the scenario's registered timeline; each item
    is a ``DynamicsEvent`` or a ``(label, event)`` pair.  Every event's
    adapter reaction (reschedule vs replan, reaction time, post-event
    latency) is recorded in the returned trace.  Pass an existing
    ``session`` (from ``dora.serve`` of the *same* scenario) to reuse
    its plan instead of re-running the planner.
    """
    if session is None:
        session = serve(scenario, **overrides)
    else:
        want = get_scenario(scenario).name
        have = session.report.scenario.name
        if want != have:
            raise ValueError(f"session was served for scenario {have!r}, "
                             f"not {want!r}")
        if overrides:
            raise ValueError("overrides are ignored when reusing a session; "
                             "pass them to dora.serve instead")
    timeline: List[Tuple[str, DynamicsEvent]] = []
    source: Sequence[TimelineItem] = (
        events if events is not None else session.report.scenario.timeline)
    for item in source:
        if isinstance(item, DynamicsEvent):
            timeline.append((f"event@t={item.t:g}s", item))
        else:
            label, ev = item
            timeline.append((label, ev))
    steps: List[SimulationStep] = []
    for label, ev in sorted(timeline, key=lambda kv: kv[1].t):
        new, action, react = session.on_dynamics(ev)
        steps.append(SimulationStep(t=ev.t, label=label, action=action,
                                    react_seconds=react, latency=new.latency,
                                    qoe_ok=session.meets_qoe))
    return SimulationTrace(report=session.report, steps=steps)


__all__ = [
    "PlanReport", "ServeSession", "SimulationStep", "SimulationTrace",
    "plan", "planner_for", "serve", "simulate",
]
