"""Serving under runtime dynamics — Dora's adapter on a camera ring.

1. Dora plans inference for the Traffic Monitor fleet (ring + WiFi).
2. A background-interference timeline hits the fleet; the Runtime
   Adapter absorbs small fluctuations with network-only rescheduling
   and replans (async + delta switching) on large shifts.
3. A real reduced model serves batched requests through prefill/decode
   with its KV cache (greedy), reporting tokens/sec on this host.

    PYTHONPATH=src python examples/traffic_monitor_serving.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import dora
from repro.configs import reduced_config
from repro.core.adapter import DynamicsEvent
from repro.models import build_model

TIMELINE = [
    ("t=10s  camera uploads footage (wifi −50%)",
     DynamicsEvent(t=10.0, bandwidth_scale={"wifi": 0.5})),
    ("t=20s  cam0 runs a detector (compute −40%)",
     DynamicsEvent(t=20.0, compute_speed={0: 0.6})),
    ("t=30s  interference clears",
     DynamicsEvent(t=30.0, compute_speed={0: 1.0},
                   bandwidth_scale={"wifi": 1.0})),
]


def main() -> None:
    # ---- 1 + 2. plan inference, then replay the dynamics timeline ----------
    # ``simulate`` = plan (partition → schedule) + runtime adapter armed
    # over the Pareto set, reacting to each event.
    trace = dora.simulate("traffic_monitor", events=TIMELINE)
    print("serving plan:", trace.report.best.summary(), "\n")
    print(trace.summary())

    # ---- 3. real batched decode on this host -------------------------------
    print("\nreal batched serving (reduced model, greedy decode):")
    cfg = reduced_config("qwen3_32b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, prompt, gen = 4, 16, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, prompt),
                              0, cfg.vocab_size)
    cache = model.init_cache(B, prompt + gen)
    logits, cache = model.prefill(params, toks, cache)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    decode = jax.jit(model.decode)
    # warmup + timed loop
    pos = jnp.full((B,), prompt, jnp.int32)
    _, _ = decode(params, cur, cache, pos)
    t0 = time.time()
    for i in range(gen):
        pos = jnp.full((B,), prompt + i, jnp.int32)
        logits, cache = decode(params, cur, cache, pos)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(cur)
    dt = time.time() - t0
    print(f"  {B} streams × {gen} tokens in {dt:.2f}s "
          f"= {B * gen / dt:.0f} tok/s on {jax.default_backend()}")


if __name__ == "__main__":
    main()
