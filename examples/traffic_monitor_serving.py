"""Serving under runtime dynamics — Dora's adapter on a camera ring.

1. Dora plans inference for the Traffic Monitor fleet (ring + WiFi).
2. A background-interference timeline hits the fleet; the Runtime
   Adapter absorbs small fluctuations with network-only rescheduling
   and replans (async + delta switching) on large shifts.
3. A real reduced model serves batched requests through prefill/decode
   with its KV cache (greedy), reporting tokens/sec on this host.

    PYTHONPATH=src python examples/traffic_monitor_serving.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.core.adapter import DynamicsEvent, RuntimeAdapter
from repro.core.cost_model import Workload
from repro.core.device import make_setting
from repro.core.graph_builders import paper_model
from repro.core.planner import DoraPlanner
from repro.core.qoe import QoESpec
from repro.core.scheduler import NetworkScheduler
from repro.models import build_model

TIMELINE = [
    ("t=10s  camera uploads footage (wifi −50%)",
     DynamicsEvent(t=10.0, bandwidth_scale={"wifi": 0.5})),
    ("t=20s  cam0 runs a detector (compute −40%)",
     DynamicsEvent(t=20.0, compute_speed={0: 0.6})),
    ("t=30s  interference clears",
     DynamicsEvent(t=30.0, compute_speed={0: 1.0},
                   bandwidth_scale={"wifi": 1.0})),
]


def main() -> None:
    # ---- 1. plan inference for the fleet -----------------------------------
    topo = make_setting("traffic_monitor")
    graph = paper_model("qwen3-0.6b", seq_len=1)          # per-token serving
    qoe = QoESpec(t_qoe=0.2, lam=100.0)                    # ≤200 ms per batch
    planner = DoraPlanner(graph, topo, qoe)
    result = planner.plan(Workload(global_batch=8, microbatch_size=1,
                                   training=False))
    print("serving plan:", result.best.summary())

    # ---- 2. dynamics timeline ----------------------------------------------
    sched = NetworkScheduler(topo, qoe)
    adapter = RuntimeAdapter(result.candidates, topo, qoe, sched)
    current = result.best
    print(f"\nbaseline batch latency {current.latency * 1e3:.1f} ms")
    for label, ev in TIMELINE:
        current, action, react = adapter.on_dynamics(
            current, ev, replan_fn=lambda: list(result.candidates))
        print(f"{label:48s} -> {action:10s} "
              f"({react * 1e3:.0f} ms) new latency "
              f"{current.latency * 1e3:.1f} ms "
              f"{'[QoE OK]' if current.latency <= qoe.t_qoe else '[QoE MISS]'}")

    # ---- 3. real batched decode on this host -------------------------------
    print("\nreal batched serving (reduced model, greedy decode):")
    cfg = reduced_config("qwen3_32b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, prompt, gen = 4, 16, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, prompt),
                              0, cfg.vocab_size)
    cache = model.init_cache(B, prompt + gen)
    logits, cache = model.prefill(params, toks, cache)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    decode = jax.jit(model.decode)
    # warmup + timed loop
    pos = jnp.full((B,), prompt, jnp.int32)
    _, _ = decode(params, cur, cache, pos)
    t0 = time.time()
    for i in range(gen):
        pos = jnp.full((B,), prompt + i, jnp.int32)
        logits, cache = decode(params, cur, cache, pos)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(cur)
    dt = time.time() - t0
    print(f"  {B} streams × {gen} tokens in {dt:.2f}s "
          f"= {B * gen / dt:.0f} tok/s on {jax.default_backend()}")


if __name__ == "__main__":
    main()
