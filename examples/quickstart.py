"""Quickstart — QoE-aware planning in ~20 lines.

Plan Qwen3-0.6B training for a smart home (2 laptops + 3 phones on
shared WiFi) under a latency target, inspect the chosen hybrid-parallel
plan, and see the energy/latency frontier the runtime adapter can mix.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.cost_model import Workload
from repro.core.device import make_setting
from repro.core.graph_builders import paper_model
from repro.core.planner import DoraPlanner
from repro.core.qoe import QoESpec


def main() -> None:
    topo = make_setting("smart_home_2")           # Table 3 deployment
    graph = paper_model("qwen3-0.6b", seq_len=512)
    qoe = QoESpec(t_qoe=8.0, lam=50.0)            # ≤8 s/iteration; λ = 50 J/s

    planner = DoraPlanner(graph, topo, qoe)
    result = planner.plan(Workload(global_batch=32, microbatch_size=4,
                                   optimizer_mult=3.0))

    print(f"planning took {result.total_s:.2f}s "
          f"(phase1 {result.phase1_s:.2f}s + phase2 {result.phase2_s:.2f}s)\n")
    print("BEST PLAN:", result.best.summary(), "\n")
    print("Pareto frontier (for runtime mixing):")
    for p in result.pareto:
        print(f"  lat={p.latency * 1e3:7.1f} ms  energy={p.energy:7.1f} J  "
              f"stages={p.n_stages} mb={p.microbatch_size}")

    meets = result.best.latency <= qoe.t_qoe
    print(f"\nQoE target {qoe.t_qoe:.1f}s: "
          f"{'MET' if meets else 'VIOLATED'} "
          f"({result.best.latency:.2f}s, {result.best.energy:.0f} J/iter)")


if __name__ == "__main__":
    main()
