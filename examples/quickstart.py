"""Quickstart — QoE-aware planning in three lines.

Plan Qwen3-0.6B training for a smart home (2 laptops + 3 phones on
shared WiFi) under a latency target, inspect the chosen hybrid-parallel
plan, and see the energy/latency frontier the runtime adapter can mix.
Every deployment here is a named scenario from ``repro.scenarios``; run
``python -m repro.scenarios --list`` to see them all.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import dora


def main() -> None:
    report = dora.plan("smart_home_2")            # Table 3 deployment
    print(report.summary())

    # every knob stays reachable through overrides:
    from repro.core.qoe import QoESpec
    tight = dora.plan("smart_home_2", qoe=QoESpec(t_qoe=6.0, lam=200.0))
    print(f"\nwith a 6 s target instead: latency {tight.latency:.2f}s, "
          f"energy {tight.energy:.0f} J "
          f"({'MET' if tight.meets_qoe else 'VIOLATED'})")


if __name__ == "__main__":
    main()
