"""End-to-end training driver — plan with Dora, then actually train.

1. Dora plans hybrid parallelism for the Smart Home 2 fleet (QoE-aware).
2. The JAX substrate trains a small qwen-family model on the synthetic
   token stream with AdamW, async sharded checkpointing and restart.

On this CPU container the model defaults to a ~10M-param reduced config
(~300 steps in minutes); pass ``--big`` for a ~100M-param model if you
have the patience or a real accelerator.

    PYTHONPATH=src python examples/smart_home_training.py --steps 200
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import dora
from repro.checkpoint import Checkpointer, latest_step
from repro.configs import reduced_config
from repro.core.cost_model import Workload
from repro.core.graph_builders import GraphSpec, build_lm_graph
from repro.core.qoe import QoESpec
from repro.data import DataConfig, TokenPipeline
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.launch.steps import make_train_step
from repro.models.common import count_params
from repro.optim import adamw_init


def model_cfg(big: bool):
    base = reduced_config("qwen3_32b")
    if big:   # ~100M params
        return dataclasses.replace(base, n_layers=12, d_model=768,
                                   n_heads=12, n_kv_heads=4, head_dim=64,
                                   d_ff=2048, vocab_size=32768)
    return dataclasses.replace(base, n_layers=8, d_model=256, n_heads=8,
                               n_kv_heads=4, head_dim=32, d_ff=1024,
                               vocab_size=8192)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/dora_smart_home_ckpt")
    args = ap.parse_args()

    # ---- 1. QoE-aware plan for the edge fleet -----------------------------
    # the scenario supplies fleet + workload; we swap in the actual
    # (reduced) model being trained and this run's QoE target.
    cfg = model_cfg(args.big)
    spec = GraphSpec("home-lm", cfg.n_layers, cfg.d_model, cfg.n_heads,
                     cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size,
                     head_dim=cfg.head_dim, seq_len=args.seq)
    report = dora.plan("smart_home_2", graph=build_lm_graph(spec),
                       qoe=QoESpec(t_qoe=2.0, lam=10.0),
                       workload=Workload(global_batch=32, microbatch_size=4,
                                         optimizer_mult=3.0))
    result = report.result
    print("Dora plan for the fleet:", report.best.summary())
    print(f"(planned in {result.total_s:.2f}s; executing the training loop "
          f"locally on {jax.device_count()} JAX device(s))\n")

    # ---- 2. real training on the JAX substrate ----------------------------
    mesh = make_host_mesh()
    model, train_step = make_train_step(cfg, peak_lr=1e-3,
                                        warmup=max(args.steps // 20, 5),
                                        total=args.steps, remat="none")
    with use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        print(f"model: {count_params(params) / 1e6:.1f}M params")
        opt = adamw_init(params)
        ckpt = Checkpointer(args.ckpt_dir)
        step0 = latest_step(args.ckpt_dir) or 0
        if step0:
            tree = ckpt.restore(step0, {"params": params, "opt": opt})
            params, opt = tree["params"], tree["opt"]
            print(f"resumed from checkpoint step {step0}")

        data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=args.seq,
                                        global_batch=args.global_batch), mesh)
        jit_step = jax.jit(train_step, donate_argnums=(0, 1))
        losses, t0 = [], time.time()
        for step in range(step0, args.steps):
            params, opt, m = jit_step(params, opt, next(data),
                                      jnp.asarray(step))
            losses.append(float(m["loss"]))
            if step % 20 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                      f"lr {float(m['lr']):.2e}  ({time.time() - t0:.0f}s)",
                      flush=True)
            if (step + 1) % 100 == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt})
        ckpt.save(args.steps, {"params": params, "opt": opt}, wait=True)
        data.close()
        print(f"\nloss {np.mean(losses[:10]):.3f} → {np.mean(losses[-10:]):.3f}"
              f"  (checkpoints in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
