"""Fault tolerance end to end — checkpoint, lose half the fleet, resume.

Runs on 8 virtual host devices (set before jax import): trains a tiny
model on an 8-device mesh with async sharded checkpoints, simulates 4
devices going silent, and shows the elastic controller re-mesh + Dora
replan + resharded restore resuming training on the survivors.

    python examples/elastic_recovery.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import dora
from repro.checkpoint import Checkpointer
from repro.configs import reduced_config
from repro.core.cost_model import Workload
from repro.core.device import CATALOG, Topology
from repro.core.graph_builders import GraphSpec, build_lm_graph
from repro.core.qoe import QoESpec
from repro.scenarios import Scenario
from repro.launch.mesh import use_mesh
from repro.launch.steps import make_train_step
from repro.models.sharding import ShardingRules
from repro.optim import adamw_init
from repro.runtime.elastic import ElasticController, ElasticState


def make_mesh(n):
    return jax.make_mesh((1, n), ("data", "model"), devices=jax.devices()[:n])


def main() -> None:
    cfg = dataclasses.replace(reduced_config("granite_8b"), n_layers=2,
                              d_model=64, d_ff=128, vocab_size=256,
                              n_heads=4, n_kv_heads=2, head_dim=16)
    model, train_step = make_train_step(cfg, remat="none")
    jit_step = jax.jit(train_step)

    def batch(mesh, seed):
        k = jax.random.PRNGKey(seed)
        t = jax.random.randint(k, (8, 17), 0, cfg.vocab_size)
        sh = NamedSharding(mesh, P())
        return {"tokens": jax.device_put(t[:, :-1], sh),
                "labels": jax.device_put(t[:, 1:], sh)}

    def spec_fn(mesh, shapes):
        rules = ShardingRules(cfg, mesh)
        return {"params": rules.param_specs(shapes["params"]),
                "opt": {"m": rules.param_specs(shapes["opt"]["m"]),
                        "v": rules.param_specs(shapes["opt"]["v"]),
                        "count": P()}}

    ckpt = Checkpointer(tempfile.mkdtemp(), async_save=False)
    mesh8 = make_mesh(8)
    print(f"training on {mesh8.devices.size} devices...")
    with use_mesh(mesh8):
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        for step in range(4):
            params, opt, m = jit_step(params, opt, batch(mesh8, step),
                                      jnp.asarray(step))
            print(f"  step {step} loss {float(m['loss']):.4f}")
        ckpt.save(4, {"params": params, "opt": opt}, wait=True)
    print("checkpoint committed at step 4")

    ctrl = ElasticController(make_mesh=make_mesh, spec_fn=spec_fn,
                             ckpt=ckpt, n_devices=8)
    for t in (1.0, 2.0, 3.0, 4.0):
        for d in range(4):
            ctrl.coordinator.beat(d, t)
    failed = ctrl.coordinator.tick(5.0)
    print(f"\nheartbeat detector: devices {failed} FAILED "
          f"(healthy: {ctrl.coordinator.healthy})")

    # Dora replans for the shrunk fleet (planner view of the same event):
    # an ad-hoc Scenario — the facade takes unregistered deployments too.
    devs = [CATALOG["rtx4050"]] * 4
    spec = GraphSpec("m", cfg.n_layers, cfg.d_model, cfg.n_heads,
                     cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size, seq_len=16)
    survivors = Scenario(
        name="home_survivors",
        description="Smart-home fleet after losing 4 of 8 devices",
        topology=lambda: Topology.shared_medium(devs, 600.0),
        model=lambda seq_len: build_lm_graph(spec, seq_len=seq_len),
        workload=Workload(global_batch=8, microbatch_size=1,
                          optimizer_mult=3.0),
        qoe=QoESpec(t_qoe=1.0, lam=10.0), seq_len=16)
    plan = dora.plan(survivors).result
    print(f"Dora replanned for 4 survivors in {plan.total_s:.2f}s: "
          f"{plan.best.n_stages} stages")

    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          {"params": params, "opt": opt})
    state = ctrl.remesh(ElasticState(mesh=mesh8, step=4, params=None,
                                     opt_state=None), shapes)
    print(f"restored step {state.step} onto a "
          f"{state.mesh.devices.size}-device mesh (generation "
          f"{state.generation})")
    with use_mesh(state.mesh):
        p, o, m = jit_step(state.params, state.opt_state,
                           batch(state.mesh, 99), jnp.asarray(5))
    print(f"training resumed: step 5 loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
