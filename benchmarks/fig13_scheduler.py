"""Fig. 13 — network scheduler: utilization + the responsiveness knob.

(a) WiFi utilization across one training iteration with vs without the
Phase-2 schedule (Traffic Monitor); (b) schedule quality vs the tunable
search budget (chunk modes searched). Paper: sub-second rescheduling.
"""
from __future__ import annotations

import time

from .common import Claim, table

from repro.core.qoe import QoESpec
from repro.core.scheduler import NetworkScheduler, SchedulerConfig
from repro.sim.runner import dora_plan, scenario_case

LAT = QoESpec(t_qoe=0.0, lam=1e15)


def run(report) -> None:
    # the traffic-monitor fleet, driven in training mode for this figure
    topo, graph, wl = scenario_case("traffic_monitor", model="qwen3-0.6b",
                                    mode="train")
    plan = dora_plan(graph, topo, LAT, wl).best

    # (a) utilization with/without Phase 2
    sched = NetworkScheduler(topo, LAT)
    fair = sched.evaluate_fair(plan)
    refined = sched.refine(plan)
    rows = []
    for name, p in (("fluid (no schedule)", fair), ("Dora Phase-2", refined)):
        util = max(p.schedule.utilization(r) for r in topo.resources)
        rows.append([name, f"{p.latency * 1e3:.1f}", f"{util:.1%}"])
    report.add_table(table(["schedule", "iteration (ms)", "peak link util"],
                           rows, "Fig. 13a — schedule vs utilization"))

    # (b) search-budget knob: more chunk modes = better schedule, more time
    rows_b, lat_by_budget, times = [], [], []
    for modes in ((1,), (1, 2), (1, 2, 4), (1, 2, 4, 8)):
        cfg = SchedulerConfig(modes=modes, time_budget_s=10.0)
        s = NetworkScheduler(topo, LAT, cfg)
        t0 = time.perf_counter()
        r = s.refine(plan)
        dt = time.perf_counter() - t0
        lat_by_budget.append(r.latency)
        times.append(dt)
        rows_b.append([str(list(modes)), f"{r.latency * 1e3:.2f}",
                       f"{dt * 1e3:.0f}"])
    report.add_table(table(["chunk modes searched", "latency (ms)",
                            "search time (ms)"], rows_b,
                           "Fig. 13b — responsiveness knob"))

    c1 = Claim("Fig13: per-plan network (re)scheduling completes sub-second")
    c1.check(max(times) < 1.0, f"max {max(times) * 1e3:.0f} ms")
    c2 = Claim("Fig13: wider search never worsens the schedule")
    c2.check(all(b <= a * (1 + 1e-9)
                 for a, b in zip(lat_by_budget, lat_by_budget[1:])),
             " → ".join(f"{l * 1e3:.2f}" for l in lat_by_budget))
    report.add_claims([c1, c2])
