"""Fig. 8 — training latency: Dora vs 4 baselines across 4 settings ×
4 models. Paper claim: 1.1–6.3× faster than the best baseline."""
from __future__ import annotations

from .common import MODELS_TRAIN, SETTINGS, Claim, ms, table

from repro.sim.runner import (COMPARISON_PLANNERS, best_baseline,
                              compare_planners, setting_and_graph,
                              workload_for)

PLANNERS = list(COMPARISON_PLANNERS)


def run(report) -> None:
    rows = []
    speedups = []
    results = {}
    for model in MODELS_TRAIN:
        for setting in SETTINGS:
            topo, graph = setting_and_graph(setting, model, "train")
            res = compare_planners(graph, topo, workload_for("train"))
            results[(model, setting)] = res
            row = [model, setting]
            for p in PLANNERS:
                row.append(ms(res[p].latency) if res[p].ok
                           else res[p].failure_label)
            try:
                _, bb = best_baseline(res)
                sp = bb.latency / res["dora"].latency
                speedups.append(sp)
                row.append(f"{sp:.2f}x")
            except RuntimeError:
                row.append("n/a")
            rows.append(row)
    report.add_table(table(
        ["model", "setting"] + [f"{p} (ms)" for p in PLANNERS] + ["speedup"],
        rows, "Fig. 8 — training iteration latency"))

    c = Claim("Fig8: Dora never slower than the best baseline; speedups in "
              "the paper's 1.1–6.3× band on contended settings")
    c.check(min(speedups) >= 0.999 and max(speedups) >= 1.1,
            f"range {min(speedups):.2f}–{max(speedups):.2f}×")
    report.add_claims([c])
    report.stash("fig8", results)
