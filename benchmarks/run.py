"""Run every benchmark harness; print tables + per-claim verdicts.

    PYTHONPATH=src python -m benchmarks.run            # full
    BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.run   # smoke

Each module maps to one paper table/figure (DESIGN.md §7).
"""
from __future__ import annotations

import importlib
import sys
import time
import traceback
from typing import Dict, List

from .common import Claim

HARNESSES = [
    "scenario_sweep",
    "fig2_contention",
    "fig8_training",
    "fig9_inference",
    "fig10_energy",
    "fig12_mixing",
    "fig13_scheduler",
    "fig14_breakdown",
    "fig15_pareto",
    "fig16_dynamics",
    "fig_serving",
    "fig_fleet",
    "fig17_topk",
    "table4_planning_time",
    "fig_serving_scale",
    "fig_fidelity",
    "fig_chaos",
    "fig_control",
    "roofline",
]


class Report:
    def __init__(self):
        self.tables: List[str] = []
        self.claims: List[Claim] = []
        self.data: Dict[str, object] = {}

    def add_table(self, text: str) -> None:
        self.tables.append(text)
        print(text, flush=True)

    def add_claims(self, claims) -> None:
        self.claims.extend(claims)
        for c in claims:
            print(c.line(), flush=True)

    def stash(self, key: str, value) -> None:
        self.data[key] = value


def main() -> int:
    report = Report()
    failures = []
    for name in HARNESSES:
        print(f"\n##### {name} " + "#" * max(0, 60 - len(name)), flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.run(report)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"[ERROR] {name}: {type(e).__name__}: {e}")
            traceback.print_exc()
        print(f"({name}: {time.time() - t0:.1f}s)", flush=True)

    print("\n" + "=" * 72)
    print("CLAIM SUMMARY")
    print("=" * 72)
    n_pass = sum(1 for c in report.claims if c.ok)
    for c in report.claims:
        print(c.line())
    print(f"\n{n_pass}/{len(report.claims)} claims validated; "
          f"{len(failures)} harness errors {failures if failures else ''}")
    return 1 if (failures or n_pass < len(report.claims)) else 0


if __name__ == "__main__":
    sys.exit(main())
