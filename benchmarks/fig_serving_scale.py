"""Serving-kernel throughput — the tracked request-simulator benchmark.

The PR that vectorized the serving kernel (``repro.core.events``)
replaced the historical per-request Python loop with closed-form
Lindley segments; this harness is the guard that keeps it fast.  It
writes ``BENCH_serving.json`` at the repo root — the machine-readable
simulator-throughput trajectory future PRs are judged against:

* ``single_tenant`` — wall seconds / requests-per-second for a
  pre-armed ``traffic_monitor`` serve session driven at rate 6.0 with
  10^4, 10^5 and 10^6-request traces (no dynamics: pure queueing);
* ``fleet_8tenant`` — the same for an ad-hoc 8-tenant, 16-device
  shared-medium fleet splitting 10^5 requests across tenants;
* a sticky ``baseline`` section holding the numbers measured on the
  commit *before* the vectorization (the per-request loop), and the
  baseline/current speedups.

CLI::

    PYTHONPATH=src python -m benchmarks.fig_serving_scale          # full bench + rewrite JSON
    BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.fig_serving_scale --check
        # CI gate: re-run the quick subset and fail (exit 1) if it
        # regressed >BENCH_REGRESSION_FACTOR (default 1.5x) vs. the
        # committed quick numbers

``benchmarks/run.py`` executes :func:`run`, which emits the table, the
JSON artifact and the <10 s acceptance claims.
"""
from __future__ import annotations

import contextlib
import dataclasses
import gc
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence

from .common import Claim, table

from repro import dora
from repro.core.cost_model import PAPER_SERVE_WORKLOAD
from repro.core.device import CATALOG, Topology
from repro.core.qoe import QoESpec
from repro.fleet import FleetScenario
from repro.scenarios import Scenario
from repro.sim.fleet import simulate_fleet
from repro.sim.serving import ServingLoad, simulate_requests

SCENARIO = "traffic_monitor"
RATE = 6.0
SIZES = (10_000, 100_000, 1_000_000)
QUICK_SIZES = (10_000, 100_000)
FLEET_SIZES = (100_000,)
QUICK_FLEET_SIZES = (10_000,)

BENCH_PATH = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json"))
SCHEMA = "dora-bench-serving/v1"

#: Throughput of the pre-vectorization per-request loop, measured on
#: commit 15180af (the parent of the kernel refactor) on the CI-class
#: host that seeded this file: same scenario, rate, seeds and pre-armed
#: session as ``bench_single_tenant``.  Sticky — ``write_bench`` never
#: overwrites an existing baseline, and seeds this one on first write.
PRE_REFACTOR_BASELINE: Dict[str, object] = {
    "commit": "15180af",
    "note": "per-request Python loop (pre-vectorization)",
    "single_tenant": {
        "10000": {"wall_s": 0.0268, "rps": 373_000.0},
        "100000": {"wall_s": 0.310, "rps": 322_000.0},
    },
}


def _quick() -> bool:
    return bool(os.environ.get("BENCH_QUICK"))


def _commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, cwd=os.path.dirname(BENCH_PATH)).stdout.strip()
    except OSError:
        return "unknown"


@contextlib.contextmanager
def _no_gc():
    gc.collect()
    was = gc.isenabled()
    gc.disable()
    try:
        yield
    finally:
        if was:
            gc.enable()


# -- workloads -------------------------------------------------------------------
def bench_single_tenant(sizes: Sequence[int],
                        repeats: int = 3) -> Dict[str, Dict[str, float]]:
    """Best-of-``repeats`` wall seconds per trace length.

    The session is armed once outside the timed region (planning time
    is ``BENCH_planner.json``'s business); ``events=()`` isolates pure
    queueing/energy bookkeeping throughput.
    """
    session = dora.serve(SCENARIO)
    out: Dict[str, Dict[str, float]] = {}
    for n in sizes:
        best = float("inf")
        with _no_gc():
            for _ in range(repeats):
                load = ServingLoad(rate=RATE, n_requests=n, seed=0)
                t0 = time.perf_counter()
                trace = simulate_requests(SCENARIO, session=session,
                                          load=load, events=())
                best = min(best, time.perf_counter() - t0)
        assert len(trace.requests) == n
        out[str(n)] = {"wall_s": best, "rps": n / best}
    return out


def _bench_fleet_scenario() -> FleetScenario:
    """An ad-hoc 8-tenant fleet on 16 shared-medium edge devices.

    Deliberately *not* registered: registry-wide tests plan every
    registered scenario, and this one exists only to be big."""
    kinds = ("rtx4060", "rtx4050", "mi15", "genio720")

    def topo() -> Topology:
        base = [CATALOG[kinds[i % len(kinds)]] for i in range(16)]
        devs = [dataclasses.replace(d, name=f"{d.name}-{i}")
                for i, d in enumerate(base)]
        return Topology.shared_medium(devs, 900.0)

    tenants = tuple(
        Scenario(name=f"svc_{i}",
                 description=f"bench tenant {i}",
                 topology=topo,
                 model="bert" if i % 2 == 0 else "qwen3-0.6b",
                 workload=PAPER_SERVE_WORKLOAD,
                 qoe=QoESpec(t_qoe=0.5 if i % 2 else 1.0, lam=100.0),
                 tags=("serve", "tenant"),
                 request_rate=2.0 + i)
        for i in range(8))
    return FleetScenario(
        name="bench_fleet_8",
        description="8 services sharing 16 edge devices (bench only)",
        topology=topo, tenants=tenants, tags=("fleet", "serve"))


def bench_fleet(sizes: Sequence[int],
                repeats: int = 3) -> Dict[str, Dict[str, float]]:
    """Best-of-``repeats`` wall seconds for N total requests split
    evenly across the 8 tenants (co-planning is pre-armed)."""
    fleet = _bench_fleet_scenario()
    session = dora.serve_fleet(fleet)
    out: Dict[str, Dict[str, float]] = {}
    for n in sizes:
        per = n // len(fleet.tenants)
        best = float("inf")
        with _no_gc():
            for _ in range(repeats):
                loads = {t.name: ServingLoad(rate=t.request_rate,
                                             n_requests=per, seed=i)
                         for i, t in enumerate(fleet.tenants)}
                t0 = time.perf_counter()
                ftr = simulate_fleet(fleet, session=session, loads=loads,
                                     events=())
                best = min(best, time.perf_counter() - t0)
        served = sum(len(tr.requests) for tr in ftr.tenants.values())
        assert served == per * len(fleet.tenants)
        out[str(n)] = {"wall_s": best, "rps": served / best}
    return out


def bench_serving(quick: bool = False) -> Dict[str, object]:
    """The ``current`` section of ``BENCH_serving.json``."""
    repeats = int(os.environ.get("BENCH_REPEATS", "3"))
    single = bench_single_tenant(QUICK_SIZES if quick else SIZES,
                                 repeats=repeats)
    fleet = bench_fleet(QUICK_FLEET_SIZES if quick else FLEET_SIZES,
                        repeats=repeats)
    return {
        "commit": _commit(),
        "single_tenant": single,
        "fleet_8tenant": fleet,
    }


def _total(section: Dict[str, object]) -> float:
    walls = [v["wall_s"] for v in section.get("single_tenant", {}).values()]
    walls += [v["wall_s"] for v in section.get("fleet_8tenant", {}).values()]
    return sum(walls)


def write_bench(current: Dict[str, object],
                path: str = BENCH_PATH) -> Dict[str, object]:
    """Merge ``current`` with the sticky baseline and write ``path``."""
    doc: Dict[str, object] = {"schema": SCHEMA}
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    doc["schema"] = SCHEMA
    doc.setdefault("method",
                   "best-of-N wall seconds / requests-per-second, idle "
                   "machine; single_tenant = pre-armed traffic_monitor "
                   "serve session at rate 6.0, events=(); fleet_8tenant "
                   "= ad-hoc 8-tenant 16-device shared-medium fleet, "
                   "total requests split evenly across tenants")
    doc.setdefault("baseline", PRE_REFACTOR_BASELINE)
    prev = doc.get("current")
    if (isinstance(prev, dict) and prev.get("commit") == current.get("commit")
            and _total(prev) <= _total(current)):
        current = prev      # keep the best observed floor for this commit
    doc["current"] = current
    base, speed = doc["baseline"], {}
    for size, ref in base.get("single_tenant", {}).items():
        cur = current.get("single_tenant", {}).get(size)
        if cur and ref.get("wall_s"):
            speed[f"single_tenant_{size}"] = ref["wall_s"] / cur["wall_s"]
    doc["speedup_vs_baseline"] = speed
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return doc


def check_regression(path: str = BENCH_PATH) -> int:
    """CI gate: quick-mode throughput vs. the committed numbers.

    Exit 1 when the quick total wall time regresses by more than
    ``BENCH_REGRESSION_FACTOR`` (default 1.5x) against the committed
    ``quick`` section; the factor absorbs normal runner jitter."""
    factor = float(os.environ.get("BENCH_REGRESSION_FACTOR", "1.5"))
    with open(path, encoding="utf-8") as f:
        committed = json.load(f)
    ref = committed.get("quick")
    cur = bench_serving(quick=True)
    # persist this runner's measurement so the uploaded artifact carries
    # fresh numbers (the committed file itself is not rewritten by CI)
    committed["quick"] = cur
    with open(path, "w", encoding="utf-8") as f:
        json.dump(committed, f, indent=1)
        f.write("\n")
    if ref is None:
        print("no committed quick section; recorded one")
        return 0
    print(f"quick serving total: {_total(cur):.3f}s "
          f"(committed {_total(ref):.3f}s, gate {factor:.2f}x)")
    if _total(cur) > _total(ref) * factor:
        print(f"FAIL: serving throughput regressed "
              f"{_total(cur) / _total(ref):.2f}x (> {factor:.2f}x gate)")
        return 1
    print("serving benchmark regression gate: OK")
    return 0


def refresh_quick(path: str = BENCH_PATH) -> None:
    """Re-measure and rewrite only the ``quick`` section."""
    doc: Dict[str, object] = {"schema": SCHEMA}
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    doc["quick"] = bench_serving(quick=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


# -- the benchmark-harness entry point -------------------------------------------
def run(report) -> None:
    quick = _quick()
    if quick:
        refresh_quick()
        with open(BENCH_PATH, encoding="utf-8") as f:
            cur = json.load(f)["quick"]
    else:
        cur = bench_serving(quick=False)
        doc = write_bench(cur)
        cur = doc["current"]

    rows = [["single-tenant", size, f"{v['wall_s']:.3f}",
             f"{v['rps'] / 1e3:.0f}k"]
            for size, v in cur["single_tenant"].items()]
    rows += [["8-tenant fleet", size, f"{v['wall_s']:.3f}",
              f"{v['rps'] / 1e3:.0f}k"]
             for size, v in cur["fleet_8tenant"].items()]
    report.add_table(table(
        ["workload", "requests", "wall (s)", "req/s"], rows,
        "Serving-kernel throughput (BENCH_serving.json)"))

    claims = []
    if not quick:
        c1 = Claim("BENCH: a 10^6-request single-tenant trace simulates "
                   "in <10 s")
        c1.check(cur["single_tenant"]["1000000"]["wall_s"] < 10.0,
                 f"{cur['single_tenant']['1000000']['wall_s']:.2f}s")
        c2 = Claim("BENCH: a 10^5-request 8-tenant fleet trace simulates "
                   "in <10 s")
        c2.check(cur["fleet_8tenant"]["100000"]["wall_s"] < 10.0,
                 f"{cur['fleet_8tenant']['100000']['wall_s']:.2f}s")
        speed = doc["speedup_vs_baseline"]
        c3 = Claim("BENCH: 10^5-request throughput ≥3x the pre-refactor "
                   "per-request loop recorded in BENCH_serving.json")
        c3.check(speed.get("single_tenant_100000", 0.0) >= 3.0,
                 f"{speed.get('single_tenant_100000', 0.0):.1f}x")
        claims += [c1, c2, c3]
    else:
        c = Claim("BENCH(quick): a 10^5-request single-tenant trace "
                  "simulates in <10 s")
        c.check(cur["single_tenant"]["100000"]["wall_s"] < 10.0,
                f"{cur['single_tenant']['100000']['wall_s']:.2f}s")
        claims.append(c)
    report.add_claims(claims)


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--check" in argv:
        return check_regression()
    if _quick():
        refresh_quick()
        print(f"refreshed quick section of {BENCH_PATH}")
        return 0
    doc = write_bench(bench_serving(quick=False))
    print(json.dumps(doc["speedup_vs_baseline"], indent=1))
    print(f"wrote {BENCH_PATH}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
