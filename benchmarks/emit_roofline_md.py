"""Append the §Roofline markdown table (from dryrun_results.jsonl) to
EXPERIMENTS.md. Run after the dry-run:

    PYTHONPATH=src python -m benchmarks.emit_roofline_md
"""
from __future__ import annotations

import os

from .roofline import load_results, model_flops

HERE = os.path.dirname(__file__)
EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")


def build_table() -> str:
    recs = load_results()
    lines = ["", "| arch | shape | mesh | Tc (ms) | Tm (ms) | Tn (ms) | "
             "bound | useful/HLO | peak GB |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rl = r["roofline"]
        ratio = model_flops(r["arch"], r["shape"], r["devices"]) \
            / max(r["per_device_flops"], 1.0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rl['t_compute'] * 1e3:.1f} | {rl['t_memory'] * 1e3:.1f} "
            f"| {rl['t_collective'] * 1e3:.1f} | {rl['bound']} "
            f"| {ratio:.2f} | {r['memory']['peak_gb']:.1f} |")
    lines.append("")
    return "\n".join(lines)


def main() -> None:
    with open(EXP) as f:
        text = f.read()
    marker = "## §Roofline-table"
    head = text.split(marker)[0]
    intro = ("## §Roofline-table\n\n(Generated from the final "
             "`dryrun_results.jsonl`; both meshes, Tc/Tm/Tn per step.)\n")
    with open(EXP, "w") as f:
        f.write(head + intro + build_table())
    print("EXPERIMENTS.md §Roofline-table updated "
          f"({len(load_results())} rows)")


if __name__ == "__main__":
    main()
