"""Chaos serving — fallback ladder vs. naive replan-on-detect.

The resilience PR made failures *unannounced*: a crash at ``t`` is only
acted on one heartbeat detection window later, blind-window requests
time out and retry, and recovery either switches to a precomputed
QoE-ranked fallback plan (``recovery="ladder"``) or replans from
scratch on the critical path (``recovery="replan"``).  This harness
drives seeded, service-affecting fault scripts through three catalog
scenarios and one multi-tenant fleet under both recovery modes and
writes ``BENCH_chaos.json`` — the machine-readable resilience
trajectory future PRs are judged against:

* per case: SLO attainment, failed-request rate, MTTR, retry/hedge
  counts for both recovery modes, plus the ladder-vs-naive deltas;
* a ``quick`` section (same sizes — chaos runs are analytic and take
  seconds) that CI re-measures and gates.

CLI::

    PYTHONPATH=src python -m benchmarks.fig_chaos          # full + rewrite JSON
    BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.fig_chaos --check
        # CI gate: re-run the quick subset and fail (exit 1) if the
        # ladder's failed-request rate or MTTR regressed
        # >BENCH_REGRESSION_FACTOR (default 1.5x) vs. the committed
        # quick numbers, or if the ladder stops beating naive replan
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List, Optional

from .common import Claim, table

from repro import dora
from repro.resilience import Fault, FaultScript
from repro.sim.serving import ServingLoad

BENCH_PATH = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_chaos.json"))
SCHEMA = "dora-bench-chaos/v1"

#: (scenario, script seed, request rate, n_requests, slo_s) — cases
#: whose best plan spans several devices on a slow shared medium, so
#: naive sync replan-on-detect pays a real weight reload that the
#: precomputed ladder avoids.  Rates sit at ~60-70%% of plan capacity
#: and the SLO is ~3x the fault-free latency: enough headroom that the
#: fault-free tail meets SLO and the recovery stall is what decides it.
#: Scripts are crash+straggler only with guaranteed repair: link-down
#: recovery is identical under both modes and would only dilute MTTR.
CASES = (
    ("smart_home_1", 0, 0.2, 400, 10.5),
    ("smart_home_degraded", 0, 0.05, 150, 35.0),
    ("smart_home_2", 0, 0.09, 240, 22.0),
)
SCRIPT_KW = dict(n_faults=4, kinds=("crash", "straggler"), repair_p=1.0)
FLEET = "smart_home_overnight"
#: The fleet script is explicit (``for_session`` targets a single
#: tenant session): device 1 carries the middle stage of the 3-stage
#: overnight_tune pipeline, so its crash forces a genuine multi-device
#: migration — naive replan reloads the moved stage's weights over the
#: home Wi-Fi on the critical path; device 3 (the assistant's host)
#: silently slows to 50%% later.
FLEET_SCRIPT = FaultScript((Fault("crash", 8.0, 1, duration=120.0),
                            Fault("straggler", 60.0, 3, duration=25.0,
                                  factor=0.5)),
                           name=f"{FLEET}/chaos-fixed")
FLEET_LOADS = {
    "overnight_tune": ServingLoad(rate=0.05, n_requests=30, seed=0,
                                  slo_s=12.0),
    "night_assistant": ServingLoad(rate=1.0, n_requests=300, seed=1),
}
RECOVERIES = ("ladder", "replan")


def _quick() -> bool:
    return bool(os.environ.get("BENCH_QUICK"))


def _commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, cwd=os.path.dirname(BENCH_PATH)).stdout.strip()
    except OSError:
        return "unknown"


def _metrics(tr) -> Dict[str, object]:
    return {
        "slo_attainment": round(tr.slo_attainment, 6),
        "failed_rate": round(tr.failed_rate, 6),
        "mttr_s": None if tr.mttr_s is None else round(tr.mttr_s, 4),
        "retried": tr.n_retried,
        "hedged": tr.n_hedged,
        "n_faults": len(tr.faults),
    }


def _deltas(by_mode: Dict[str, Dict[str, object]]) -> Dict[str, object]:
    lad, rep = by_mode["ladder"], by_mode["replan"]
    out: Dict[str, object] = {
        "slo_gain": round(lad["slo_attainment"] - rep["slo_attainment"], 6),
        "failed_rate_gain": round(rep["failed_rate"] - lad["failed_rate"], 6),
    }
    if lad["mttr_s"] is not None and rep["mttr_s"] is not None:
        out["mttr_speedup"] = round(rep["mttr_s"] / max(lad["mttr_s"], 1e-9),
                                    4)
    return out


def bench_case(name: str, seed: int, rate: float, n_requests: int,
               slo_s: float) -> Dict[str, object]:
    session = dora.serve(name)
    script = FaultScript.for_session(session, seed=seed, **SCRIPT_KW)
    load = ServingLoad(rate=rate, n_requests=n_requests, seed=0, slo_s=slo_s)
    case: Dict[str, object] = {
        "script": script.name,
        "faults": [f.describe() for f in script.faults],
        "rate_rps": rate, "n_requests": n_requests, "slo_s": slo_s,
    }
    for rec in RECOVERIES:
        tr = dora.simulate(name, mode="requests", session=session,
                           copy=True, faults=script, recovery=rec,
                           load=load)
        case[rec] = _metrics(tr)
    case["ladder_vs_naive"] = _deltas(case)
    return case


def bench_fleet_case() -> Dict[str, object]:
    session = dora.serve_fleet(FLEET)
    case: Dict[str, object] = {
        "script": FLEET_SCRIPT.name,
        "faults": [f.describe() for f in FLEET_SCRIPT.faults],
        "n_requests_per_tenant": {n: ld.n_requests
                                  for n, ld in FLEET_LOADS.items()},
    }
    for rec in RECOVERIES:
        tr = dora.simulate(FLEET, mode="fleet", session=session, copy=True,
                           faults=FLEET_SCRIPT, recovery=rec, seed=1,
                           loads=dict(FLEET_LOADS))
        case[rec] = {
            "slo_attainment": round(tr.slo_attainment, 6),
            "failed_rate": round(
                sum(t.n_failed for t in tr.tenants.values())
                / sum(len(t.requests) for t in tr.tenants.values()), 6),
            "mttr_s": None if tr.mttr_s is None else round(tr.mttr_s, 4),
            "retried": sum(t.n_retried for t in tr.tenants.values()),
            "hedged": sum(t.n_hedged for t in tr.tenants.values()),
            "n_faults": len(tr.faults),
        }
    case["ladder_vs_naive"] = _deltas(case)
    return case


def bench_chaos(quick: bool = False) -> Dict[str, object]:
    # chaos runs are analytic and finish in seconds, so the quick (CI)
    # subset measures the exact same cases at the same sizes — the two
    # sections differ only in when they were measured
    cases = {name: bench_case(name, seed, rate, n, slo)
             for name, seed, rate, n, slo in CASES}
    cases[FLEET] = bench_fleet_case()
    return {"commit": _commit(), "quick": quick, "cases": cases}


def _ladder_wins(case: Dict[str, object]) -> bool:
    lad, rep = case["ladder"], case["replan"]
    slo_ok = lad["slo_attainment"] >= rep["slo_attainment"]
    mttr_ok = (lad["mttr_s"] is not None and rep["mttr_s"] is not None
               and lad["mttr_s"] <= rep["mttr_s"])
    return slo_ok and mttr_ok


def write_bench(current: Dict[str, object],
                path: str = BENCH_PATH) -> Dict[str, object]:
    doc: Dict[str, object] = {"schema": SCHEMA}
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    doc["schema"] = SCHEMA
    doc["method"] = (
        "seeded service-affecting fault scripts (FaultScript.for_session, "
        "crash + straggler, guaranteed repair) through pre-armed serve "
        "sessions whose best plans span multiple devices on a shared "
        "medium; both recovery modes on identical arrivals; detection "
        "via heartbeat Coordinator (1s beats, miss_limit 3); fleet case "
        f"= {FLEET} with a fixed crash+straggler script that breaks the "
        "multi-stage tenant's middle stage")
    doc["current"] = current
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return doc


def refresh_quick(path: str = BENCH_PATH) -> Dict[str, object]:
    doc: Dict[str, object] = {"schema": SCHEMA}
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    doc["quick"] = bench_chaos(quick=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return doc


def check_regression(path: str = BENCH_PATH) -> int:
    """CI gate on the ladder's failed-request rate and MTTR.

    Re-measures the quick subset and fails when either metric
    regresses more than ``BENCH_REGRESSION_FACTOR`` (default 1.5x,
    plus a small absolute slack for near-zero failed rates) against
    the committed ``quick`` section, or when the fallback ladder stops
    beating naive replan-on-detect on any case."""
    factor = float(os.environ.get("BENCH_REGRESSION_FACTOR", "1.5"))
    with open(path, encoding="utf-8") as f:
        committed = json.load(f)
    ref = committed.get("quick")
    cur = bench_chaos(quick=True)
    committed["quick"] = cur
    with open(path, "w", encoding="utf-8") as f:
        json.dump(committed, f, indent=1)
        f.write("\n")
    if ref is None:
        print("no committed quick section; recorded one")
        return 0
    bad: List[str] = []
    for name, case in cur["cases"].items():
        if not _ladder_wins(case):
            bad.append(f"{name}: ladder no longer beats naive replan "
                       f"(ladder {case['ladder']}, replan {case['replan']})")
        refc = ref.get("cases", {}).get(name)
        if refc is None:
            continue
        for metric, slack in (("failed_rate", 0.02), ("mttr_s", 0.5)):
            was, now = refc["ladder"].get(metric), case["ladder"].get(metric)
            if was is None or now is None:
                continue
            if now > was * factor + slack:
                bad.append(f"{name}: ladder {metric} regressed "
                           f"{was:.4f} -> {now:.4f} "
                           f"(gate {factor:.2f}x + {slack})")
        print(f"{name}: ladder failed_rate {case['ladder']['failed_rate']:.4f}"
              f" (committed {refc['ladder']['failed_rate']:.4f}), "
              f"mttr {case['ladder']['mttr_s']} "
              f"(committed {refc['ladder']['mttr_s']})")
    if bad:
        for line in bad:
            print(f"FAIL: {line}")
        return 1
    print("chaos benchmark regression gate: OK")
    return 0


# -- the benchmark-harness entry point -------------------------------------------
def run(report) -> None:
    quick = _quick()
    if quick:
        doc = refresh_quick()
        cur = doc["quick"]
    else:
        doc = write_bench(bench_chaos(quick=False))
        cur = doc["current"]

    rows = []
    for name, case in cur["cases"].items():
        for rec in RECOVERIES:
            m = case[rec]
            rows.append([
                name, rec, f"{m['slo_attainment']:.3f}",
                f"{m['failed_rate'] * 100:.2f}%",
                "-" if m["mttr_s"] is None else f"{m['mttr_s']:.2f}",
                str(m["retried"])])
    report.add_table(table(
        ["case", "recovery", "SLO att.", "failed", "MTTR (s)", "retried"],
        rows, "Chaos serving: fallback ladder vs naive replan "
              "(BENCH_chaos.json)"))

    wins = {name: _ladder_wins(case) for name, case in cur["cases"].items()}
    c1 = Claim("BENCH: the fallback ladder beats naive replan-on-detect "
               "on SLO attainment and MTTR on every chaos case")
    c1.check(all(wins.values()),
             ", ".join(f"{n}:{'win' if ok else 'LOSS'}"
                       for n, ok in wins.items()))
    c2 = Claim("BENCH: every chaos case measured a defined MTTR under "
               "both recovery modes")
    c2.check(all(case[rec]["mttr_s"] is not None
                 for case in cur["cases"].values() for rec in RECOVERIES),
             f"{len(cur['cases'])} cases x {len(RECOVERIES)} modes")
    report.add_claims([c1, c2])


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--check" in argv:
        return check_regression()
    if _quick():
        refresh_quick()
        print(f"refreshed quick section of {BENCH_PATH}")
        return 0
    doc = write_bench(bench_chaos(quick=False))
    for name, case in doc["current"]["cases"].items():
        print(f"{name}: {case['ladder_vs_naive']}")
    print(f"wrote {BENCH_PATH}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
