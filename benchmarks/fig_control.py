"""Control plane — preemption, battery SoC, and streamed migration.

The control-plane PR unified every dynamics reaction behind
``repro.control`` and added three mechanisms on top of the vectorized
serving kernel: stage-level priority preemption (interactive requests
jump queued batch admissions), battery state-of-charge tracking with
pre-death evacuation, and DEFER-style streamed migration (next-plan
weights ship behind the running plan's execution).  This harness
measures each mechanism against its off arm on catalog scenarios plus
one multi-tenant fleet and writes ``BENCH_control.json``:

* preemption: interactive p95 / interactive SLO / aggregate SLO under
  FIFO vs priority preemption on three catalog scenarios and the
  ``traffic_intersection`` fleet;
* battery: deaths and dead-battery QoE violations (deaths + post-death
  SLO misses) with SoC tracked but ignored vs SoC-aware evacuation;
* migration: total priced replan stall, synchronous vs streamed, on
  forced device-leave migrations;
* a ``quick`` section (same sizes — runs are analytic and take
  seconds) that CI re-measures and gates.

CLI::

    PYTHONPATH=src python -m benchmarks.fig_control        # full + rewrite JSON
    BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.fig_control --check
        # CI gate: re-run the quick subset and fail (exit 1) if any
        # mechanism stops beating its off arm, or if a headline metric
        # regressed >BENCH_REGRESSION_FACTOR (default 1.5x) vs. the
        # committed quick numbers
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional

import numpy as np

from .common import Claim, table

from repro import dora
from repro.control import ControlConfig
from repro.core.device import Topology
from repro.core.events import DynamicsEvent, interactive_batch
from repro.sim.serving import ServingLoad, simulate_requests

BENCH_PATH = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_control.json"))
SCHEMA = "dora-bench-control/v1"

#: (scenario, rate, n_requests, class seed, interactive slo_s, batch
#: slo_s, interactive share) — rates sit high enough that a FIFO queue
#: builds and batch admissions delay interactive arrivals; the
#: interactive SLO is a small multiple of the best plan's latency so
#: queueing (not service time) decides it.
PREEMPT_CASES = (
    ("hospital_ward", 6.0, 400, 3, 0.5, 10.0, 0.3),
    ("stadium_gate", 5.5, 400, 3, 0.6, 12.0, 0.3),
    ("edge_pod_v5e", 1.4, 300, 3, 2.0, 30.0, 0.3),
)
FLEET = "traffic_intersection"
#: The detector tenant carries the interactive/batch mix; the tracker
#: runs a plain single-class load on its own sub-topology, so the fleet
#: aggregate (worst tenant) shows preemption helps one tenant without
#: costing the other.
FLEET_LOADS = {
    "detector": ServingLoad(rate=5.5, n_requests=300, seed=3,
                            classes=interactive_batch(
                                0.6, 12.0, interactive_share=0.3)),
    "tracker": ServingLoad(rate=2.0, n_requests=120, seed=4),
}

#: (scenario, rate, n_requests, arrival seed) — ``battery_constrained``
#: carries generated batteries of its own; the other cases get the
#: hottest device's battery self-calibrated from a dry run so it dies
#: mid-horizon (see ``_calibrated_topology``).
BATTERY_CASES = (
    ("battery_constrained", None, None, None),
    ("hospital_ward", 5.0, 200, 2),
    ("smart_home_1", 4.0, 150, 2),
)
#: Calibrated battery capacity as a fraction of the dry run's drain on
#: the hottest device — 0.5 puts the death squarely mid-horizon.
CAP_FRAC = 0.5

#: (scenario, device leaving, leave time, rate, n_requests) — cases
#: whose best plan spans several devices on a slow shared medium, so
#: the forced migration pays a real weight reload that a streamed
#: switch can hide behind ongoing execution.  Async prefetch is
#: disabled on both arms: it would hide the reload entirely and
#: measure nothing.
MIGRATION_CASES = (
    ("smart_home_1", 1, 8.0, 4.0, 150),
    ("smart_home_2", 3, 10.0, 2.0, 80),
    ("edge_cluster", 1, 5.0, 1.0, 60),
)


def _quick() -> bool:
    return bool(os.environ.get("BENCH_QUICK"))


def _commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, cwd=os.path.dirname(BENCH_PATH)).stdout.strip()
    except OSError:
        return "unknown"


# -- preemption --------------------------------------------------------------
def _class_metrics(tr) -> Dict[str, object]:
    cm = tr.class_metrics()["interactive"]
    return {
        "interactive_p95": round(cm["p95"], 6),
        "interactive_slo": round(cm["slo_attainment"], 6),
        "aggregate_slo": round(tr.slo_attainment, 6),
    }


def bench_preempt_case(name: str, rate: float, n: int, seed: int,
                       islo: float, bslo: float,
                       share: float) -> Dict[str, object]:
    load = ServingLoad(rate=rate, n_requests=n, seed=seed,
                       classes=interactive_batch(islo, bslo,
                                                 interactive_share=share))
    session = dora.serve(name)
    case: Dict[str, object] = {
        "rate_rps": rate, "n_requests": n,
        "interactive_slo_s": islo, "batch_slo_s": bslo,
        "interactive_share": share,
    }
    for arm, pre in (("fifo", False), ("preempt", True)):
        tr = dora.simulate(name, mode="requests", session=session,
                           copy=True, load=load,
                           control=ControlConfig(preemption=pre))
        case[arm] = _class_metrics(tr)
    return case


def bench_preempt_fleet() -> Dict[str, object]:
    case: Dict[str, object] = {
        "n_requests_per_tenant": {n: ld.n_requests
                                  for n, ld in FLEET_LOADS.items()},
    }
    for arm, pre in (("fifo", False), ("preempt", True)):
        session = dora.serve_fleet(FLEET)
        tr = dora.simulate(FLEET, mode="fleet", session=session,
                           loads=dict(FLEET_LOADS),
                           control=ControlConfig(preemption=pre))
        det = tr.tenants["detector"]
        m = _class_metrics(det)
        m["aggregate_slo"] = round(tr.slo_attainment, 6)   # worst tenant
        case[arm] = m
    return case


def _preempt_wins(case: Dict[str, object]) -> bool:
    fifo, pre = case["fifo"], case["preempt"]
    return (pre["interactive_p95"] < fifo["interactive_p95"]
            and pre["interactive_slo"] >= fifo["interactive_slo"]
            and pre["aggregate_slo"] >= fifo["aggregate_slo"])


# -- battery SoC -------------------------------------------------------------
def _calibrated_topology(name: str, load: ServingLoad) -> Topology:
    """Give the dry run's hottest device a battery sized to die
    mid-horizon (capacity = CAP_FRAC x its fault-free drain)."""
    dry = simulate_requests(name, load=load)
    pe = dry.per_device_energy
    hot = max(pe, key=pe.get)
    topo = dora.serve(name).report.topology
    devs = list(topo.devices)
    devs[hot] = dataclasses.replace(devs[hot],
                                    battery_j=CAP_FRAC * pe[hot])
    return Topology(devs, list(topo.resources.values()), topo._p2p)


def _battery_metrics(tr) -> Dict[str, object]:
    deaths = [a.t for a in tr.actions
              if a.label.startswith("battery dead")]
    evacs = sum(1 for a in tr.actions
                if a.label.startswith("battery low"))
    misses = 0
    if deaths:
        arr, fin = tr.requests.arrival, tr.requests.finish
        late = arr >= min(deaths)
        misses = int(np.count_nonzero(late & ((fin - arr) > tr.slo_s)))
    return {
        "deaths": len(deaths),
        "evacuations": evacs,
        # the QoE damage the aware arm exists to avoid: every death
        # plus every SLO miss among requests arriving at/after the
        # first one
        "dead_battery_violations": len(deaths) + misses,
        "aggregate_slo": round(tr.slo_attainment, 6),
        "energy_j": round(tr.energy, 2),
    }


def bench_battery_case(name: str, rate: Optional[float], n: Optional[int],
                       seed: Optional[int]) -> Dict[str, object]:
    kw: Dict[str, object] = {}
    case: Dict[str, object] = {"batteries": "generated"}
    if rate is not None:
        load = ServingLoad(rate=rate, n_requests=n, seed=seed)
        kw = {"load": load, "topology": _calibrated_topology(name, load)}
        case = {"batteries": f"calibrated ({CAP_FRAC:g}x dry-run drain)",
                "rate_rps": rate, "n_requests": n}
    for arm, aware in (("ignore", False), ("aware", True)):
        tr = simulate_requests(
            name, control=ControlConfig(battery=True, battery_aware=aware),
            **kw)
        case[arm] = _battery_metrics(tr)
    return case


def _battery_wins(case: Dict[str, object]) -> bool:
    return (case["aware"]["dead_battery_violations"]
            < case["ignore"]["dead_battery_violations"])


# -- streamed migration ------------------------------------------------------
def bench_migration_case(name: str, dev: int, t: float, rate: float,
                         n: int) -> Dict[str, object]:
    load = ServingLoad(rate=rate, n_requests=n, seed=2)
    case: Dict[str, object] = {"leave_device": dev, "leave_t_s": t,
                               "rate_rps": rate, "n_requests": n}
    for arm, streamed in (("sync", False), ("streamed", True)):
        cc = ControlConfig(streamed_migration=True) if streamed else None
        session = dora.serve(name, control=cc)
        session.adapter.config.async_switching = False
        tr = simulate_requests(
            name, load=load, session=session,
            events=[("leave", DynamicsEvent(t=t, leave=(dev,)))])
        case[arm] = {
            "replan_stall_s": round(sum(a.stall_s for a in tr.actions
                                        if a.action == "replan"), 6),
            "aggregate_slo": round(tr.slo_attainment, 6),
        }
    return case


def _migration_wins(case: Dict[str, object]) -> bool:
    return (case["streamed"]["replan_stall_s"]
            < case["sync"]["replan_stall_s"])


# -- assembly ----------------------------------------------------------------
def bench_control(quick: bool = False) -> Dict[str, object]:
    # control runs are analytic and finish in seconds, so the quick
    # (CI) subset measures the exact same cases at the same sizes —
    # the two sections differ only in when they were measured
    preempt = {name: bench_preempt_case(name, *rest)
               for name, *rest in PREEMPT_CASES}
    preempt[FLEET] = bench_preempt_fleet()
    return {
        "commit": _commit(), "quick": quick,
        "preemption": preempt,
        "battery": {name: bench_battery_case(name, *rest)
                    for name, *rest in BATTERY_CASES},
        "migration": {name: bench_migration_case(name, *rest)
                      for name, *rest in MIGRATION_CASES},
    }


def write_bench(current: Dict[str, object],
                path: str = BENCH_PATH) -> Dict[str, object]:
    doc: Dict[str, object] = {"schema": SCHEMA}
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    doc["schema"] = SCHEMA
    doc["method"] = (
        "three mechanism-vs-off-arm comparisons on identical arrivals: "
        "priority preemption (interactive_batch class mix, FIFO vs "
        f"ControlConfig(preemption=True), incl. the {FLEET} fleet), "
        "battery SoC (generated or dry-run-calibrated batteries, SoC "
        "tracked-but-ignored vs battery_aware evacuation; violations = "
        "deaths + post-death SLO misses), and streamed migration "
        "(forced device-leave, synchronous vs DEFER-style streamed "
        "switch pricing, async prefetch off on both arms)")
    doc["current"] = current
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return doc


def refresh_quick(path: str = BENCH_PATH) -> Dict[str, object]:
    doc: Dict[str, object] = {"schema": SCHEMA}
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    doc["quick"] = bench_control(quick=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return doc


def check_regression(path: str = BENCH_PATH) -> int:
    """CI gate on the three control-plane claims.

    Re-measures the quick subset and fails when any mechanism stops
    beating its off arm, or when a headline metric (interactive p95
    under preemption, aware-arm violations, streamed stall) regresses
    more than ``BENCH_REGRESSION_FACTOR`` (default 1.5x, plus a small
    absolute slack) against the committed ``quick`` section."""
    factor = float(os.environ.get("BENCH_REGRESSION_FACTOR", "1.5"))
    with open(path, encoding="utf-8") as f:
        committed = json.load(f)
    ref = committed.get("quick")
    cur = bench_control(quick=True)
    committed["quick"] = cur
    with open(path, "w", encoding="utf-8") as f:
        json.dump(committed, f, indent=1)
        f.write("\n")
    if ref is None:
        print("no committed quick section; recorded one")
        return 0
    bad: List[str] = []
    gates = (
        ("preemption", _preempt_wins, "preempt", "interactive_p95", 0.05,
         "preemption no longer improves interactive QoE without hurting "
         "aggregate attainment"),
        ("battery", _battery_wins, "aware", "dead_battery_violations", 1.0,
         "SoC-aware evacuation no longer reduces dead-battery "
         "violations"),
        ("migration", _migration_wins, "streamed", "replan_stall_s", 0.1,
         "streamed migration no longer reduces the priced switch "
         "stall"),
    )
    for group, wins, arm, metric, slack, msg in gates:
        for name, case in cur[group].items():
            if not wins(case):
                bad.append(f"{group}/{name}: {msg} ({case})")
            refc = ref.get(group, {}).get(name)
            if refc is None:
                continue
            was, now = refc[arm].get(metric), case[arm].get(metric)
            if was is not None and now is not None \
                    and now > was * factor + slack:
                bad.append(f"{group}/{name}: {arm} {metric} regressed "
                           f"{was:.4f} -> {now:.4f} "
                           f"(gate {factor:.2f}x + {slack})")
            print(f"{group}/{name}: {arm} {metric} = {now} "
                  f"(committed {was})")
    if bad:
        for line in bad:
            print(f"FAIL: {line}")
        return 1
    print("control benchmark regression gate: OK")
    return 0


# -- the benchmark-harness entry point -------------------------------------------
def run(report) -> None:
    quick = _quick()
    if quick:
        doc = refresh_quick()
        cur = doc["quick"]
    else:
        doc = write_bench(bench_control(quick=False))
        cur = doc["current"]

    rows = []
    for name, case in cur["preemption"].items():
        for arm in ("fifo", "preempt"):
            m = case[arm]
            rows.append([name, arm, f"{m['interactive_p95']:.3f}",
                         f"{m['interactive_slo']:.3f}",
                         f"{m['aggregate_slo']:.3f}"])
    report.add_table(table(
        ["case", "arm", "inter. p95 (s)", "inter. SLO", "agg. SLO"],
        rows, "Priority preemption vs FIFO (BENCH_control.json)"))

    rows = []
    for name, case in cur["battery"].items():
        for arm in ("ignore", "aware"):
            m = case[arm]
            rows.append([name, arm, str(m["deaths"]),
                         str(m["evacuations"]),
                         str(m["dead_battery_violations"]),
                         f"{m['aggregate_slo']:.3f}"])
    report.add_table(table(
        ["case", "arm", "deaths", "evac.", "violations", "agg. SLO"],
        rows, "Battery SoC: tracked-but-ignored vs aware evacuation"))

    rows = []
    for name, case in cur["migration"].items():
        for arm in ("sync", "streamed"):
            m = case[arm]
            rows.append([name, arm, f"{m['replan_stall_s']:.3f}",
                         f"{m['aggregate_slo']:.3f}"])
    report.add_table(table(
        ["case", "arm", "replan stall (s)", "agg. SLO"],
        rows, "Migration: synchronous vs streamed switch"))

    checks = (
        ("BENCH: priority preemption improves interactive p95 and SLO "
         "without dropping aggregate attainment below FIFO on every "
         "case", "preemption", _preempt_wins),
        ("BENCH: SoC-aware evacuation strictly reduces dead-battery "
         "QoE violations on every battery case", "battery",
         _battery_wins),
        ("BENCH: streamed migration strictly reduces the priced switch "
         "stall on every migration case", "migration", _migration_wins),
    )
    claims = []
    for text, group, wins in checks:
        ok = {name: wins(case) for name, case in cur[group].items()}
        c = Claim(text)
        c.check(all(ok.values()),
                ", ".join(f"{n}:{'win' if w else 'LOSS'}"
                          for n, w in ok.items()))
        claims.append(c)
    report.add_claims(claims)


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--check" in argv:
        return check_regression()
    if _quick():
        refresh_quick()
        print(f"refreshed quick section of {BENCH_PATH}")
        return 0
    doc = write_bench(bench_control(quick=False))
    for group in ("preemption", "battery", "migration"):
        for name, case in doc["current"][group].items():
            arms = [k for k in case
                    if isinstance(case[k], dict)
                    and k not in ("n_requests_per_tenant",)]
            print(f"{group}/{name}: "
                  + "; ".join(f"{a}={case[a]}" for a in arms))
    print(f"wrote {BENCH_PATH}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
