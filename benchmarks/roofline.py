"""§Roofline — per (arch × shape × mesh) roofline terms from the
compiled dry-run artifacts (dryrun_results.jsonl).

    compute    = HLO_FLOPs / (chip peak)
    memory     = HLO bytes / (chip HBM bw)
    collective = collective bytes / (chip ICI bw)

plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the useful-
compute ratio MODEL_FLOPS / HLO_FLOPs (remat/redundancy check).
Run ``python -m repro.launch.dryrun --out dryrun_results.jsonl`` first.
"""
from __future__ import annotations

import json
import os

from .common import Claim, table

from repro.configs import SHAPES, get_config

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.jsonl")


def model_flops(arch: str, shape_name: str, devices: int) -> float:
    """Per-device useful FLOPs: 6·N·D training, 2·N·D forward-only."""
    cfg = get_config(arch.replace("-", "_"))
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:                      # decode: one token per sequence
        tokens = shape.global_batch
        mult = 2.0
    return mult * n * tokens / devices


def load_results(path: str = RESULTS):
    if not os.path.exists(path):
        return []
    rows = []
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            if "error" not in rec:
                rows.append(rec)
    return rows


def run(report) -> None:
    recs = load_results()
    if not recs:
        report.add_table("\n== §Roofline ==\n(no dryrun_results.jsonl — run "
                         "the dry-run first)")
        report.add_claims([])
        return
    rows = []
    ratios = []
    for r in recs:
        if r["mesh"] != "16x16":
            continue           # roofline table is single-pod per the brief
        rl = r["roofline"]
        mf = model_flops(r["arch"], r["shape"], r["devices"])
        ratio = mf / max(r["per_device_flops"], 1.0)
        ratios.append((r["arch"], r["shape"], ratio))
        dom = rl["bound"]
        total = rl["t_compute"] + rl["t_memory"] + rl["t_collective"]
        frac = rl[f"t_{'collective' if dom == 'collective' else dom}"] / total
        rows.append([r["arch"], r["shape"],
                     f"{rl['t_compute'] * 1e3:.1f}",
                     f"{rl['t_memory'] * 1e3:.1f}",
                     f"{rl['t_collective'] * 1e3:.1f}",
                     dom, f"{ratio:.2f}",
                     f"{r['memory']['peak_gb']:.1f}"])
    report.add_table(table(
        ["arch", "shape", "Tc (ms)", "Tm (ms)", "Tn (ms)", "bound",
         "useful/HLO", "peak GB"], rows,
        "§Roofline — single-pod (16×16) terms per cell"))

    c1 = Claim("Roofline: every assigned (arch × shape) cell compiled on "
               "both meshes")
    n_multi = sum(1 for r in recs if r["mesh"] == "2x16x16")
    n_single = sum(1 for r in recs if r["mesh"] == "16x16")
    c1.check(n_single == 33 and n_multi == 33,
             f"{n_single} single-pod + {n_multi} multi-pod cells")
    c2 = Claim("Roofline: multi-pod train cells fit 16 GB HBM/chip "
               "(documented exceptions: deepseek-236B needs ≥1024 chips; "
               "recurrentgemma-9b is 13% over — fits with bf16 optimizer "
               "state or 4 pods; EXPERIMENTS.md §Perf)")
    peaks = {r["arch"]: r["memory"]["peak_gb"] for r in recs
             if r["shape"] == "train_4k" and r["mesh"] == "2x16x16"}
    exceptions = {"deepseek_v2_236b", "recurrentgemma_9b"}
    rest = {a: p for a, p in peaks.items() if a not in exceptions}
    c2.check(all(p <= 16.0 for p in rest.values()),
             f"max(rest) {max(rest.values()):.1f} GB; "
             + ", ".join(f"{a} {peaks.get(a, 0):.1f} GB" for a in exceptions))
    c3 = Claim("Roofline: useful/HLO FLOP ratio ≥ 0.2 on dense train cells "
               "(remat ≤ ~1 extra fwd + attention/vocab overhead)")
    dense = {"qwen3_32b", "granite_20b", "granite_8b", "h2o_danube_1_8b",
             "mamba2_780m", "recurrentgemma_9b", "paligemma_3b"}
    train_ratios = [x for a, s, x in ratios
                    if s == "train_4k" and a.replace("-", "_") in dense]
    c3.check(min(train_ratios) >= 0.2,
             f"min {min(train_ratios):.2f}, max {max(train_ratios):.2f}")
    report.add_claims([c1, c2, c3])
    report.stash("roofline", recs)
