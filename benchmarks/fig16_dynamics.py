"""Fig. 16 — reacting to runtime dynamics (serving).

Qwen-1.7B inference in Smart Home 2; interference arrives in two waves
(network download, then compute-heavy video watching on device 0).
Compare: static Asteroid plan, Dora (adapter), and an oracle that
switches to the per-condition optimum instantly at zero cost.
"""
from __future__ import annotations

from .common import QUICK, Claim, table

from repro.core.adapter import DynamicsEvent, RuntimeAdapter
from repro.core.qoe import QoESpec
from repro.core.scheduler import NetworkScheduler
from repro.sim.runner import dora_plan, scenario_case
from repro.strategies import get_strategy

LAT = QoESpec(t_qoe=0.0, lam=1e15)
MODEL = "qwen3-0.6b" if QUICK else "qwen3-1.7b"

PHASES = [
    ("baseline", DynamicsEvent(t=0.0)),
    ("download (bw −60%)", DynamicsEvent(t=10.0,
                                         bandwidth_scale={"wifi": 0.4})),
    ("watch video (dev0 −50%, bw −30%)",
     DynamicsEvent(t=20.0, compute_speed={0: 0.5},
                   bandwidth_scale={"wifi": 0.7})),
]


def run(report) -> None:
    topo, graph, wl = scenario_case("smart_home_2", model=MODEL,
                                    mode="infer")
    sched = NetworkScheduler(topo, LAT)

    ast = get_strategy("asteroid").plan(graph, topo, LAT, wl).best
    res = dora_plan(graph, topo, LAT, wl)
    adapter = RuntimeAdapter(res.candidates, topo, LAT, sched)
    current = res.best

    rows, ratios, react_times = [], [], []
    for name, ev in PHASES:
        speed = dict(ev.compute_speed)
        bw = dict(ev.bandwidth_scale)
        ast_lat = sched.evaluate_fair(ast, compute_speed=speed,
                                      bandwidth_scale=bw).latency
        if ev.t == 0.0:
            dora_lat = current.latency
            react = 0.0
        else:
            current, action, react = adapter.on_dynamics(
                current, ev, replan_fn=lambda: list(res.candidates))
            dora_lat = current.latency
        # oracle: best candidate under the new conditions, zero overhead
        oracle = min(sched.refine(p, compute_speed=speed,
                                  bandwidth_scale=bw).latency
                     for p in res.candidates)
        ratios.append(dora_lat / oracle)
        react_times.append(react)
        rows.append([name, f"{ast_lat * 1e3:.1f}", f"{dora_lat * 1e3:.1f}",
                     f"{oracle * 1e3:.1f}", f"{react * 1e3:.0f}"])
    report.add_table(table(
        ["phase", "Asteroid (ms)", "Dora (ms)", "oracle (ms)",
         "Dora react (ms)"], rows, "Fig. 16 — serving under dynamics"))

    c1 = Claim("Fig16: Dora tracks the zero-cost oracle within 10%")
    c1.check(max(ratios) <= 1.10,
             f"worst dora/oracle {max(ratios):.3f}")
    c2 = Claim("Fig16: Dora reacts within seconds (paper: subsecond "
               "network-only rescheduling)")
    c2.check(max(react_times) < 5.0, f"max react {max(react_times):.2f}s")
    report.add_claims([c1, c2])


if __name__ == "__main__":
    import sys

    from .run import Report
    r = Report()
    run(r)
    sys.exit(0 if all(c.ok for c in r.claims) else 1)
