"""Fig. 12 — long-horizon multi-plan orchestration.

A 6000-iteration tuning job in Smart Home 2 under deadlines from loose
to tight: the Runtime Adapter's plan *mixture* vs the best single plan
meeting each deadline. Paper: up to 31.8% lower energy.
"""
from __future__ import annotations

import math

from .common import Claim, table

from repro.core.qoe import QoESpec
from repro.sim.runner import dora_plan, scenario_case
from repro.core.adapter import RuntimeAdapter
from repro.core.scheduler import NetworkScheduler

ITERS = 6000.0


def run(report) -> None:
    topo, graph, wl = scenario_case("smart_home_2")
    qoe = QoESpec(t_qoe=math.inf, lam=1.0)
    res = dora_plan(graph, topo, qoe, wl, top_k=10)
    plans = res.pareto
    sched = NetworkScheduler(topo, qoe)

    # deadlines BETWEEN adjacent solo-completion times: feasible for the
    # faster plan, infeasible for the slower one — the regime where a
    # single plan must over-deliver but a mixture harvests the cheaper
    # plan for part of the horizon (the paper's 6.7 h case)
    solo = sorted({ITERS * p.latency for p in plans})
    deadlines = sorted({a + (b - a) * f
                        for a, b in zip(solo[:-1], solo[1:])
                        for f in (0.5, 0.9)})
    if not deadlines:
        deadlines = [solo[0] * 1.2]
    rows, gains = [], []
    for dl in deadlines:
        # best single plan that makes the deadline = min energy among feasible
        feasible = [p for p in plans if ITERS * p.latency <= dl]
        single = min(feasible, key=lambda p: p.energy) if feasible else None
        single_e = ITERS * single.energy if single else float("inf")

        adapter = RuntimeAdapter(plans, topo, qoe, sched)
        out = adapter.run_interruptible(ITERS, dl, horizon=dl / 60.0)
        mix_e = out["energy"]
        gain = 1.0 - mix_e / single_e if single else 0.0
        gains.append(gain)
        rows.append([f"{dl / 3600:.2f}", f"{single_e:.0f}" if single else "—",
                     f"{mix_e:.0f}", f"{gain:+.1%}",
                     "yes" if out["met_deadline"] else "NO"])
    report.add_table(table(
        ["deadline (h)", "best single (J)", "Dora mixture (J)", "gain",
         "deadline met"], rows,
        "Fig. 12 — 6000-iteration job, energy vs deadline"))

    c = Claim("Fig12: plan mixing beats the best single plan under at least "
              "one deadline regime (paper: up to 31.8%)")
    c.check(max(gains) > 0.02, f"best gain {max(gains):+.1%}")
    report.add_claims([c])
