"""Table 4 — planning time — and the tracked planner-latency benchmark.

Two jobs:

1. The paper's Table 4: Metis-like, Asteroid-like and Dora planning
   times across models × settings (Dora plans in seconds end-to-end,
   Phase-1 subsecond).
2. ``BENCH_planner.json`` at the repo root — the machine-readable
   planner-latency trajectory future PRs are judged against:

   * ``catalog`` — benchmark-grade planning (``sim.runner.dora_plan``:
     top_k=10 + microbatch sweep, the search every figure harness uses)
     for every registered scenario, best-of-N wall/phase1/phase2;
   * ``catalog_default`` — the same sweep with ``dora.plan`` defaults;
   * ``churn_replan`` — reaction seconds of a ``ServeSession`` device
     ``leave`` churn event, cold (fresh DP, ``warm_replan=False``) vs.
     warm (``DoraPlanner.replan`` over the surviving candidate pool);
   * a ``baseline`` section holding the same measurements from the
     commit *before* the current optimization PR, and the
     baseline/current speedups.

   CLI::

       PYTHONPATH=src python -m benchmarks.table4_planning_time            # full bench + rewrite JSON
       BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.table4_planning_time --check
           # CI gate: re-run the quick subset and fail (exit 1) if it
           # regressed >BENCH_REGRESSION_FACTOR (default 1.5x) vs. the
           # committed quick numbers

``benchmarks/run.py`` executes :func:`run`, which emits the table, the
JSON artifact and the speedup claims.
"""
from __future__ import annotations

import contextlib
import gc
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence

from .common import Claim, table

from repro import dora
from repro.core.qoe import QoESpec
from repro.scenarios import list_scenarios
from repro.sim.runner import dora_plan, scenario_case
from repro.strategies import get_strategy

LAT = QoESpec(t_qoe=0.0, lam=1e15)
MODELS = ["bert", "qwen3-1.7b", "qwen-omni"]
SETTINGS = ["smart_home_2", "traffic_monitor"]

#: Scenarios with a device-``leave`` churn event in their registered
#: timeline (the churn-replan benchmark input).
CHURN_SCENARIOS = ("smart_home_2", "traffic_monitor")
QUICK_SCENARIOS = ("smart_home_2", "traffic_monitor", "vehicle_platoon")

BENCH_PATH = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_planner.json"))
SCHEMA = "dora-bench-planner/v1"


def _quick() -> bool:
    return bool(os.environ.get("BENCH_QUICK"))


def _commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, cwd=os.path.dirname(BENCH_PATH)).stdout.strip()
    except OSError:
        return "unknown"


# -- measurements ----------------------------------------------------------------
@contextlib.contextmanager
def _no_gc():
    """Collect once, then keep the collector out of the timed region —
    inside ``benchmarks.run`` the earlier harnesses leave a large live
    heap and generational GC otherwise taxes the planner's allocation-
    heavy DP loops."""
    gc.collect()
    was = gc.isenabled()
    gc.disable()
    try:
        yield
    finally:
        if was:
            gc.enable()


def bench_catalog(scenarios: Sequence[str], repeats: int = 3,
                  grade: str = "table4") -> Dict[str, Dict[str, float]]:
    """Best-of-``repeats`` planning seconds per scenario (GC paused).

    ``grade="table4"`` uses the benchmark-grade search (top_k=10 +
    microbatch sweep); ``grade="default"`` uses ``dora.plan`` defaults.
    """
    out: Dict[str, Dict[str, float]] = {}
    for name in scenarios:
        sc = dora.get_scenario(name)
        best: Optional[Dict[str, float]] = None
        with _no_gc():
            for _ in range(repeats):
                if grade == "table4":
                    topo, graph = sc.build_topology(), sc.build_graph()
                    t0 = time.perf_counter()
                    res = dora_plan(graph, topo, sc.qoe, sc.workload)
                    wall = time.perf_counter() - t0
                    p1, p2 = res.phase1_s, res.phase2_s
                else:
                    t0 = time.perf_counter()
                    rep = dora.plan(name)
                    wall = time.perf_counter() - t0
                    p1, p2 = rep.result.phase1_s, rep.result.phase2_s
                if best is None or wall < best["wall_s"]:
                    best = {"wall_s": wall, "phase1_s": p1, "phase2_s": p2}
        out[name] = best
    return out


def bench_churn(scenarios: Sequence[str] = CHURN_SCENARIOS,
                repeats: int = 3) -> Dict[str, Dict[str, float]]:
    """Best-of-``repeats`` churn-replan reaction seconds, cold vs warm.

    Each trial serves the scenario fresh and fires the first registered
    device-``leave`` event; ``cold_s`` forces the fresh-DP path
    (``warm_replan=False``), ``warm_s`` uses ``DoraPlanner.replan``.
    """
    out: Dict[str, Dict[str, float]] = {}
    for name in scenarios:
        row: Dict[str, float] = {}
        for label, warm in (("cold_s", False), ("warm_s", True)):
            best = float("inf")
            with _no_gc():
                for _ in range(repeats):
                    session = dora.serve(name, warm_replan=warm)
                    ev = next(e for _, e in session.report.scenario.timeline
                              if e.leave)
                    _, act, react = session.on_dynamics(ev)
                    assert act == "replan", act
                    best = min(best, react)
            row[label] = best
        out[name] = row
    return out


def _series(catalog: Dict[str, Dict[str, float]]) -> float:
    return sum(v["wall_s"] for v in catalog.values())


def bench_planner(quick: bool = False) -> Dict[str, object]:
    """The ``current`` section of ``BENCH_planner.json``.

    ``BENCH_REPEATS`` (default 3) sets the best-of-N trial count — raise
    it on noisy machines so the minimum approaches the true floor."""
    repeats = int(os.environ.get("BENCH_REPEATS", "3"))
    scenarios = QUICK_SCENARIOS if quick else list_scenarios()
    catalog = bench_catalog(scenarios, repeats=repeats, grade="table4")
    churn = bench_churn(CHURN_SCENARIOS if not quick
                        else ("traffic_monitor",),
                        repeats=repeats)
    doc: Dict[str, object] = {
        "commit": _commit(),
        "catalog": catalog,
        "catalog_total_s": _series(catalog),
        "churn_replan_s": churn,
        "churn_cold_total_s": sum(v["cold_s"] for v in churn.values()),
        "churn_warm_total_s": sum(v["warm_s"] for v in churn.values()),
    }
    if not quick:
        default = bench_catalog(scenarios, repeats=repeats, grade="default")
        doc["catalog_default"] = default
        doc["catalog_default_total_s"] = _series(default)
    return doc


def write_bench(current: Dict[str, object],
                path: str = BENCH_PATH) -> Dict[str, object]:
    """Merge ``current`` with the committed baseline and write ``path``.

    The ``baseline`` section is sticky: it records the pre-optimization
    measurements and is only seeded (from the current numbers) when the
    file doesn't exist yet.
    """
    doc: Dict[str, object] = {"schema": SCHEMA}
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    doc["schema"] = SCHEMA
    doc.setdefault("method",
                   "best-of-N wall seconds, idle machine; catalog = "
                   "benchmark-grade search (top_k=10 + microbatch sweep) "
                   "over every registered scenario; churn = ServeSession "
                   "device-leave replan reaction seconds")
    doc.setdefault("baseline", current)
    prev = doc.get("current")
    if (isinstance(prev, dict) and prev.get("commit") == current.get("commit")
            and prev.get("catalog_total_s", float("inf"))
            <= current.get("catalog_total_s", float("inf"))):
        current = prev      # keep the best observed floor for this commit
    doc["current"] = current
    base = doc["baseline"]
    speed: Dict[str, float] = {}
    if base.get("catalog_total_s") and current.get("catalog_total_s"):
        speed["catalog"] = base["catalog_total_s"] / current["catalog_total_s"]
    if base.get("catalog_default_total_s") \
            and current.get("catalog_default_total_s"):
        speed["catalog_default"] = (base["catalog_default_total_s"]
                                    / current["catalog_default_total_s"])
    if base.get("churn_cold_total_s") and current.get("churn_warm_total_s"):
        speed["churn_replan"] = (base["churn_cold_total_s"]
                                 / current["churn_warm_total_s"])
    doc["speedup_vs_baseline"] = speed
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return doc


def check_regression(path: str = BENCH_PATH) -> int:
    """CI gate: quick-mode planning time vs. the committed numbers.

    Exit 1 when the quick catalog total regresses by more than
    ``BENCH_REGRESSION_FACTOR`` (default 1.5x) against the committed
    ``quick`` section. Requires comparable runner hardware — the factor
    absorbs normal CI jitter.
    """
    factor = float(os.environ.get("BENCH_REGRESSION_FACTOR", "1.5"))
    with open(path, encoding="utf-8") as f:
        committed = json.load(f)
    ref = committed.get("quick")
    cur = bench_planner(quick=True)
    # persist this runner's measurement so the uploaded artifact carries
    # fresh numbers (the committed file itself is not rewritten by CI)
    committed["quick"] = cur
    with open(path, "w", encoding="utf-8") as f:
        json.dump(committed, f, indent=1)
        f.write("\n")
    print(f"quick catalog total: {cur['catalog_total_s']:.3f}s "
          f"(committed {ref['catalog_total_s']:.3f}s, "
          f"gate {factor:.2f}x)" if ref else "no committed quick section")
    if ref is None:
        return 0
    if cur["catalog_total_s"] > ref["catalog_total_s"] * factor:
        print(f"FAIL: quick-mode planning regressed "
              f"{cur['catalog_total_s'] / ref['catalog_total_s']:.2f}x "
              f"(> {factor:.2f}x gate)")
        return 1
    print("planner benchmark regression gate: OK")
    return 0


def refresh_quick(path: str = BENCH_PATH) -> None:
    """Re-measure and rewrite only the ``quick`` section."""
    doc: Dict[str, object] = {"schema": SCHEMA}
    if os.path.exists(path):
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    doc["quick"] = bench_planner(quick=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


# -- the benchmark-harness entry point -------------------------------------------
def run(report) -> None:
    quick = _quick()
    rows = []
    phase1_times, e2e_times = [], []
    models = MODELS[:1] if quick else MODELS
    for model in models:
        for setting in SETTINGS:
            topo, graph, wl = scenario_case(setting, model=model,
                                            mode="train")
            # phase1_s = pure planning time (fair execution excluded)
            t_metis = get_strategy("metis").plan(graph, topo, LAT, wl).phase1_s
            t_ast = get_strategy("asteroid").plan(graph, topo, LAT,
                                                  wl).phase1_s
            res = dora_plan(graph, topo, LAT, wl)
            phase1_times.append(res.phase1_s)
            e2e_times.append(res.total_s)
            rows.append([model, setting, f"{t_metis:.2f}", f"{t_ast:.2f}",
                         f"{res.phase1_s:.2f}", f"{res.total_s:.2f}"])
    report.add_table(table(
        ["model", "setting", "Metis (s)", "Asteroid (s)", "Dora Ph-1 (s)",
         "Dora e2e (s)"], rows, "Table 4 — planning time"))

    c1 = Claim("Table4: Dora Phase-1 partitioning completes in ≤3 s on this "
               "single shared CPU core (paper: subsecond on their HW)")
    c1.check(max(phase1_times) <= 3.0, f"max {max(phase1_times):.2f}s")
    c2 = Claim("Table4: end-to-end planning stays seconds-scale (≤30 s)")
    c2.check(max(e2e_times) <= 30.0, f"max {max(e2e_times):.2f}s")
    claims = [c1, c2]

    if quick:
        # CI: only refresh the quick section; the committed full numbers
        # (and their machine-specific baseline) stay untouched
        refresh_quick()
        report.add_claims(claims)
        return

    doc = write_bench(bench_planner(quick=False))
    speed = doc["speedup_vs_baseline"]
    report.add_table(table(
        ["series", "baseline (s)", "current (s)", "speedup"],
        [["catalog (bench-grade)",
          f"{doc['baseline']['catalog_total_s']:.2f}",
          f"{doc['current']['catalog_total_s']:.2f}",
          f"{speed.get('catalog', float('nan')):.1f}x"],
         ["churn replan (cold→warm)",
          f"{doc['baseline']['churn_cold_total_s'] * 1e3:.1f}ms",
          f"{doc['current']['churn_warm_total_s'] * 1e3:.1f}ms",
          f"{speed.get('churn_replan', float('nan')):.1f}x"]],
        "Planner-latency trajectory (BENCH_planner.json)"))
    c3 = Claim("BENCH: catalog-wide planning ≥5x faster than the pre-PR "
               "baseline recorded in BENCH_planner.json")
    c3.check(speed.get("catalog", 0.0) >= 5.0,
             f"{speed.get('catalog', 0.0):.1f}x")
    c4 = Claim("BENCH: warm-start churn replanning ≥10x faster than the "
               "pre-PR cold replan baseline")
    c4.check(speed.get("churn_replan", 0.0) >= 10.0,
             f"{speed.get('churn_replan', 0.0):.1f}x")
    claims += [c3, c4]
    report.add_claims(claims)


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--check" in argv:
        return check_regression()
    if _quick():
        refresh_quick()
        print(f"refreshed quick section of {BENCH_PATH}")
        return 0
    doc = write_bench(bench_planner(quick=False))
    print(json.dumps(doc["speedup_vs_baseline"], indent=1))
    print(f"wrote {BENCH_PATH}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
