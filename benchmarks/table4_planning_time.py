"""Table 4 — planning time (seconds): Metis-like, Asteroid-like, Dora
on Smart Home 2 and Traffic Monitor. Paper: Dora plans faster and stays
in seconds end-to-end; the Phase-1 partitioner is subsecond.
"""
from __future__ import annotations

from .common import Claim, table

from repro.core.qoe import QoESpec
from repro.sim.runner import dora_plan, scenario_case
from repro.strategies import get_strategy

LAT = QoESpec(t_qoe=0.0, lam=1e15)
MODELS = ["bert", "qwen3-1.7b", "qwen-omni"]
SETTINGS = ["smart_home_2", "traffic_monitor"]


def run(report) -> None:
    rows = []
    phase1_times, e2e_times = [], []
    for model in MODELS:
        for setting in SETTINGS:
            topo, graph, wl = scenario_case(setting, model=model,
                                            mode="train")
            # phase1_s = pure planning time (fair execution excluded)
            t_metis = get_strategy("metis").plan(graph, topo, LAT, wl).phase1_s
            t_ast = get_strategy("asteroid").plan(graph, topo, LAT,
                                                  wl).phase1_s
            res = dora_plan(graph, topo, LAT, wl)
            phase1_times.append(res.phase1_s)
            e2e_times.append(res.total_s)
            rows.append([model, setting, f"{t_metis:.2f}", f"{t_ast:.2f}",
                         f"{res.phase1_s:.2f}", f"{res.total_s:.2f}"])
    report.add_table(table(
        ["model", "setting", "Metis (s)", "Asteroid (s)", "Dora Ph-1 (s)",
         "Dora e2e (s)"], rows, "Table 4 — planning time"))

    c1 = Claim("Table4: Dora Phase-1 partitioning completes in ≤3 s on this "
               "single shared CPU core (paper: subsecond on their HW)")
    c1.check(max(phase1_times) <= 3.0, f"max {max(phase1_times):.2f}s")
    c2 = Claim("Table4: end-to-end planning stays seconds-scale (≤30 s)")
    c2.check(max(e2e_times) <= 30.0, f"max {max(e2e_times):.2f}s")
    report.add_claims([c1, c2])
