"""Request-level serving under churn — tail latency and SLO attainment.

The paper's Fig. 16 measures per-iteration latency as conditions shift;
a deployment is judged on what *requests* experience. This harness runs
the request-level serving simulator on the smart-home scenario's
default dynamics timeline (WiFi saturation, a phone leaving and
rejoining the fleet) and compares Dora's runtime adapter against
contention-oblivious static baselines on p99 latency, SLO attainment
and energy.  The static planners spread layers across the full fleet,
so the churn window fails their requests outright; Dora replans onto
the surviving devices and keeps serving.
"""
from __future__ import annotations

from .common import QUICK, Claim, table

from repro import dora
from repro.sim.serving import ServingLoad, simulate_requests

SCENARIO = "smart_home_2"
#: Dora vs two contention-oblivious static strategies (chain_split is
#: DistrEdge-style speed-balanced chaining; edgeshard an even chain).
STRATEGIES = ("dora", "chain_split", "edgeshard")
OBLIVIOUS = tuple(s for s in STRATEGIES if s != "dora")

# The scenario's registered rate; enough requests that the run spans
# the whole default timeline (churn window ends at t=1200 s).
LOAD = ServingLoad(rate=0.04, n_requests=20 if QUICK else 80, seed=0)


def run(report) -> None:
    traces = {}
    rows = []
    for name in STRATEGIES:
        tr = simulate_requests(SCENARIO, strategy=name, load=LOAD)
        traces[name] = tr

        def fmt(x):
            return f"{x:.2f}" if x == x and x != float("inf") else "unserved"
        rows.append([name, fmt(tr.p50), fmt(tr.p99),
                     f"{tr.slo_attainment:.1%}", tr.n_failed,
                     f"{tr.energy / 1e3:.1f}", tr.replans])
    report.add_table(table(
        ["strategy", "p50 (s)", "p99 (s)", "SLO att.", "failed",
         "energy (kJ)", "replans"], rows,
        f"Serving under churn — {SCENARIO}, {LOAD.n_requests} requests @ "
        f"{LOAD.rate:g}/s, default timeline"))

    dora_tr = traces["dora"]
    c1 = Claim("Serving: dora's SLO attainment under churn beats a "
               "contention-oblivious static baseline")
    best_obl = max(traces[s].slo_attainment for s in OBLIVIOUS)
    c1.check(dora_tr.slo_attainment > best_obl,
             f"dora {dora_tr.slo_attainment:.1%} vs best oblivious "
             f"{best_obl:.1%}")
    c2 = Claim("Serving: dora serves every request across the churn "
               "window (adapter replans onto the surviving fleet)")
    c2.check(dora_tr.n_failed == 0 and dora_tr.replans >= 2,
             f"{dora_tr.n_failed} failed, {dora_tr.replans} replans")
    report.add_claims([c1, c2])
    report.stash("fig_serving", {k: t.to_dict() for k, t in traces.items()})


if __name__ == "__main__":
    import sys

    from .run import Report
    r = Report()
    run(r)
    sys.exit(0 if all(c.ok for c in r.claims) else 1)
