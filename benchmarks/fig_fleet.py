"""Multi-tenant fleets: co-planning vs independent per-tenant planning.

The claim behind ``repro.fleet``: when several workloads share one edge
fleet, planning each tenant *independently on the full fleet* piles
every tenant onto the same energy-optimal device — once the resulting
fluid-fair interference is priced (a device in k plans serves each at
1/k of its cycles), tenants blow their QoE targets and burn more
energy.  Co-planning (``dora.plan_fleet``: exclusive device allotments,
fluid-fair shared links, joint assignment search) keeps every tenant
QoE-feasible on the same hardware.

For each registered multi-tenant fleet scenario this harness plans both
ways, tabulates per-tenant latency vs target and total energy, then
runs the multi-tenant serving simulator on the co-planned session
(request streams + fleet timeline with churn/rebalancing) and checks
that no exclusive device is ever oversubscribed.  Everything lands in
``BENCH_fleet.json`` at the repo root (uploaded by CI alongside
``BENCH_planner.json``).
"""
from __future__ import annotations

import json
import os

from .common import QUICK, Claim, table

from repro import dora
from repro.fleet import list_fleets, plan_independent, resolve_fleet

ARTIFACT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_fleet.json"))

#: Fleets whose independent baseline must violate QoE or overspend —
#: the acceptance pair; QUICK runs only these two.
CLAIM_FLEETS = ("smart_home_assist", "traffic_intersection")


def run(report) -> None:
    fleets = list(CLAIM_FLEETS) if QUICK else list_fleets()
    artifact = {}
    rows = []
    claims = []
    sim_rows = []
    for name in fleets:
        fs = resolve_fleet(name)
        co = dora.plan_fleet(name)
        ind = plan_independent(fs.build_topology(), fs.tenants,
                               name=fs.name)
        for tenant in co.tenants:
            c, i = co.tenant(tenant), ind.tenant(tenant)
            rows.append([
                name, tenant, f"{c.scenario.qoe.t_qoe:g}",
                f"{c.latency * 1e3:.1f}", "OK" if c.feasible else "MISS",
                f"{i.latency * 1e3:.1f}", "OK" if i.feasible else "MISS",
                str(list(c.allotment)), str(list(i.allotment))])
        artifact[name] = {"co_planned": co.to_dict(),
                          "independent": ind.to_dict()}

        wins = (co.feasible
                and (not ind.feasible
                     or ind.total_energy > 1.05 * co.total_energy))
        detail = (f"co: feasible={co.feasible} E={co.total_energy:.2f} J/req"
                  f"; independent: feasible={ind.feasible} "
                  f"E={ind.total_energy:.2f} J/req")
        if name in CLAIM_FLEETS:
            c = Claim(f"Fleet {name}: co-planning keeps every tenant "
                      f"QoE-feasible where independent full-fleet planning "
                      f"violates QoE or spends >5% more energy")
            c.check(wins, detail)
            claims.append(c)
        artifact[name]["co_planning_wins"] = bool(wins)

        trace = dora.simulate(name, mode="fleet")
        artifact[name]["serving"] = trace.to_dict()
        for tenant, tr in trace.tenants.items():
            sim_rows.append([name, tenant, len(tr.requests),
                             f"{tr.load.rate:g}",
                             f"{tr.p50:.3f}" if tr.p50 == tr.p50 else "-",
                             f"{tr.p99:.3f}" if tr.p99 == tr.p99 else "-",
                             f"{tr.slo_attainment:.1%}", trace.rebalances])
        over = trace.oversubscribed_devices
        c = Claim(f"Fleet {name}: the serving simulator never "
                  f"oversubscribes an exclusive device")
        c.check(not over, f"oversubscribed: {over or 'none'}")
        claims.append(c)

    report.add_table(table(
        ["fleet", "tenant", "t_qoe (s)", "co lat (ms)", "co QoE",
         "indep lat (ms)", "indep QoE", "co devs", "indep devs"], rows,
        "Co-planned vs independently-planned tenants "
        "(independent latencies priced under fluid-fair interference)"))
    report.add_table(table(
        ["fleet", "tenant", "reqs", "rate/s", "p50 (s)", "p99 (s)",
         "SLO att.", "rebalances"], sim_rows,
        "Multi-tenant serving on the co-planned fleets "
        "(default timelines: churn, throttles, WiFi shifts)"))
    report.add_claims(claims)
    report.stash("fig_fleet", artifact)

    with open(ARTIFACT, "w", encoding="utf-8") as f:
        json.dump(artifact, f, indent=2, allow_nan=False)
        f.write("\n")
    print(f"wrote {ARTIFACT}")


if __name__ == "__main__":
    import sys

    from .run import Report
    r = Report()
    run(r)
    sys.exit(0 if all(c.ok for c in r.claims) else 1)
