"""Fig. 2 — contention-oblivious planning degrades under a real shared
medium.

Reproduces the motivating experiment: Asteroid's plan evaluated under
(1) its own idealized D2D assumption, (2) the real shared-WiFi network,
vs (3) the brute-force optimal under real conditions, and (4) Dora.
Paper: 2.4× degradation D2D→Edge, 2.8× gap to optimal.
"""
from __future__ import annotations

from .common import Claim, table

from repro.core.engine import EventEngine
from repro.core.cep import build_cep, cep_resource_caps
from repro.core.qoe import QoESpec
from repro.sim.runner import dora_plan, scenario_case
from repro.strategies import get_strategy

LAT = QoESpec(t_qoe=0.0, lam=1e15)


def _d2d_latency(plan, topo):
    """Evaluate the plan in the idealized world: every transfer gets the
    pair's full peak bandwidth concurrently (no shared-medium coupling)."""
    tasks = build_cep(plan, topo)
    # dedicated per-task resources: clone each comm task onto its own link
    caps = {}
    fixed = []
    for t in tasks:
        if t.kind == "comm" and t.resources:
            cap = min(cep_resource_caps(topo)[r] for r in t.resources)
            rname = f"dedicated::{t.name}"
            caps[rname] = cap
            fixed.append(t.clone(resources=(rname,), net_latency=0.0))
        else:
            fixed.append(t)
    eng = EventEngine(fixed, caps, comm_mode="fair")
    eng.assign_priorities()
    return eng.run().makespan


def run(report) -> None:
    topo, graph, wl = scenario_case("smart_home_2", model="bert",
                                    mode="train")

    # both comparison points resolve through the strategy registry: the
    # asteroid baseline returns its plan already priced under real fluid
    # contention, brute_force real-evaluates its shortlist the same way
    ast = get_strategy("asteroid").plan(graph, topo, LAT, wl).best
    d2d = _d2d_latency(ast, topo)
    edge = ast.latency

    opt = get_strategy("brute_force", shortlist=150).plan(
        graph, topo, LAT, wl).best
    dora = dora_plan(graph, topo, LAT, wl).best
    if dora.latency < opt.latency:      # optimal = best of search ∪ planners
        opt = dora

    rows = [["Asteroid @ D2D (idealized)", f"{d2d * 1e3:.0f}"],
            ["Asteroid @ Edge (real WiFi)", f"{edge * 1e3:.0f}"],
            ["Optimal (brute force, real)", f"{opt.latency * 1e3:.0f}"],
            ["Dora (real)", f"{dora.latency * 1e3:.0f}"]]
    report.add_table(table(["plan", "iteration latency (ms)"], rows,
                           "Fig. 2 — contention degrades oblivious plans"))

    c1 = Claim("Fig2: Asteroid degrades ≥1.5× from idealized D2D to real edge "
               "(paper: 2.4×)")
    c1.check(edge / d2d >= 1.5, f"measured {edge / d2d:.2f}×")
    c2 = Claim("Fig2: Asteroid ≥1.3× slower than brute-force optimal "
               "(paper: 2.8×)")
    c2.check(edge / opt.latency >= 1.3, f"measured {edge / opt.latency:.2f}×")
    c3 = Claim("Fig2: Dora within 15% of the brute-force optimal")
    c3.check(dora.latency <= opt.latency * 1.15,
             f"dora/opt = {dora.latency / opt.latency:.2f}")
    report.add_claims([c1, c2, c3])
